"""Fig. 5: PE energy vs sequence length (16- and 32-wide), analytical model
+ measured CPU wall-time of the softermax kernel vs the two-pass baseline
(the measurable half of the same claim: one fused pass beats max+exp+div)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.softermax as sm
from repro.core import energy_model


def _time(f, x, iters=5):
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(x).block_until_ready()
    return (time.perf_counter() - t0) / iters


def run_model():
    return energy_model.fig5_sweep()


def run_measured(seq_lens=(256, 512, 1024, 2048)):
    """CPU wall time: two-pass e-base softmax vs one-pass softermax scan."""
    rows = []
    two_pass = jax.jit(sm.softmax_e)
    one_pass = jax.jit(lambda x: sm.softermax_online_scan(x, block=512))
    for S in seq_lens:
        x = jnp.asarray(np.random.default_rng(0).normal(size=(64, S)),
                        jnp.float32) * 4
        rows.append({
            "seq_len": S,
            "two_pass_us": _time(two_pass, x) * 1e6,
            "softermax_us": _time(one_pass, x) * 1e6,
        })
    return rows


def main():
    for r in run_model():
        print(f"fig5_model,width={r['width']},seq={r['seq_len']},"
              f"baseline_uj={r['baseline_uj']:.2f},"
              f"softermax_uj={r['softermax_uj']:.2f},ratio={r['ratio']:.3f}")
    for r in run_measured():
        print(f"fig5_measured,seq={r['seq_len']},"
              f"two_pass_us={r['two_pass_us']:.1f},"
              f"softermax_us={r['softermax_us']:.1f}")


if __name__ == "__main__":
    main()
