"""Roofline table from the dry-run artifacts (artifacts/dryrun/**.json).

Renders EXPERIMENTS.md §Roofline rows: the three terms (seconds), dominant
bottleneck, MODEL_FLOPS / HLO_FLOPs ratio, roofline fraction — per
(arch × shape × mesh).
"""
from __future__ import annotations

import glob
import json
import os

ART = os.environ.get("DRYRUN_ART", "artifacts/dryrun")


def _upgrade(r):
    """Recompute roofline terms with analytic FLOPs for artifacts written
    before launch/analytic.py existed (no recompile needed)."""
    if r.get("skipped") or r["roofline"].get("analytic_flops"):
        return r
    from repro.configs.base import ALL_SHAPES
    from repro.launch.analytic import analytic_flops
    from repro.launch.roofline import Roofline
    from repro.models.registry import get_config
    shape = {s.name: s for s in ALL_SHAPES}[r["shape"]]
    cfg = get_config(r["arch"])
    rf = r["roofline"]
    roof = Roofline(
        flops_per_chip=rf["flops_per_chip"],
        bytes_per_chip=rf["bytes_per_chip"],
        collective_per_chip=rf["collective_per_chip"],
        chips=rf["chips"],
        model_flops=rf["model_flops"],
        collective_breakdown=rf["collective_breakdown"],
        analytic_flops=analytic_flops(cfg, shape),
    )
    r["roofline"] = roof.to_dict()
    return r


def load(mesh="16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        with open(path) as f:
            rows.append(_upgrade(json.load(f)))
    return rows


def render(mesh="16x16"):
    lines = []
    for r in load(mesh):
        if r.get("skipped"):
            lines.append(f"roofline,{mesh},{r['arch']},{r['shape']},SKIP")
            continue
        rf = r["roofline"]
        lines.append(
            f"roofline,{mesh},{r['arch']},{r['shape']},"
            f"compute={rf['compute_s']:.4g}s,memory={rf['memory_s']:.4g}s,"
            f"collective={rf['collective_s']:.4g}s,dom={rf['dominant']},"
            f"useful={rf['useful_flops_ratio']:.3f},"
            f"frac={rf['roofline_fraction']:.4f}")
    return lines


def markdown_table(mesh="16x16"):
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful FLOPs ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in load(mesh):
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"SKIP | — | — |")
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4g} | "
            f"{rf['memory_s']:.4g} | {rf['collective_s']:.4g} | "
            f"{rf['dominant']} | {rf['useful_flops_ratio']:.3f} | "
            f"{rf['roofline_fraction']:.4f} |")
    return "\n".join(out)


def main():
    meshes = [m for m in ("16x16", "2x16x16")
              if os.path.isdir(os.path.join(ART, m))]
    if not meshes:
        return
    # self-describing CSV: the roofline rows come from committed dry-run
    # artifacts, not a fresh measurement — the header says which checkout
    # rendered them so CI uploads can be diffed by commit
    from benchmarks.provenance import provenance
    print("# provenance:",
          json.dumps(provenance(mode="dryrun-artifacts"), sort_keys=True))
    for mesh in meshes:
        for line in render(mesh):
            print(line)


if __name__ == "__main__":
    main()
