"""Per-step grid planning vs the best static grid on a mixed
short/long-context decode trajectory.

Replays the serving regime the planner exists for: a fused decode batch
where ONE long-context request stretches the shared table width while
short requests come and go. While the long request is live, wide-table
steps favor big kv tiles and split-K (amortize grid-step overhead, cut
the long lane's sequential walk); once it finishes, the width bucket
collapses and the same grid is pure padding overhead for the surviving
short rows — the step-optimal grid *changes mid-trajectory*, which is
exactly what a static knob cannot follow.

Both sides are scored with the analytic cost model
(``serve/kernel_costs.py`` — the same model the serve-time planner uses,
pinned byte-exact against the ref-layer gather oracles by
``tests/test_kernel_costs.py``):

* **static**    — every candidate grid held for the whole trajectory;
  the BEST one (min total modeled step latency) is the baseline.
* **per-step**  — ``GridPlanner`` re-ranks the same candidates each step
  from that step's lengths vector.

Per-step total ≤ best-static total holds by construction (a per-step
argmin can never lose to any fixed choice under the same model — the gate
``>= 1.0`` is a tautology check on the machinery); the *strict* win on
the mixed workload is the regime shift above, and full mode asserts it.
Wall-clock is NOT the headline off-TPU: the Pallas interpreter serializes
grid lanes, so split-K latency wins don't materialize under it — the JSON
records ``measurement_mode: analytic-cost-model`` honestly, and the
engine-level check instead gates what must hold on EVERY backend: greedy
streams are identical at every autotune mode (grids are layout, not
math), and planning overhead is microseconds per step.

Full mode writes ``BENCH_autotune.json`` (repo root). Prints
``autotune_bench,...`` CSV lines, last one the static/per-step modeled
cost ratio.

    PYTHONPATH=src python benchmarks/autotune_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _trajectory(args):
    """Engine-faithful batch states: per decode step, the lengths vector
    the kernel attends (zombie rows = 1) and the pow2-bucketed table
    width covering the LIVE rows. One long request (finishes mid-run) +
    staggered short requests."""
    from repro.serve.paged_step import table_width_bucket

    BS = args.block_size
    rng = np.random.default_rng(args.seed)
    reqs = [(args.long_blocks * BS, args.long_steps)]   # (start_len, n_new)
    for _ in range(args.requests - 1):
        reqs.append((int(rng.integers(BS, args.short_blocks_max * BS + 1)),
                     int(rng.integers(args.short_steps_min,
                                      args.short_steps_max + 1))))
    steps = []
    for t in range(max(n for _, n in reqs)):
        live = [(s + t) for s, n in reqs if t < n]
        if not live:
            break
        lens = np.ones((args.requests,), np.int64)      # zombies attend 1
        i = 0
        for s, n in reqs:
            if t < n:
                lens[i] = s + t + 1                     # kernel's new_len
            i += 1
        need = max(-(-ln // BS) for ln in live) + 1     # next-token block
        steps.append((lens, table_width_bucket(need)))
    return steps


def _model_costs(args, steps):
    from repro.serve.autotune import GridPlanner
    from repro.serve.kernel_costs import (CostParams, decode_launch_cost,
                                          estimate_seconds)

    # The machine model is pinned at a BALANCED operating point: cores=8
    # exposes split-K parallelism vs tile padding, and flops_per_s sits
    # where tile-rounding compute (lengths-dependent — short rows round
    # up to the tile) is comparable to per-grid-step overhead (width-
    # dependent). At an overhead-dominated point every step degenerates
    # to "biggest tile" and per-step merely ties static; the balanced
    # point is where planning has a decision to make — which is the
    # regime real hardware occupies whenever a knob is worth tuning. The
    # conclusions are *relative* (per-step vs static under one consistent
    # model), not absolute seconds.
    params = CostParams(cores=args.cores, flops_per_s=args.flops_per_s)
    cands = [tuple(map(int, c.split("x"))) for c in args.candidates.split(",")]
    shape = dict(n_q_heads=args.hq, n_kv_heads=args.hkv,
                 head_dim=args.head_dim, block_size=args.block_size,
                 kv_dtype=args.kv_dtype)

    static_tot = {c: 0.0 for c in cands}
    for lens, w in steps:
        for (ti, sp) in cands:
            c = decode_launch_cost(lens, w, kv_tile_blocks=ti, split_k=sp,
                                   **shape)
            static_tot[(ti, sp)] += estimate_seconds(c, params)
    best_static, best_tot = min(static_tot.items(), key=lambda kv: kv[1])

    planner = GridPlanner(cands, cost_params=params, **shape)
    t0 = time.time()
    per_step = [planner.plan_decode(lens, w) for lens, w in steps]
    plan_us = (time.time() - t0) / len(steps) * 1e6
    per_tot = sum(d.predicted_s for d in per_step)
    return (best_static, best_tot, per_tot, static_tot, planner.summary(),
            plan_us)


def _engine_equality(args, rng):
    """Greedy streams must be identical at every autotune mode (off /
    static / per-step), bf16 and int8 — planning changes layout only."""
    import jax
    from repro.models.registry import get_config, model_fns, reduce_config
    from repro.serve import ContinuousEngine

    cfg = reduce_config(get_config(args.arch))
    params = model_fns(cfg).init(jax.random.PRNGKey(0))
    prompts = [rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (13, 41, 7)]

    def serve(**kw):
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                               max_batch=4, max_len=96, kv_tile_blocks=2,
                               decode_split_k=2, **kw)
        hs = [eng.submit(p, 6) for p in prompts]
        res = eng.run()
        return [res[h.req_id].tokens for h in hs], eng

    decided = 0
    for kd in ({}, {"kv_dtype": "int8"}):
        off, _ = serve(**kd)
        stat, _ = serve(autotune="static", **kd)
        step, es = serve(autotune="per-step", **kd)
        assert off == stat == step, \
            f"{kd or 'bf16'}: greedy streams diverged across autotune modes"
        decided += sum(es.planner.summary().values())
    assert decided > 0, "per-step planner made no decisions"
    return True


def main(argv=None) -> float:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--hq", type=int, default=8)
    ap.add_argument("--hkv", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--long-blocks", type=int, default=44,
                    help="resident blocks of the long request at step 0")
    ap.add_argument("--long-steps", type=int, default=48,
                    help="decode steps the long request stays live; after "
                         "it finishes the width bucket collapses — the "
                         "regime shift per-step planning exploits")
    ap.add_argument("--short-blocks-max", type=int, default=6)
    ap.add_argument("--short-steps-min", type=int, default=24)
    ap.add_argument("--short-steps-max", type=int, default=96)
    ap.add_argument("--candidates", default="1x1,4x1,8x1,1x4,4x2,4x4",
                    help="comma-separated TILExSPLIT grid candidates")
    ap.add_argument("--cores", type=int, default=8,
                    help="CostParams.cores for the machine model")
    ap.add_argument("--flops-per-s", type=float, default=5e10,
                    help="CostParams.flops_per_s — see _model_costs for "
                         "why the default sits at the balanced "
                         "overhead-vs-compute operating point")
    ap.add_argument("--kv-dtype", default="float32",
                    choices=("float32", "bfloat16", "int8"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_autotune.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast mode for CI (gates per-step >= static "
                         "and engine greedy equality)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = 4
        args.long_blocks, args.long_steps = 12, 10
        args.short_steps_min, args.short_steps_max = 6, 20
        args.candidates = "1x1,2x1,2x2"

    rng = np.random.default_rng(args.seed)
    steps = _trajectory(args)
    print(f"autotune_bench,workload,requests,{args.requests},steps,"
          f"{len(steps)},long_blocks,{args.long_blocks},candidates,"
          f"{args.candidates.replace(',', '+')}")

    (best_static, best_tot, per_tot, static_tot, decisions,
     plan_us) = _model_costs(args, steps)
    ratio = best_tot / per_tot
    for (ti, sp), tot in sorted(static_tot.items()):
        print(f"autotune_bench,static,t{ti}_s{sp},modeled_s,{tot:.6f}")
    print(f"autotune_bench,per_step,modeled_s,{per_tot:.6f},"
          f"plan_us_per_step,{plan_us:.1f}")
    print(f"autotune_bench,decisions,{json.dumps(decisions)}")

    assert ratio >= 1.0, (
        f"per-step planning lost to a fixed grid under its own model "
        f"({ratio:.4f}x) — the argmin is broken")

    _engine_equality(args, rng)
    print("autotune_bench,engine,greedy_equal,1")
    print(f"autotune_bench,ratio_best_static_over_per_step,{ratio:.4f}")

    if not args.smoke:
        assert ratio > 1.0, (
            "per-step planning only TIED the best static grid on the "
            "mixed-length workload — the regime shift should force "
            "different step-optimal grids")
        assert len(decisions) > 1, (
            f"planner picked one grid for the whole mixed trajectory "
            f"({decisions}) — no per-step signal")
        from benchmarks.provenance import provenance
        record = {
            "bench": "autotune",
            "provenance": provenance(mode="analytic-cost-model"),
            "workload": {
                "requests": args.requests, "hq": args.hq, "hkv": args.hkv,
                "head_dim": args.head_dim, "block_size": args.block_size,
                "long_blocks": args.long_blocks,
                "long_steps": args.long_steps,
                "short_blocks_max": args.short_blocks_max,
                "decode_steps": len(steps), "arch": args.arch,
                "reduced": True},
            "machine_model": {"cores": args.cores,
                              "flops_per_s": args.flops_per_s,
                              "kv_dtype": args.kv_dtype},
            "candidates": sorted(f"t{t}_s{s}" for t, s in static_tot),
            "static_modeled_s": {f"t{t}_s{s}": round(v, 6)
                                 for (t, s), v in sorted(static_tot.items())},
            "best_static": f"t{best_static[0]}_s{best_static[1]}",
            "per_step_modeled_s": round(per_tot, 6),
            "planning_us_per_step": round(plan_us, 1),
            "decisions": decisions,
            "ratio_best_static_over_per_step": round(ratio, 4),
            "greedy_equal": True,
        }
        with open(args.out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"autotune_bench,wrote,{args.out}")
    return ratio


if __name__ == "__main__":
    main()
