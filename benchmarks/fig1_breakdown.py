"""Fig. 1 analogue: softmax fraction of attention runtime vs sequence length.

The paper profiles BERT-Large on a Volta GPU and shows softmax growing to a
large runtime fraction at long sequence lengths. We reproduce the *shape* of
that claim on CPU: measure matmul (QK^T + AV) time vs softmax time of a
single attention layer across sequence lengths, for the e-base baseline and
for softermax (base-2 + online).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.softermax as sm

H, D = 16, 64


def _time(f, *args, iters=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters


def run():
    rows = []
    for S in (128, 256, 512, 1024):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(1, H, S, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, H, S, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, H, S, D)), jnp.float32)

        mm1 = jax.jit(lambda q, k: jnp.einsum("bhqd,bhkd->bhqk", q, k))
        soft_e = jax.jit(lambda s: sm.softmax_e(s))
        soft_2 = jax.jit(lambda s: sm.softermax(s))
        mm2 = jax.jit(lambda p, v: jnp.einsum("bhqk,bhkd->bhqd", p, v))

        s = mm1(q, k)
        p = soft_e(s)
        t_mm = _time(mm1, q, k) + _time(mm2, p, v)
        t_soft_e = _time(soft_e, s)
        t_soft_2 = _time(soft_2, s)
        rows.append({
            "seq_len": S,
            "matmul_us": t_mm * 1e6,
            "softmax_e_us": t_soft_e * 1e6,
            "softermax_us": t_soft_2 * 1e6,
            "softmax_frac_baseline": t_soft_e / (t_soft_e + t_mm),
            "softmax_frac_softermax": t_soft_2 / (t_soft_2 + t_mm),
        })
    return rows


def main():
    for r in run():
        print(f"fig1,seq={r['seq_len']},"
              f"{r['softmax_e_us']:.0f},"
              f"frac_baseline={r['softmax_frac_baseline']:.3f},"
              f"frac_softermax={r['softmax_frac_softermax']:.3f}")


if __name__ == "__main__":
    main()
