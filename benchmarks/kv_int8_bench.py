"""Int8 quantized paged KV pool vs bf16 at an EQUAL HBM budget.

Decode is memory-bandwidth-bound and pool capacity caps concurrency, so
storing K/V as int8 with per-row scales buys two compounding wins that this
benchmark measures separately:

1. **Pool token capacity** (the headline, asserted >= 1.8x in full mode):
   both pools get the same HBM byte budget — sized from the bf16 pool's
   ``PagedKVCache.bytes_per_block`` — and the int8 pool simply holds ~2x
   the blocks (per-row f32 scales cost Dh/4 of the int8 payload back, so
   the exact ratio is ``2*Dh / (Dh + 4)``; 1.88x at head_dim 64).
2. **Served throughput** (asserted >= 1.3x): a concurrency-bound workload —
   more admissible trajectories than either pool can hold at once — is
   served to completion by both engines. The bf16 engine's FIFO serializes
   into more admission waves; the int8 engine runs more requests per fused
   decode step at the same per-step cost. tok/s is wall-clock over
   delivered tokens, best of N alternating rounds, compiles excluded by a
   throwaway first round.

**Accuracy guardrail.** The runs must not buy speed with drift. Two gates:

* bounded logit error — an op-level probe asserts the max |logit delta|
  between a full-precision and an int8-quantized prefill stays within a
  documented bound (measured ~0.02 on this config; gated at 0.1). On a
  trained checkpoint top-2 gaps are orders of magnitude above this, so
  greedy outputs are unchanged in practice.
* greedy-flip audit — every request's token stream is compared
  bf16-vs-int8. The reduced config is *random-init*, so its logits are
  near-uniform and top-2 gaps are routinely inside the noise band; for
  each diverged stream the bench recomputes the full-precision logits at
  the first divergence and asserts the top-2 gap there is below the
  documented band (the flip is quantization-noise on a near-tie, not
  drift). A flip at a decisive gap fails the bench. Agreement rate and
  the largest excused gap are recorded in the JSON.

The config is the reduced CPU-smoke model with a production head_dim (64):
the capacity ratio depends only on head_dim, and 16-dim toy heads would
overstate the relative scale overhead.

Full mode writes ``BENCH_kv_int8.json`` (repo root).

    PYTHONPATH=src python benchmarks/kv_int8_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np


def make_prompts(n: int, plen: int, vocab: int, seed: int) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, (plen,)).astype(np.int32)
            for _ in range(n)]


def _serve_once(eng, prompts, max_new):
    """One full serve of the workload on a persistent engine (jit caches
    warm after the first round); returns (wall_s, token streams)."""
    handles = [eng.submit(p, max_new) for p in prompts]
    t0 = time.time()
    results = eng.run()
    wall = time.time() - t0
    toks = [results[h.req_id].tokens for h in handles]
    assert all(len(t) == max_new for t in toks)
    return wall, toks


def _prefill_logits(cfg, params, tokens, quantize):
    import jax.numpy as jnp
    from repro.serve.paged_step import paged_prefill
    t = jnp.asarray(np.asarray(tokens, np.int32)[None])
    last = jnp.asarray([t.shape[1] - 1], jnp.int32)
    lg, _, _ = paged_prefill(params, t, last, cfg, kv_quantize=quantize)
    return np.asarray(lg[0, :cfg.vocab_size])


def _logit_error_probe(cfg, params, prompt) -> float:
    """Max |logit delta| of a full-precision vs int8-quantized prefill of
    one prompt — the documented accuracy bound for the pool."""
    return float(np.abs(_prefill_logits(cfg, params, prompt, False) -
                        _prefill_logits(cfg, params, prompt, True)).max())


def _audit_divergences(cfg, params, prompts, toks_ref, toks_q, band):
    """For every request whose int8 stream diverges from bf16, check the
    first flipped token was a near-tie: the full-precision logit gap
    between the two tokens that actually diverged (bf16's pick vs int8's
    pick, NOT the generic top-2 — int8 jumping to a distant runner-up
    would be drift even next to an unrelated tie) must sit inside the
    documented noise ``band``. Returns (n_diverged, max excused gap);
    raises on a decisive flip."""
    n_div, max_gap = 0, 0.0
    for prompt, a, b in zip(prompts, toks_ref, toks_q):
        if a == b:
            continue
        n_div += 1
        d = next(i for i, (x, y) in enumerate(zip(a, b)) if x != y)
        ctx = np.concatenate([prompt, np.asarray(a[:d], np.int32)])
        lg = _prefill_logits(cfg, params, ctx, False)
        gap = abs(float(lg[a[d]] - lg[b[d]]))
        max_gap = max(max_gap, gap)
        assert gap <= band, (
            f"int8 flipped a greedy token at a decisive logit gap "
            f"{gap:.4f} > noise band {band} (true drift, not a near-tie)")
    return n_div, max_gap


def main(argv=None) -> float:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--head-dim", type=int, default=64,
                    help="override the reduced config's toy head_dim with "
                         "a production one — the capacity ratio "
                         "2*Dh/(Dh+4) is what's being measured")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=40)
    ap.add_argument("--max-new", type=int, default=56)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--bf16-blocks", type=int, default=18,
                    help="bf16 pool blocks; its HBM bytes are the shared "
                         "budget the int8 pool is sized from (3 "
                         "trajectories' worth by default — the workload "
                         "stays concurrency-bound for both pools)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="alternating serve rounds per engine; best-of")
    ap.add_argument("--logit-tol", type=float, default=0.1,
                    help="guardrail: max |logit delta| allowed between "
                         "full-precision and int8-quantized prefill")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_kv_int8.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast mode for CI (asserts int8==bf16 greedy "
                         "outputs + the logit bound; ratios reported, not "
                         "gated)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = 4
        args.prompt_len = 12
        args.max_new = 8
        args.bf16_blocks = 6
        args.block_size = 8
        args.repeats = 1
        args.seed = 2       # verified: all top-2 gaps clear the noise band

    import jax
    from repro.models.registry import get_config, model_fns, reduce_config
    from repro.serve.kv_pool import PagedKVCache
    cfg = reduce_config(get_config(args.arch)).replace(
        head_dim=args.head_dim)
    params = model_fns(cfg).init(jax.random.PRNGKey(0))

    # -- equal HBM budget -------------------------------------------------
    b_bf16 = PagedKVCache.bytes_per_block(cfg, args.block_size, "bf16")
    b_int8 = PagedKVCache.bytes_per_block(cfg, args.block_size, "int8")
    budget = args.bf16_blocks * b_bf16
    int8_blocks = budget // b_int8
    cap_ratio = int8_blocks / args.bf16_blocks
    print(f"kv_int8_bench,budget_bytes,{budget},bf16_blocks,"
          f"{args.bf16_blocks},int8_blocks,{int8_blocks},"
          f"capacity_ratio,{cap_ratio:.2f}")

    max_len = args.prompt_len + args.max_new
    traj_blocks = -(-(max_len - 1) // args.block_size)
    conc = {"bf16": args.bf16_blocks // traj_blocks,
            "int8": int8_blocks // traj_blocks}
    max_batch = max(conc["int8"] + 1, 2)
    print(f"kv_int8_bench,workload,requests,{args.requests},prompt,"
          f"{args.prompt_len},max_new,{args.max_new},traj_blocks,"
          f"{traj_blocks},concurrency,bf16,{conc['bf16']},int8,"
          f"{conc['int8']}")

    prompts = make_prompts(args.requests, args.prompt_len, cfg.vocab_size,
                           args.seed)
    from repro.serve import ContinuousEngine
    engines = {
        kv: ContinuousEngine(
            cfg, params, block_size=args.block_size, num_blocks=nb,
            max_batch=max_batch, max_len=max_len,
            max_admit_per_step=max_batch, prefix_cache=False, kv_dtype=kv)
        for kv, nb in (("bf16", args.bf16_blocks),
                       ("int8", int(int8_blocks)))}

    # throwaway round per engine to compile, then alternating timed rounds
    walls = {"bf16": [], "int8": []}
    toks = {}
    for eng in engines.values():
        _serve_once(eng, prompts, args.max_new)
    for _ in range(args.repeats):
        for kv, eng in engines.items():
            w, t = _serve_once(eng, prompts, args.max_new)
            walls[kv].append(w)
            toks[kv] = t
    assert engines["int8"].metrics.preemptions == 0

    total = args.requests * args.max_new
    tok_s = {kv: total / min(ws) for kv, ws in walls.items()}
    ratio = tok_s["int8"] / tok_s["bf16"]
    for kv in ("bf16", "int8"):
        print(f"kv_int8_bench,{kv},serve_s,{min(walls[kv]):.3f},"
              f"tok_s,{tok_s[kv]:.0f}")
    print(f"kv_int8_bench,ratio_int8_over_bf16,{ratio:.2f}")

    # -- accuracy guardrail ----------------------------------------------
    err = max(_logit_error_probe(cfg, params, p) for p in prompts[:3])
    assert err <= args.logit_tol, (
        f"int8 prefill logit error {err:.4f} > {args.logit_tol}")
    greedy_equal = toks["bf16"] == toks["int8"]
    n_div, flip_gap = _audit_divergences(
        cfg, params, prompts, toks["bf16"], toks["int8"],
        band=2 * args.logit_tol)
    agreement = 1.0 - n_div / args.requests
    print(f"kv_int8_bench,guardrail,greedy_equal,{int(greedy_equal)},"
          f"agreement,{agreement:.2f},max_logit_err,{err:.4f},"
          f"max_excused_flip_gap,{flip_gap:.4f}")
    # the audit above IS the gate in both modes: it raised already if any
    # flip sat at a decisive gap. (The smoke seed happens to produce zero
    # flips on the verified toolchain, but CI must not depend on that —
    # a different XLA/BLAS can legitimately flip a near-tie.)

    if not args.smoke:
        assert cap_ratio >= 1.8, (
            f"equal-HBM token capacity {cap_ratio:.2f}x < 1.8x")
        assert ratio >= 1.3, (
            f"int8 served tok/s {ratio:.2f}x < 1.3x at equal HBM")
        from benchmarks.provenance import provenance
        record = {
            "bench": "kv_int8",
            "provenance": provenance(mode="measured"),
            "workload": {"requests": args.requests,
                         "prompt_len": args.prompt_len,
                         "max_new": args.max_new,
                         "block_size": args.block_size,
                         "head_dim": args.head_dim,
                         "bf16_blocks": args.bf16_blocks,
                         "int8_blocks": int(int8_blocks),
                         "arch": args.arch, "reduced": True},
            "backend": jax.default_backend(),
            "hbm_budget_bytes": int(budget),
            "capacity_ratio_int8_over_bf16": round(cap_ratio, 3),
            "bf16": {"serve_s": round(min(walls["bf16"]), 4),
                     "tok_s": round(tok_s["bf16"], 1)},
            "int8": {"serve_s": round(min(walls["int8"]), 4),
                     "tok_s": round(tok_s["int8"], 1)},
            "tok_s_ratio_int8_over_bf16": round(ratio, 3),
            "greedy_equal": greedy_equal,
            "greedy_agreement": round(agreement, 3),
            "divergences_excused_as_near_ties": n_div,
            "max_excused_flip_gap": round(flip_gap, 5),
            "max_prefill_logit_error": round(err, 5),
        }
        with open(args.out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"kv_int8_bench,wrote,{args.out}")
    return ratio


if __name__ == "__main__":
    main()
