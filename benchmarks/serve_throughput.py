"""Serving throughput: continuous batching (paged KV) vs static slots.

Replays the same Poisson-arrival, mixed-length workload (prompts drawn from
[min_prompt, max_prompt]) through both engines at an EQUAL KV memory budget
of ``num_blocks * block_size`` cache tokens:

* static — ``ServeEngine`` slots are sized for the worst case
  (max_prompt + max_new tokens), so the budget admits only
  ``budget // slot_width`` requests at once and every prompt is padded to
  max_prompt (the over-allocation a static engine cannot avoid);
* paged  — ``ContinuousEngine`` allocates each request
  ceil(len/block_size) blocks and grows block-by-block, so the same budget
  holds ~2× the concurrent requests and short prompts prefill at their
  padded-to-block length, not the global max.

Both engines are warmed up (all jit shapes compiled) before the measured
phase. Prints ``serve_throughput,...`` CSV lines, last one the paged/static
tok/s ratio.

    PYTHONPATH=src python benchmarks/serve_throughput.py [--fast] \
        [--engine {static,paged,both}]
"""
from __future__ import annotations

import argparse
import dataclasses
import gc
import time
from collections import deque
from typing import List

import numpy as np


@dataclasses.dataclass
class Arrival:
    t: float                 # seconds after workload start
    prompt: np.ndarray


def make_workload(n: int, rate: float, min_prompt: int, max_prompt: int,
                  vocab: int, seed: int) -> List[Arrival]:
    """Poisson arrivals; prompt lengths are the classic serving mixture —
    mostly short (chat turns), a long tail up to max_prompt (documents).
    The static engine must size every slot for the tail."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, n)
    ts = np.cumsum(gaps)
    ts[0] = 0.0              # clock starts at the first request
    mid = min(min_prompt + 16, max_prompt)
    lens = np.where(rng.random(n) < 0.8,
                    rng.integers(min_prompt, mid + 1, n),
                    rng.integers(mid, max_prompt + 1, n))
    return [Arrival(float(t),
                    rng.integers(1, vocab, (int(L),)).astype(np.int32))
            for t, L in zip(ts, lens)]


def make_paged_driver(cfg, params, workload, *, block_size, num_blocks,
                      max_batch, max_len, max_new, telemetry=None):
    """Returns drive() -> (tok_s, metrics) on one warmed engine."""
    from repro.serve import ContinuousEngine, EngineMetrics
    # prefix cache OFF: the repeats replay identical prompts, and a warm
    # radix tree would let the paged engine skip prefills the static engine
    # must run — this benchmark isolates the paged-vs-static structural win;
    # prefix reuse has its own benchmark (prefix_cache_bench.py)
    eng = ContinuousEngine(cfg, params, block_size=block_size,
                           num_blocks=num_blocks, max_batch=max_batch,
                           max_len=max_len, prefix_cache=False,
                           telemetry=telemetry)
    eng.warmup()                                   # compile all jit buckets

    def drive(telemetry=None):
        # the overhead check swaps telemetry on/off on THIS engine so the
        # on/off rounds share every jit cache and buffer — the ratio then
        # measures only the hooks, not engine-to-engine host noise
        eng.telemetry = telemetry
        pending = deque(workload)
        t0 = time.time()
        while pending or eng.sched.has_work():
            now = time.time() - t0
            while pending and pending[0].t <= now:
                eng.submit(pending.popleft().prompt, max_new)
            if eng.sched.has_work():
                eng.step()
            else:
                time.sleep(0.002)
        eng.drain()
        elapsed = time.time() - t0
        toks = sum(len(r.tokens) for r in eng.pop_finished().values())
        m = eng.metrics
        eng.metrics = EngineMetrics()
        return toks, elapsed, m

    return drive


def make_static_driver(cfg, params, workload, *, slots, pad_len, max_new,
                       window_s=0.25):
    """Static slots: fixed-size batches of worst-case-width cache rows.
    Prompts are padded to ``pad_len``; a batch launches when every slot is
    filled or no further arrivals can join within ``window_s``."""
    from repro.serve import ServeEngine
    eng = ServeEngine(cfg, params, max_len=pad_len + max_new)
    filler = np.ones((slots, pad_len), np.int32)
    eng.generate(filler, 2)                       # warmup compile

    def drive():
        pending = deque(workload)
        total = 0
        t0 = time.time()
        while pending:
            batch: List[Arrival] = []
            while len(batch) < slots:
                now = time.time() - t0
                if pending and pending[0].t <= now:
                    batch.append(pending.popleft())
                elif batch and (not pending or
                                pending[0].t > now + window_s):
                    break                          # launch underfilled
                elif not pending:
                    break
                else:
                    time.sleep(0.002)
            tokens = filler.copy()                 # dummy rows fill the batch
            for i, a in enumerate(batch):
                row = np.ones((pad_len,), np.int32)
                row[:a.prompt.shape[0]] = a.prompt  # pad to the slot width
                tokens[i] = row
            eng.generate(tokens, max_new)
            total += max_new * len(batch)
        return total, time.time() - t0

    return drive


def main(argv=None) -> float:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("static", "paged", "both"),
                    default="both")
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=4000.0,
                    help="Poisson arrival rate (req/s); default saturates "
                         "both engines so tok/s measures capacity")
    ap.add_argument("--repeats", type=int, default=5,
                    help="replay count per engine; best run is reported "
                         "(absorbs host-scheduler noise on small runs)")
    ap.add_argument("--min-prompt", type=int, default=16)
    ap.add_argument("--max-prompt", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--no-overhead-check", action="store_true",
                    help="skip the telemetry-enabled vs -disabled paged "
                         "drive comparison (and its extra warmup)")
    args = ap.parse_args(argv)
    if args.fast:
        args.repeats = 6      # warmup dominates runtime; keep the workload
                              # (6 rounds: the overhead gate is best-of —
                              # more paired windows for the host-noise tail)

    import jax
    from repro.models.registry import get_config, model_fns, reduce_config
    cfg = reduce_config(get_config(args.arch))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))

    budget = args.num_blocks * args.block_size     # cache tokens, both engines
    slot_width = args.max_prompt + args.max_new
    slots = max(budget // slot_width, 1)
    max_len = slot_width
    workload = make_workload(args.requests, args.rate, args.min_prompt,
                             args.max_prompt, cfg.vocab_size, args.seed)
    print(f"serve_throughput,budget_tokens,{budget},slot_width,{slot_width},"
          f"static_slots,{slots}")

    # interleave the repeats so both engines sample the same noise windows
    # (a slow host window then hurts both, not just whichever ran second)
    static_drive = paged_drive = None
    if args.engine in ("static", "both"):
        static_drive = make_static_driver(cfg, params, workload, slots=slots,
                                          pad_len=args.max_prompt,
                                          max_new=args.max_new)
    tel = None
    if args.engine in ("paged", "both"):
        paged_drive = make_paged_driver(
            cfg, params, workload, block_size=args.block_size,
            num_blocks=args.num_blocks, max_batch=args.max_batch,
            max_len=max_len, max_new=args.max_new)
        if not args.no_overhead_check:
            # telemetry fully on (per-request traces + step timeline +
            # latency histograms) for extra rounds on the SAME engine,
            # back-to-back with the plain rounds: identical jit caches and
            # buffers, adjacent noise windows — the <5% gate measures the
            # hooks, not engine-to-engine host variance
            from repro.serve import Telemetry
            tel = Telemetry()

    # interleaved rounds, each round pairing one static and one paged drive
    # in the same wall-clock window; the reported tok/s are the per-engine
    # medians and the ratio is the median of the per-round ratios — robust
    # to host-scheduler hiccups hitting either engine's turn
    s_rounds, p_rounds, t_rounds, ratios = [], [], [], []
    m = None
    for r in range(args.repeats):
        if static_drive:
            t, e = static_drive()
            s_rounds.append(t / e)
        if paged_drive:
            # alternate on/off order within the pair: whichever runs
            # second systematically sees a slightly colder window (turbo
            # decay, cache pressure), so a fixed order would bias the
            # overhead ratio. GC is held off across the pair (and only
            # the pair): a collection pause landing inside one window
            # would read as hook overhead — allocator cost that BOTH
            # configurations pay stays in the measurement either way.
            order = [(p_rounds, None)]
            if tel is not None:
                order.insert(r % 2, (t_rounds, tel))
            gc.collect()
            gc.disable()
            try:
                for sink, t_arg in order:
                    t, e, mm = paged_drive(telemetry=t_arg)
                    sink.append(t / e)
                    if t_arg is None:
                        m = mm
            finally:
                gc.enable()
        if static_drive and paged_drive:
            ratios.append(p_rounds[-1] / s_rounds[-1])
    tok_s_static = float(np.median(s_rounds)) if s_rounds else 0.0
    tok_s_paged = float(np.median(p_rounds)) if p_rounds else 0.0
    if static_drive:
        print(f"serve_throughput,static,tok_s,{tok_s_static:.2f},"
              f"concurrency,{slots}")
    if paged_drive:
        print(f"serve_throughput,paged,tok_s,{tok_s_paged:.2f},"
              f"peak_blocks,{m.peak_blocks},decode_steps,{m.decode_steps},"
              f"preemptions,{m.preemptions}")
    if tel is not None:
        # latency quantiles from the telemetry engine's streaming log-bucket
        # histograms (all measured rounds' samples; no per-sample storage)
        for name in ("ttft", "tpot", "e2e"):
            q = tel.quantiles(name)
            print(f"serve_throughput,{name},"
                  f"p50_ms,{q['p50'] * 1e3:.2f},"
                  f"p90_ms,{q['p90'] * 1e3:.2f},"
                  f"p99_ms,{q['p99'] * 1e3:.2f},n,{q['count']}")
        # <5% overhead gate: per-round on/off ratios pair back-to-back
        # drives of the same engine; best-of across rounds keeps a host-
        # scheduler hiccup in one window from reading as hook overhead
        overhead_ratio = max(t / p for t, p in zip(t_rounds, p_rounds))
        print("serve_throughput,telemetry_rounds_tok_s," +
              ",".join(f"{t:.0f}/{p:.0f}"
                       for t, p in zip(t_rounds, p_rounds)))
        print(f"serve_throughput,telemetry_on_over_off,"
              f"{overhead_ratio:.3f}")
        assert overhead_ratio >= 0.95, (
            f"telemetry-enabled tok/s {overhead_ratio:.3f}x of disabled "
            f"(> 5% regression)")
    if args.engine == "both":
        ratio = float(np.median(ratios))
        print(f"serve_throughput,ratio_paged_over_static,{ratio:.2f}")
        return ratio
    return 0.0


if __name__ == "__main__":
    main()
