"""Grouped/tiled/split paged decode kernel vs the per-head single-block
kernel (long-context mixed-length serving).

Replays the decode shape that dominates long-context serving — a fused
batch where ONE long request stretches the (pow2-bucketed, batch-shared)
block-table width that every short request's lane must also walk — through
the two kernel dataflows at an EQUAL pool budget (both sides read the same
physical pool through the same tables):

* **per-head** — the pre-restructure kernel (``flash_decode_paged_single``):
  grid ``(B*Hq, W)``, every query head of a GQA group re-gathering the
  group's shared KV block (group× redundant operand movement), one
  ``block_size``-row block per kv grid step, the whole walk serialized on
  one lane per head.
* **grouped**  — this PR (``flash_decode_paged``): grid
  ``(B*Hkv, split_k, W/(T*split_k))`` — one gather feeds the whole query
  group (``(group, D)`` MXU tiles instead of ``(1, D)`` vector dots), each
  step streams a ``kv_tile_blocks``-block KV tile, compute skips tiles past
  a row's length, and the split partials merge through the associative
  Softermax combine.

Two measurements:

1. **Kernel-level decode tok/s** (the headline, asserted ≥ 1.5× in full
   mode): N decode steps of the whole batch through each kernel, lengths
   advancing per step, best-of over strictly alternating rounds. On TPU
   this times the compiled kernels; elsewhere both kernels run under the
   Pallas *interpreter*, whose per-call cost tracks grid steps and
   per-step operand movement — exactly the quantities the restructure
   amortizes on hardware (the JSON records which mode produced the
   number). The modeled per-token gather traffic (the serve/README DMA
   math) is reported alongside as the hardware-side view.
2. **Engine-level greedy equality**: one-shot (cold + cached/COW-fork) and
   chunked engines at baseline and at tiled/split grid settings, bf16 and
   int8, must produce identical token streams per dtype — the grid knobs
   are layout, not math.

Full mode writes ``BENCH_decode.json`` (repo root) for the perf
trajectory. Prints ``decode_paged_bench,...`` CSV lines, last one the
tok/s ratio.

    PYTHONPATH=src python benchmarks/decode_paged_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _mixed_lengths(rng, requests, long_tokens, short_blocks_max,
                   block_size):
    """One long-context request + short mixed-length rest (the regime
    where the shared table width punishes the per-head kernel)."""
    lens = [long_tokens]
    for _ in range(requests - 1):
        lens.append(int(rng.integers(block_size,
                                     short_blocks_max * block_size + 1)))
    return np.asarray(lens, np.int64)


def _time_kernels(args, rng):
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_decode_paged import (flash_decode_paged,
                                                  flash_decode_paged_single,
                                                  split_layout)
    from repro.serve.paged_step import table_width_bucket

    B, Hq, Hkv = args.requests, args.hq, args.hkv
    D, BS = args.head_dim, args.block_size
    lens0 = _mixed_lengths(rng, B, args.long_blocks * BS,
                           args.short_blocks_max, BS)
    need = int(-(-(lens0.max() + args.steps) // BS))
    W = table_width_bucket(need)          # the engine's decode width policy
    N = int(sum(-(-(l + args.steps) // BS) for l in lens0)) + 1  # pool
    kp = jnp.asarray(rng.normal(size=(N, Hkv, BS, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, Hkv, BS, D)), jnp.float32)
    bt = np.zeros((B, W), np.int32)
    nxt = 1
    for b, l in enumerate(lens0):         # disjoint tables, pool-faithful
        nb = -(-(int(l) + args.steps) // BS)
        bt[b, :nb] = np.arange(nxt, nxt + nb)
        nxt += nb
    bt = jnp.asarray(bt)
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32) / np.sqrt(D)
    interpret = jax.default_backend() != "tpu"
    lens_steps = [jnp.asarray(lens0 + s, jnp.int32)
                  for s in range(args.steps)]

    def run_single():
        for ln in lens_steps:
            o = flash_decode_paged_single(q, kp, vp, bt, ln,
                                          interpret=interpret)
        return o

    def run_grouped():
        for ln in lens_steps:
            o = flash_decode_paged(q, kp, vp, bt, ln,
                                   kv_tile_blocks=args.tile_blocks,
                                   split_k=args.split_k,
                                   interpret=interpret)
        return o

    # parity first (and compiles both), then strictly alternating rounds
    o_s = np.asarray(jax.block_until_ready(run_single()))
    o_g = np.asarray(jax.block_until_ready(run_grouped()))
    np.testing.assert_allclose(o_g, o_s, atol=1e-5)
    single_s, grouped_s = [], []
    for _ in range(args.repeats):
        t0 = time.time()
        jax.block_until_ready(run_single())
        single_s.append(time.time() - t0)
        t0 = time.time()
        jax.block_until_ready(run_grouped())
        grouped_s.append(time.time() - t0)

    # modeled gather traffic per decoded token, per layer (README math):
    # the per-head kernel walks W blocks once per *query* head, the
    # grouped kernel once per *KV* head over the (tile-padded) table —
    # padded exactly as the kernel wrapper pads it (shared split_layout)
    _, _, _, Wp = split_layout(W, args.tile_blocks, args.split_k)
    itm = np.dtype(np.float32).itemsize
    bytes_single = 2 * Hq * W * BS * D * itm
    bytes_grouped = 2 * Hkv * Wp * BS * D * itm
    return (float(min(single_s)), float(min(grouped_s)),
            {"mode": "compiled-tpu" if not interpret else "pallas-interpret",
             "table_width": int(W), "padded_width": int(Wp),
             "gather_bytes_per_token_per_layer": {
                 "single": int(bytes_single), "grouped": int(bytes_grouped),
                 "ratio": round(bytes_single / bytes_grouped, 3)}})


def _engine_equality(args, rng):
    """Five serving paths (one-shot cold, one-shot cached incl. COW fork
    and rehit, chunked), baseline vs tiled/split grids, bf16 + int8:
    greedy streams must be identical per dtype."""
    import jax
    from repro.models.registry import get_config, model_fns, reduce_config
    from repro.serve import ContinuousEngine

    cfg = reduce_config(get_config(args.arch))
    params = model_fns(cfg).init(jax.random.PRNGKey(0))
    shared = rng.integers(1, cfg.vocab_size, (21,)).astype(np.int32)
    prompts = [np.concatenate(
        [shared, rng.integers(1, cfg.vocab_size, (n,))]).astype(np.int32)
        for n in (13, 30, 7)]

    def serve(**kw):
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                               max_batch=4, max_len=96, **kw)
        hs = [eng.submit(p, 6) for p in prompts]
        res = eng.run()
        return [res[h.req_id].tokens for h in hs], eng

    grid = dict(kv_tile_blocks=args.tile_blocks,
                decode_split_k=args.split_k)
    cow_seen = 0
    for dtype in ("bf16", "int8"):
        kd = dict(kv_dtype=dtype) if dtype == "int8" else {}
        base, _ = serve(**kd)
        cold, _ = serve(prefix_cache=False, **grid, **kd)
        cached, e1 = serve(**grid, **kd)
        chunked, _ = serve(prefill_chunk=16, **grid, **kd)
        assert base == cold == cached == chunked, \
            f"{dtype}: greedy streams diverged across paths/grids"
        cow_seen += e1.metrics.cow_copies
    assert cow_seen >= 2, "COW-fork path was not exercised"
    return True


def main(argv=None) -> float:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--hq", type=int, default=8,
                    help="query heads (kernel-level workload)")
    ap.add_argument("--hkv", type=int, default=2,
                    help="KV heads — hq/hkv is the GQA group whose "
                         "redundant gather the restructure removes")
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--long-blocks", type=int, default=44,
                    help="resident blocks of the long-context request; its "
                         "pow2-bucketed cover is the table width EVERY "
                         "row's lane walks")
    ap.add_argument("--short-blocks-max", type=int, default=8)
    ap.add_argument("--steps", type=int, default=4,
                    help="decode steps timed per round (lengths advance)")
    ap.add_argument("--tile-blocks", type=int, default=4,
                    help="kv_tile_blocks for the grouped kernel")
    ap.add_argument("--split-k", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=2,
                    help="alternating rounds; best-of reported")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_decode.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast mode for CI (asserts kernel parity + "
                         "engine greedy equality; speed reported, not "
                         "gated)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = 2
        args.hq, args.hkv, args.head_dim = 4, 2, 16
        args.long_blocks, args.short_blocks_max = 6, 2
        args.steps, args.repeats = 2, 1
        args.tile_blocks, args.split_k = 2, 2

    rng = np.random.default_rng(args.seed)
    print(f"decode_paged_bench,workload,requests,{args.requests},"
          f"hq,{args.hq},hkv,{args.hkv},long_blocks,{args.long_blocks},"
          f"block_size,{args.block_size},tile_blocks,{args.tile_blocks},"
          f"split_k,{args.split_k}")

    single_s, grouped_s, meta = _time_kernels(args, rng)
    toks = args.requests * args.steps
    ratio = single_s / grouped_s
    print(f"decode_paged_bench,per_head,decode_s,{single_s:.3f},"
          f"tok_s,{toks / single_s:.1f}")
    print(f"decode_paged_bench,grouped,decode_s,{grouped_s:.3f},"
          f"tok_s,{toks / grouped_s:.1f}")
    dma = meta["gather_bytes_per_token_per_layer"]
    print(f"decode_paged_bench,gather_bytes_ratio,{dma['ratio']},"
          f"mode,{meta['mode']}")

    _engine_equality(args, rng)
    print("decode_paged_bench,engine,greedy_equal,1")
    print(f"decode_paged_bench,ratio_per_head_over_grouped,{ratio:.2f}")

    if not args.smoke:
        assert ratio >= 1.5, (
            f"grouped/tiled/split decode speedup {ratio:.2f}x < 1.5x")
        from benchmarks.provenance import provenance
        record = {
            "bench": "decode_paged",
            "provenance": provenance(mode=meta["mode"]),
            "workload": {
                "requests": args.requests, "hq": args.hq, "hkv": args.hkv,
                "head_dim": args.head_dim, "block_size": args.block_size,
                "long_blocks": args.long_blocks,
                "short_blocks_max": args.short_blocks_max,
                "steps": args.steps, "arch": args.arch, "reduced": True},
            "grid": {"kv_tile_blocks": args.tile_blocks,
                     "split_k": args.split_k},
            "measurement": meta,
            "backend": __import__("jax").default_backend(),
            "per_head": {"decode_s": round(single_s, 4),
                         "tok_s": round(toks / single_s, 2)},
            "grouped": {"decode_s": round(grouped_s, 4),
                        "tok_s": round(toks / grouped_s, 2)},
            "ratio_per_head_over_grouped": round(ratio, 3),
            "greedy_equal": True,
        }
        with open(args.out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"decode_paged_bench,wrote,{args.out}")
    return ratio


if __name__ == "__main__":
    main()
