"""Fleet serving: multi-replica scaling, prefix-affinity routing, and
journaled failover under the canned fleet fault plan.

One Poisson multi-tenant twin-prefix workload (``--tenants`` tenants,
each with a shared prompt prefix and ``--per-tenant`` requests arriving
on a seeded exponential clock) driven through three fleet
configurations built from identical ``ContinuousEngine`` replicas:

* single   — one replica, affinity router: the scaling baseline;
* fleet    — ``--replicas`` replicas, affinity router: the scaling and
  prefix-locality measurement (also the fault-free stream reference);
* fleet-rr — same replicas, round-robin router: the routing baseline
  affinity is gated against;
* chaos    — the affinity fleet under ``canned_fleet_plan`` (replica 0
  crashes at the workload midpoint; replica 1 hangs shortly after and
  recovers), with the write-ahead journal attached and the pool/radix
  invariant checker run after every supervision tick.

Gates (the bench fails loudly on any):

* aggregate tokens **per supervision tick** of the fleet >=
  ``--min-scaling`` (default 1.6) x the single replica's. One tick is
  one lockstep round of replica steps — on N devices it costs one step
  time, so tok/tick is the device-parallel throughput model and is
  exactly deterministic (this host timeshares every replica on one
  core, so wall tok/s — reported, not gated — cannot show the scaling);
* the affinity router's fleet-wide prefix-cache hit rate beats
  round-robin's on the same workload;
* under the chaos plan every request completes (``finish_reason
  "length"``), at least one request actually failed over, and every
  greedy stream is byte-identical to the fault-free fleet reference;
* zero invariant violations during the chaos drive and zero leaked
  blocks on every surviving pool after it drains;
* ``journal.replay()`` (in-memory AND from the JSONL file) reconstructs
  every request's tokens and terminal state exactly.

Writes ``BENCH_fleet.json`` (``--out``) with a provenance header, and
the chaos drive's journal as the CI replay artifact (``--journal-out``).

    PYTHONPATH=src:. python benchmarks/fleet_bench.py [--smoke] \
        [--out BENCH_fleet.json] [--journal-out fleet_journal.jsonl]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Tuple

import numpy as np

BLOCK_SIZE = 8
NUM_BLOCKS = 64
MAX_BATCH = 4
PREFIX_LEN = 16                  # two full shared blocks per tenant
TAIL_LEN = 8
HANG_GRACE_TICKS = 2
MAX_TICKS = 20_000               # runaway backstop, not a tuning knob


def make_workload(tenants: int, per_tenant: int, mean_gap: float,
                  vocab: int, seed: int) -> List[Tuple[int, np.ndarray]]:
    """Poisson multi-tenant arrivals: each tenant owns a shared
    ``PREFIX_LEN``-token prefix; its requests are that prefix plus a
    private random tail. Arrival gaps are exponential (mean ``mean_gap``
    supervision ticks) on a seeded RNG, interleaved across tenants in
    arrival order — so prefix affinity has to win against genuinely
    mixed traffic, not conveniently batched tenants. Returns
    ``[(arrival_tick, prompt), ...]`` sorted by arrival."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(1, vocab, (PREFIX_LEN,)).astype(np.int32)
                for _ in range(tenants)]
    arrivals = []
    t = 0.0
    order = rng.permutation(np.repeat(np.arange(tenants), per_tenant))
    for tenant in order:
        t += rng.exponential(mean_gap)
        tail = rng.integers(1, vocab, (TAIL_LEN,)).astype(np.int32)
        arrivals.append((int(t), np.concatenate([prefixes[tenant], tail])))
    return arrivals


def build_engines(cfg, params, n: int, max_new: int) -> List[object]:
    from repro.serve import ContinuousEngine
    engines = []
    for _ in range(n):
        eng = ContinuousEngine(
            cfg, params, block_size=BLOCK_SIZE, num_blocks=NUM_BLOCKS,
            max_batch=MAX_BATCH,
            max_len=PREFIX_LEN + TAIL_LEN + max_new + 2,
            max_admit_per_step=2, retry_backoff_s=0.0)
        eng.warmup()
        engines.append(eng)
    return engines


def drive(engines, arrivals, max_new: int, *, policy: str = "affinity",
          faults=None, journal=None, check_each_tick: bool = False):
    """One full serve of the arrival schedule: submit each request on its
    arrival tick, tick the supervisor until the fleet drains. Returns
    (supervisor, tracked requests, wall seconds)."""
    from repro.serve import FleetSupervisor, Router
    sup = FleetSupervisor(
        engines, router=Router(policy), journal=journal, faults=faults,
        hang_grace_ticks=HANG_GRACE_TICKS,
        check_invariants_each_tick=check_each_tick,
        step_parallel=len(engines) > 1)
    treqs = []
    i = 0
    t0 = time.time()
    while i < len(arrivals) or sup.has_work():
        while i < len(arrivals) and arrivals[i][0] <= sup.ticks:
            treqs.append(sup.submit(arrivals[i][1], max_new))
            i += 1
        sup.tick()
        if sup.ticks > MAX_TICKS:
            raise RuntimeError(f"fleet did not drain in {MAX_TICKS} ticks")
    dt = time.time() - t0
    if sup._pool is not None:          # timed window excludes pool teardown
        sup._pool.shutdown(wait=True)
        sup._pool = None
    return sup, treqs, dt


def hit_rate(sup) -> float:
    """Fleet-wide prefix-cache hit rate: hit tokens over looked-up tokens,
    summed across every replica's radix tree (dead replicas included —
    their pre-crash lookups happened)."""
    hit = total = 0
    for r in sup.replicas:
        cs = r.engine.prefix_cache.stats
        hit += cs.hit_tokens
        total += cs.lookup_tokens
    return hit / total if total else 0.0


def delivered(treqs) -> int:
    return sum(len(t.result.tokens) for t in treqs)


def main(argv=None) -> float:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--per-tenant", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--mean-gap", type=float, default=0.75,
                    help="mean Poisson arrival gap in supervision ticks")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-scaling", type=float, default=1.6,
                    help="gate: fleet tok/tick over single-replica "
                         "tok/tick")
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: smaller workload, same gates")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the BENCH record (provenance + results)")
    ap.add_argument("--journal-out", default=None, metavar="PATH",
                    help="write the chaos drive's write-ahead journal "
                         "(JSONL replay artifact)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.tenants, args.per_tenant, args.max_new = 4, 4, 24

    import jax
    from repro.models.registry import get_config, model_fns, reduce_config
    from repro.serve import (FaultInjector, Journal, canned_fleet_plan,
                             leaked_blocks)
    from repro.serve.supervisor import SERVING

    cfg = reduce_config(get_config(args.arch))
    params = model_fns(cfg).init(jax.random.PRNGKey(0))
    arrivals = make_workload(args.tenants, args.per_tenant, args.mean_gap,
                             cfg.vocab_size, args.seed)
    n_req = len(arrivals)

    engines = build_engines(cfg, params, args.replicas, args.max_new)

    def reset():
        for e in engines:
            e.reset()

    # priming drive: warmup() compiled the jit buckets, but the first
    # serve still pays one-time eager-op compiles that would pollute the
    # reported (informational) wall numbers
    drive(engines, arrivals, args.max_new)
    reset()

    # -- phase 1: scaling, 1 vs N replicas --------------------------------
    sup_1, treqs_1, dt_1 = drive(engines[:1], arrivals, args.max_new)
    engines[0].reset()
    sup_n, treqs_n, dt_n = drive(engines, arrivals, args.max_new)
    toks_1, toks_n = delivered(treqs_1), delivered(treqs_n)
    tpt_1 = toks_1 / sup_1.ticks
    tpt_n = toks_n / sup_n.ticks
    scaling = tpt_n / tpt_1
    ref_streams = [list(t.result.tokens) for t in treqs_n]
    affinity_hits = hit_rate(sup_n)
    print(f"fleet,scaling,replicas,{args.replicas},requests,{n_req},"
          f"tok_per_tick_single,{tpt_1:.2f},tok_per_tick_fleet,{tpt_n:.2f},"
          f"ratio,{scaling:.2f}")
    print(f"fleet,wall_informational,tok_s_single,{toks_1 / dt_1:.1f},"
          f"tok_s_fleet,{toks_n / dt_n:.1f} (single-core host: replicas "
          f"timeshare; the tick ratio above is the device-parallel model)")

    # -- phase 2: affinity vs round-robin routing -------------------------
    reset()
    sup_rr, treqs_rr, _ = drive(engines, arrivals, args.max_new,
                                policy="round-robin")
    rr_hits = hit_rate(sup_rr)
    rr_streams = [list(t.result.tokens) for t in treqs_rr]
    print(f"fleet,routing,affinity_hit_rate,{affinity_hits:.3f},"
          f"round_robin_hit_rate,{rr_hits:.3f}")

    # -- phase 3: chaos — canned fleet plan + journal + invariants --------
    reset()
    mid = max(2, sup_n.ticks // 2)
    plan = canned_fleet_plan(crash_tick=mid, crash_replica=0,
                             hang_tick=mid + 4, hang_ticks=4,
                             hang_replica=min(1, args.replicas - 1))
    journal = Journal(path=args.journal_out)
    sup_c, treqs_c, _ = drive(engines, arrivals, args.max_new,
                              faults=FaultInjector(plan), journal=journal,
                              check_each_tick=True)
    journal.close()
    chaos_streams = [list(t.result.tokens) for t in treqs_c]
    n_failovers = sum(t.n_failovers for t in treqs_c)
    not_ok = [t.rid for t in treqs_c if not t.result.ok]
    mismatched = [i for i, s in enumerate(chaos_streams)
                  if s != ref_streams[i]]
    leaks = {r.name: leaked_blocks(r.engine.pool, r.engine.prefix_cache)
             for r in sup_c.replicas if r.state == SERVING}
    # journal replay (in-memory, and through the JSONL file when written)
    # must reconstruct every terminal state exactly
    replay_sources = [journal.replay()]
    if args.journal_out:
        replay_sources.append(Journal.load(args.journal_out).replay())
    replay_exact = all(
        st.requests[t.rid].tokens == list(t.result.tokens)
        and st.requests[t.rid].finish_reason == t.result.finish_reason
        and st.requests[t.rid].n_failovers == t.n_failovers
        for st in replay_sources for t in treqs_c)
    events = [(e["event"], e["replica"], e["tick"])
              for e in journal.replay().replica_events]
    ttft = sup_c.tracker.h_ttft
    p50, p99 = ttft.quantile(0.5), ttft.quantile(0.99)
    print(f"fleet,chaos,crash_tick,{mid},events,{events},"
          f"failovers,{n_failovers},mismatched,{mismatched},"
          f"not_ok,{not_ok},leaked,{leaks},replay_exact,{replay_exact}")
    print(f"fleet,chaos,ttft_p50_ms,{p50 * 1e3:.2f},"
          f"ttft_p99_ms,{p99 * 1e3:.2f},samples,{ttft.count}")

    failures = []
    if scaling < args.min_scaling:
        failures.append(f"fleet tok/tick scaling {scaling:.2f} < "
                        f"{args.min_scaling}")
    if affinity_hits <= rr_hits:
        failures.append(f"affinity hit rate {affinity_hits:.3f} did not "
                        f"beat round-robin {rr_hits:.3f}")
    if mismatched:
        failures.append(f"chaos streams diverged from fault-free fleet: "
                        f"{mismatched}")
    if rr_streams != ref_streams:
        failures.append("round-robin streams diverged (placement must "
                        "never change greedy tokens)")
    if not_ok:
        failures.append(f"chaos requests did not complete: {not_ok}")
    if n_failovers == 0:
        failures.append("chaos drive failed nothing over (crash plan "
                        "missed the in-flight window?)")
    if any(leaks.values()):
        failures.append(f"leaked blocks on surviving pools: {leaks}")
    if not replay_exact:
        failures.append("journal replay did not reconstruct the tracker")
    if ttft.count != n_req:
        failures.append(f"fleet TTFT sampled {ttft.count} times for "
                        f"{n_req} requests (migration double-count?)")

    if args.out:
        import sys
        sys.path.insert(0, ".")
        from benchmarks.provenance import provenance
        rec = {
            "bench": "fleet",
            "provenance": provenance(
                mode="smoke" if args.smoke else "measured"),
            "workload": {
                "replicas": args.replicas, "tenants": args.tenants,
                "per_tenant": args.per_tenant, "requests": n_req,
                "max_new": args.max_new, "mean_gap_ticks": args.mean_gap,
                "prefix_len": PREFIX_LEN, "tail_len": TAIL_LEN,
                "block_size": BLOCK_SIZE, "num_blocks": NUM_BLOCKS,
                "max_batch": MAX_BATCH, "seed": args.seed},
            "tok_per_tick_single": round(tpt_1, 3),
            "tok_per_tick_fleet": round(tpt_n, 3),
            "scaling_ratio_fleet_over_single": round(scaling, 4),
            "min_scaling_gate": args.min_scaling,
            "wall_tok_s_single_informational": round(toks_1 / dt_1, 1),
            "wall_tok_s_fleet_informational": round(toks_n / dt_n, 1),
            "affinity_hit_rate": round(affinity_hits, 4),
            "round_robin_hit_rate": round(rr_hits, 4),
            "chaos": {
                "crash_tick": mid, "replica_events": events,
                "failovers": n_failovers,
                "replicas_crashed": int(sup_c.c_crashed.value),
                "replicas_hung": int(sup_c.c_hung.value),
                "stream_mismatches": mismatched,
                "incomplete_requests": not_ok,
                "leaked_blocks": leaks,
                "journal_records": len(journal.records),
                "replay_exact": replay_exact,
                "ttft_p50_ms": round(p50 * 1e3, 3),
                "ttft_p99_ms": round(p99 * 1e3, 3)},
            "gates_passed": not failures,
        }
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
            f.write("\n")
        print(f"fleet,record,{args.out}")
    if args.journal_out:
        print(f"fleet,journal,{args.journal_out}")

    if failures:
        raise AssertionError("fleet gates failed: " + "; ".join(failures))
    print(f"fleet,scaling_ratio_fleet_over_single,{scaling:.3f}")
    return scaling


if __name__ == "__main__":
    main()
