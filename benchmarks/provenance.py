"""Shared provenance header for every benchmark artifact.

Every ``BENCH_*.json`` writer stamps its record with ``provenance()`` so a
result file is self-describing: which commit produced it, when, on what
jax/platform, and in which measurement mode. Comparing two artifacts from
different commits (the perf-compare tooling, CI uploads) starts by diffing
this block.
"""
from __future__ import annotations

import platform
import subprocess
import sys
from datetime import datetime, timezone
from typing import Dict, Optional


def git_commit() -> Optional[str]:
    """Current HEAD hash (+ ``-dirty`` suffix), or None outside a repo."""
    try:
        head = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
        return head + ("-dirty" if dirty else "")
    except Exception:
        return None


def provenance(mode: Optional[str] = None) -> Dict[str, object]:
    """The shared artifact header. ``mode`` is the bench's measurement
    mode ("measured" / "smoke" / "interpret" ...), recorded so smoke
    artifacts can't be mistaken for real measurements."""
    import jax

    out: Dict[str, object] = {
        "git_commit": git_commit(),
        "timestamp_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device": jax.devices()[0].device_kind,
        "platform": platform.platform(),
        "python": sys.version.split()[0],
    }
    if mode is not None:
        out["measurement_mode"] = mode
    return out
