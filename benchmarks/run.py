"""Benchmark harness: one entry per paper table/figure + the roofline report.

Prints ``name,...`` CSV lines. Heavy pieces (table3 finetune proxy) accept a
--fast flag used by CI.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    from benchmarks import (fig1_breakdown, fig5_sweep, roofline_report,
                            table1_bitwidth_ablation, table3_accuracy,
                            table4_efficiency)
    from benchmarks.provenance import provenance

    import json
    print("# provenance:",
          json.dumps(provenance(mode="smoke" if args.fast else "measured"),
                     sort_keys=True))

    t0 = time.time()
    print("# Table IV — unit/PE area+energy (analytical 7nm model vs paper)")
    table4_efficiency.main()

    print("# Fig 5 — PE energy vs sequence length (model + measured)")
    fig5_sweep.main()

    print("# Fig 1 — softmax runtime fraction vs sequence length (measured)")
    fig1_breakdown.main()

    print("# Table I ablation — accuracy vs bitwidths (beyond-paper)")
    table1_bitwidth_ablation.main()

    print("# Table III — softermax-aware finetuning accuracy proxy")
    if args.fast:
        print("table3,skipped(fast)")
    else:
        table3_accuracy.main()

    print("# Serving throughput — continuous batching (paged KV) vs static")
    from benchmarks import serve_throughput
    serve_throughput.main(["--fast"] if args.fast else [])

    print("# Prefix cache — radix-tree prompt reuse on the paged pool")
    from benchmarks import prefix_cache_bench
    prefix_cache_bench.main(["--smoke"] if args.fast else [])

    print("# Chunked prefill — flash-prefill kernel vs dense one-shot")
    from benchmarks import prefill_paged_bench
    prefill_paged_bench.main(["--smoke"] if args.fast else [])

    print("# Int8 KV pool — equal-HBM capacity + throughput vs bf16")
    from benchmarks import kv_int8_bench
    kv_int8_bench.main(["--smoke"] if args.fast else [])

    print("# Paged decode — grouped/tiled/split kernel vs per-head walk")
    from benchmarks import decode_paged_bench
    decode_paged_bench.main(["--smoke"] if args.fast else [])

    print("# Autotune — per-step grid planning vs best static schedule")
    from benchmarks import autotune_bench
    autotune_bench.main(["--smoke"] if args.fast else [])

    print("# Resilience — guarded engine under the canned fault plan")
    from benchmarks import resilience_bench
    resilience_bench.main(["--smoke"] if args.fast else [])

    print("# Fleet — multi-replica scaling, affinity routing, failover")
    from benchmarks import fleet_bench
    fleet_bench.main(["--smoke"] if args.fast else [])

    print("# Restore — SIGKILL mid-workload, snapshot warm restart")
    from benchmarks import restore_bench
    restore_bench.main(["--smoke"] if args.fast else [])

    print("# Roofline (baseline sharding) — from dry-run artifacts")
    roofline_report.main()

    import os
    if os.path.isdir("artifacts/dryrun_opt"):
        print("# Roofline (optimized: --optimized sweep, §Perf)")
        os.environ["DRYRUN_ART"] = "artifacts/dryrun_opt"
        import importlib
        importlib.reload(roofline_report)
        roofline_report.main()
        os.environ.pop("DRYRUN_ART")
        importlib.reload(roofline_report)

        print("# Perf comparison (baseline vs optimized, §Perf)")
        from benchmarks import perf_compare
        for mesh in ("16x16", "2x16x16"):
            if os.path.isdir(os.path.join("artifacts/dryrun", mesh)):
                perf_compare.main(mesh)

    print(f"# total_bench_s,{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
