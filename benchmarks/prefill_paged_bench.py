"""Chunked-prefill kernel vs dense one-shot suffix prefill (long documents).

Replays the long-document cached-prefix serving shape — every request is a
long unique document body behind a shared, radix-cache-resident head (RAG /
long-context chat: the head is the system prompt or a shared document, the
body is new text) — through the two prefill paths at an EQUAL pool budget:

* **dense**   — the PR-2 path: ``paged_prefill_suffix`` computes the whole
  uncached remainder as ONE dense attention: the full (suffix, prefix +
  suffix) score matrix is materialized per layer and the prefix KV is
  gathered out of the pool in one piece through the engine's
  pow2-bucketed prefix table (junk columns masked). Quadratic in the
  suffix, with a working set that falls out of cache for long documents.
* **chunked** — this PR: ``paged_prefill_chunked`` walks the same remainder
  in fixed-size chunks through ``kernels/flash_prefill_paged``; each chunk
  scatters its K/V into the pool and attends [cached prefix ‖ earlier
  chunks ‖ itself] through the block table, so no score matrix ever exceeds
  (chunk, prefix + seen) and nothing is gathered-and-concatenated.

Two measurements:

1. **Op-level prefill tok/s** (the headline, asserted ≥ 2× in full mode):
   both paths prefill the identical suffix over the identical resident
   prefix, including their pool scatters, best-of-N over strictly
   alternating rounds (min is the noise-robust estimator on a shared box —
   the true cost shows when the machine is quiet, and alternating rounds
   deny either path a quiet-period advantage). This is exactly the hot
   path the engine dispatches per prefilling request; timing it directly
   keeps decode steps and scheduler noise out of the ratio.
2. **Engine-level greedy equality**: a chunked ``ContinuousEngine`` and a
   one-shot engine serve the same workload at the same pool budget; every
   request's tokens must be identical (and the op-level argmax logits must
   agree dense vs chunked) — the speed is not bought with drift.

Full mode also writes ``BENCH_prefill.json`` (repo root) so later PRs have
a perf trajectory to compare against.

Prints ``prefill_paged_bench,...`` CSV lines, last one the tok/s ratio.

    PYTHONPATH=src python benchmarks/prefill_paged_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np


def make_docs(n: int, shared_len: int, doc_len: int, vocab: int,
              seed: int) -> List[np.ndarray]:
    """Shared head (system prompt / shared document) + long unique body."""
    rng = np.random.default_rng(seed)
    head = rng.integers(1, vocab, (shared_len,))
    return [np.concatenate([head, rng.integers(1, vocab, (doc_len,))]
                           ).astype(np.int32) for _ in range(n)]


def _time_op_paths(cfg, params, prompt, *, shared_len, block_size, chunk,
                   repeats):
    """Prefill ``prompt[shared_len:]`` over a resident prefix through both
    paths, alternating rounds; returns (dense_s, chunked_s, argmax_equal).
    Each round re-scatters into the same pool geometry (equal budget).

    Both paths are driven exactly as ``ContinuousEngine`` dispatches them,
    table-width policies included: the dense path gathers its prefix
    through a pow2-bucketed table (``_prefill_from_offset``), the chunked
    path uses chunk-quantized covers (``_do_prefill_chunk``). Host-side
    input arrays are precomputed symmetrically for both so the timing
    isolates device work."""
    import jax
    import jax.numpy as jnp
    from repro.serve.kv_pool import PagedKVCache
    from repro.serve.paged_step import (paged_prefill, paged_prefill_chunked,
                                        paged_prefill_suffix, scatter_prefill,
                                        scatter_prefill_offset,
                                        table_width_bucket)

    bs = block_size
    S = prompt.shape[0]
    m0 = shared_len
    nb = -(-S // bs)
    pool = PagedKVCache(cfg, num_blocks=nb, block_size=bs)
    table = np.asarray(pool.alloc(0, nb), np.int32)

    jf_full = jax.jit(paged_prefill, static_argnames=("cfg",))
    jf_dense = jax.jit(paged_prefill_suffix, static_argnames=("cfg",))
    jf_chunk = jax.jit(paged_prefill_chunked, static_argnames=("cfg",))
    jf_sc = jax.jit(scatter_prefill)
    jf_sco = jax.jit(scatter_prefill_offset)

    # make the shared head resident once (cold full prefill of the head,
    # right-padded to a block multiple like the engine's cold path)
    mb = -(-m0 // bs) * bs
    head = np.zeros((1, mb), np.int32)
    head[0, :m0] = prompt[:m0]
    _, ks, vs = jf_full(params, jnp.asarray(head),
                        jnp.asarray([m0 - 1], jnp.int32), cfg=cfg)
    pool.k, pool.v = jf_sc(pool.k, pool.v, ks, vs,
                           jnp.asarray(table[:mb // bs], jnp.int32))

    sl = S - m0
    slp = -(-sl // bs) * bs
    toks = np.zeros((1, slp), np.int32)
    toks[0, :sl] = prompt[m0:]
    toks = jnp.asarray(toks)
    pos = m0 + np.arange(slp)
    blk_np = np.where(pos < S, table[np.minimum(pos, S - 1) // bs], 0)
    blk = jnp.asarray(blk_np, jnp.int32)
    off = jnp.asarray(pos % bs, jnp.int32)
    W_pre = -(-m0 // bs)
    wp = table_width_bucket(W_pre)   # dense engine path: pow2 prefix table
    ptd = np.zeros((1, wp), np.int32)
    ptd[0, :W_pre] = table[:W_pre]
    ptd = jnp.asarray(ptd)
    last = jnp.asarray([sl - 1], jnp.int32)
    pos0 = jnp.asarray(m0, jnp.int32)
    m0j = jnp.asarray([m0], jnp.int32)

    cq = chunk // bs
    chunks = []
    m = m0
    while m < S:
        c = min(chunk, S - m)
        ct = np.zeros((1, chunk), np.int32)    # engine pads chunks to C
        ct[0, :c] = prompt[m:m + c]
        cover = min(-(-(m + chunk) // bs), nb)
        w = table_width_bucket(cover, chunk_blocks=cq)  # engine policy
        pt = np.zeros((1, w), np.int32)
        pt[0, :cover] = table[:cover]
        cpos = m + np.arange(chunk)
        cblk = np.where(cpos < S, table[np.minimum(cpos, S - 1) // bs], 0)
        cblk[c:] = 0                 # pad rows -> garbage block 0
        chunks.append((jnp.asarray(ct), jnp.asarray(m, jnp.int32),
                       jnp.asarray([c - 1], jnp.int32), jnp.asarray(pt),
                       jnp.asarray(cblk, jnp.int32),
                       jnp.asarray(cpos % bs, jnp.int32)))
        m += c

    def dense_once():
        t0 = time.time()
        lg, ks, vs = jf_dense(params, toks, pos0, last, pool.k, pool.v,
                              ptd, m0j, cfg=cfg)
        pool.k, pool.v = jf_sco(pool.k, pool.v, ks, vs, blk, off)
        jax.block_until_ready(pool.k)
        return time.time() - t0, lg

    def chunked_once():
        t0 = time.time()
        lg = None
        for ct, p0, lr, pt, bl, of in chunks:
            lg, pool.k, pool.v = jf_chunk(params, ct, p0, lr, pool.k,
                                          pool.v, pt, bl, of, cfg)
        jax.block_until_ready(pool.k)
        return time.time() - t0, lg

    dense_once(), chunked_once()                 # compile both
    dense_s, chunked_s = [], []
    lg_d = lg_c = None
    for _ in range(repeats):
        td, lg_d = dense_once()
        tc, lg_c = chunked_once()
        dense_s.append(td)
        chunked_s.append(tc)
    eq = bool(np.argmax(np.asarray(lg_d)) == np.argmax(np.asarray(lg_c)))
    return float(min(dense_s)), float(min(chunked_s)), eq


def _engine_equality(cfg, params, prompts, *, block_size, num_blocks,
                     max_batch, max_len, max_new, chunk):
    """Serve the workload through a chunked and a one-shot engine at the
    same pool budget; returns (tokens equal, chunked metrics)."""
    from repro.serve import ContinuousEngine
    outs = {}
    eng = None
    for c in (0, chunk):
        eng = ContinuousEngine(cfg, params, block_size=block_size,
                               num_blocks=num_blocks, max_batch=max_batch,
                               max_len=max_len, prefill_chunk=c)
        handles = [eng.submit(p, max_new) for p in prompts]
        results = eng.run()
        outs[c] = [results[h.req_id].tokens for h in handles]
    return outs[0] == outs[chunk], eng.metrics


def main(argv=None) -> float:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--shared-len", type=int, default=576,
                    help="shared head tokens, radix-cache-resident (the "
                         "cached prefix the suffix attends through the "
                         "block table); deliberately not a pow2 block "
                         "count — the dense engine path pow2-buckets its "
                         "prefix gather, and that shipped cost is part of "
                         "what the kernel path removes (with a pow2 head "
                         "the ratio drops ~0.3x but stays >= 2)")
    ap.add_argument("--doc-len", type=int, default=3072,
                    help="unique document-body tokens per request (the "
                         "uncached remainder both paths must prefill; long "
                         "enough that the one-shot score matrix is the "
                         "dominant cost — the regime chunking targets)")
    ap.add_argument("--chunk", type=int, default=256,
                    help="prefill chunk size (tokens)")
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=7,
                    help="alternating op-level rounds; best-of reported")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_prefill.json",
                    help="full mode: write the JSON perf record here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast mode for CI (asserts chunked==dense "
                         "greedy outputs; speed reported, not gated)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = 2
        args.shared_len = 96
        args.doc_len = 256
        args.chunk = 128
        args.repeats = 2

    import jax
    from repro.models.registry import get_config, model_fns, reduce_config
    cfg = reduce_config(get_config(args.arch))
    params = model_fns(cfg).init(jax.random.PRNGKey(0))

    S = args.shared_len + args.doc_len
    prompts = make_docs(args.requests, args.shared_len, args.doc_len,
                        cfg.vocab_size, args.seed)
    print(f"prefill_paged_bench,workload,requests,{args.requests},"
          f"shared,{args.shared_len},doc,{args.doc_len},"
          f"chunk,{args.chunk},block_size,{args.block_size}")

    dense_s, chunked_s, argmax_eq = _time_op_paths(
        cfg, params, prompts[0], shared_len=args.shared_len,
        block_size=args.block_size, chunk=args.chunk, repeats=args.repeats)
    assert argmax_eq, "dense and chunked prefill disagree on the next token"
    sl = args.doc_len
    ratio = dense_s / chunked_s
    print(f"prefill_paged_bench,dense,prefill_s,{dense_s:.3f},"
          f"tok_s,{sl / dense_s:.0f}")
    print(f"prefill_paged_bench,chunked,prefill_s,{chunked_s:.3f},"
          f"tok_s,{sl / chunked_s:.0f}")

    # equal pool budget for both engines: every trajectory + slack
    num_blocks = args.requests * ((S + args.max_new) // args.block_size + 2)
    tokens_eq, metrics = _engine_equality(
        cfg, params, prompts, block_size=args.block_size,
        num_blocks=num_blocks, max_batch=max(2, args.requests // 2),
        max_len=S + args.max_new, max_new=args.max_new, chunk=args.chunk)
    assert tokens_eq, "chunked engine diverged from one-shot engine"
    print(f"prefill_paged_bench,engine,greedy_equal,1,"
          f"prefill_chunks,{metrics.prefill_chunks},"
          f"prefix_hit_tokens,{metrics.prefix_hit_tokens}")
    print(f"prefill_paged_bench,ratio_dense_over_chunked,{ratio:.2f}")

    if not args.smoke:
        assert ratio >= 2.0, (
            f"chunked prefill speedup {ratio:.2f}x < 2.0x")
        from benchmarks.provenance import provenance
        record = {
            "bench": "prefill_paged",
            "provenance": provenance(mode="measured"),
            "workload": {"requests": args.requests,
                         "shared_len": args.shared_len,
                         "doc_len": args.doc_len, "chunk": args.chunk,
                         "block_size": args.block_size,
                         "arch": args.arch, "reduced": True},
            "backend": jax.default_backend(),
            "dense": {"prefill_s": round(dense_s, 4),
                      "tok_s": round(sl / dense_s, 1)},
            "chunked": {"prefill_s": round(chunked_s, 4),
                        "tok_s": round(sl / chunked_s, 1)},
            "ratio_dense_over_chunked": round(ratio, 3),
            "greedy_equal": True,
        }
        with open(args.out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"prefill_paged_bench,wrote,{args.out}")
    return ratio


if __name__ == "__main__":
    main()
