"""Resilience under injected faults: guarded vs unguarded vs fault-free.

Three drives of the SAME two-wave shared-prefix workload through identical
``ContinuousEngine`` configurations:

* fault-free — no injector, no guard: the throughput and greedy-stream
  baseline;
* guarded    — the canned fault plan (``serve/faults.py``: KV corruption,
  admission stalls, pool pressure, transient step faults, stalls,
  preemption storms, numerics spikes) with the ``EngineGuard`` degradation
  ladder attached;
* unguarded  — the identical storm with no guard: demonstrates WHY the
  guard exists (the corrupted-KV request's greedy stream silently
  diverges, and its poisoned prompt blocks stay published in the prefix
  cache).

Gates (the bench fails loudly on any):

* guarded tok/s >= ``--min-ratio`` (default 0.70) of fault-free tok/s
  (best-of ``--repeats`` paired rounds);
* ``check_invariants`` (pool/radix refcount contract) passes after EVERY
  step of both faulted drives, and ``leaked_blocks`` is 0 after each
  drive drains;
* every non-quarantined request of the guarded drive streams
  byte-identical greedy tokens to the fault-free drive;
* the unguarded drive diverges on at least one request (the corruption is
  real, and only the guard's scatter-readback audit catches it).

Writes ``BENCH_resilience.json`` (``--out``) with a provenance header and
the fault-injection replay artifact (``--fault-log``).

    PYTHONPATH=src:. python benchmarks/resilience_bench.py [--smoke] \
        [--out BENCH_resilience.json] [--fault-log resilience_faults.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

import numpy as np

BLOCK_SIZE = 8
NUM_BLOCKS = 80
MAX_BATCH = 6
PREFIX_LEN = 8                   # one full shared block per twin pair
TAIL_LEN = 6
MAX_STEPS = 500                  # runaway backstop, not a tuning knob


def make_workload(n_pairs: int, vocab: int, seed: int) -> List[np.ndarray]:
    """Two waves of ``n_pairs`` prompts; wave-2 prompt i shares its first
    ``PREFIX_LEN`` tokens (exactly one block) with wave-1 prompt i and
    nothing with any other pair — so a poisoned block published by one
    request is re-served to exactly one known successor."""
    rng = np.random.default_rng(seed)
    wave1, wave2 = [], []
    for _ in range(n_pairs):
        pre = rng.integers(1, vocab, (PREFIX_LEN,)).astype(np.int32)
        for wave in (wave1, wave2):
            tail = rng.integers(1, vocab, (TAIL_LEN,)).astype(np.int32)
            wave.append(np.concatenate([pre, tail]))
    return wave1 + wave2


def build_engine(cfg, params, *, max_new: int, guard=None,
                 telemetry=None):
    from repro.serve import ContinuousEngine
    eng = ContinuousEngine(
        cfg, params, block_size=BLOCK_SIZE, num_blocks=NUM_BLOCKS,
        max_batch=MAX_BATCH, max_len=PREFIX_LEN + TAIL_LEN + max_new + 2,
        max_admit_per_step=2, guard=guard, telemetry=telemetry,
        retry_backoff_s=0.002)
    eng.warmup()
    return eng


def drive(eng, prompts: List[np.ndarray], max_new: int,
          check_each_step: bool = False):
    """One full serve of the workload. Token streams and finish reasons
    come back indexed by WORKLOAD position (req_ids are engine-lifetime
    monotonic — warmup and earlier rounds consume them — so they can't be
    compared across engines). Returns (streams, reasons, wall seconds,
    invariant checks run, delivered tokens)."""
    from repro.serve.invariants import check_invariants, leaked_blocks
    handles = [eng.submit(p, max_new) for p in prompts]
    checks = 0
    t0 = time.time()
    steps = 0
    while eng.sched.has_work():
        eng.step()
        steps += 1
        if check_each_step:
            check_invariants(eng.pool, eng.prefix_cache)
            checks += 1
        if steps > MAX_STEPS:
            raise RuntimeError(f"drive did not converge in {MAX_STEPS} "
                               f"steps (guard stuck?)")
    eng.drain()
    dt = time.time() - t0
    done = eng.pop_finished()
    toks = [list(done[h.req_id].tokens) if h.req_id in done else None
            for h in handles]
    reasons = [done[h.req_id].finish_reason if h.req_id in done else ""
               for h in handles]
    assert leaked_blocks(eng.pool, eng.prefix_cache) == 0, \
        "blocks leaked after drain"
    delivered = sum(len(t) for t in toks if t)
    return toks, reasons, dt, checks, delivered


def main(argv=None) -> float:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--pairs", type=int, default=8,
                    help="twin prompt pairs (2x this many requests)")
    ap.add_argument("--max-new", type=int, default=96,
                    help="tokens per request; large enough that the "
                         "plan's fixed costs (stalls, retry backoff, "
                         "readback audits) amortize the way they would "
                         "on a real serving window")
    ap.add_argument("--repeats", type=int, default=3,
                    help="paired (fault-free, guarded) timing rounds; the "
                         "reported ratio is the best round (absorbs host "
                         "noise at smoke scale)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-ratio", type=float, default=0.70,
                    help="gate: guarded tok/s as a fraction of fault-free")
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: fewer timing rounds, same gates")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the BENCH record (provenance + results)")
    ap.add_argument("--fault-log", default=None, metavar="PATH",
                    help="write the guarded drive's fault-injection replay "
                         "artifact")
    args = ap.parse_args(argv)
    if args.smoke:
        args.repeats = 2

    import jax
    from repro.models.registry import get_config, model_fns, reduce_config
    from repro.serve import EngineGuard, FaultInjector, canned_plan

    cfg = reduce_config(get_config(args.arch))
    params = model_fns(cfg).init(jax.random.PRNGKey(0))
    prompts = make_workload(args.pairs, cfg.vocab_size, args.seed)

    # three engines, one workload; warmup compiles are excluded from every
    # timed window, and each engine reset()s between rounds so round N+1
    # starts from the same cold cache/pool state as round 1
    eng_base = build_engine(cfg, params, max_new=args.max_new)
    eng_guard = build_engine(cfg, params, max_new=args.max_new,
                             guard=EngineGuard())
    inj_guard = FaultInjector(canned_plan())
    eng_guard.attach_faults(inj_guard)        # after warmup: plan steps
    #                                           address serving steps
    eng_plain = build_engine(cfg, params, max_new=args.max_new)
    inj_plain = FaultInjector(canned_plan())
    eng_plain.attach_faults(inj_plain)

    # priming drive per engine: warmup() compiles the jit buckets, but
    # the first serve still pays one-time eager-op compiles (suffix
    # shapes, readback audit, host converts) that would pollute round 0's
    # timed window
    for eng in (eng_base, eng_guard):
        drive(eng, prompts, args.max_new)
        eng.reset()

    base_toks: List[Optional[List[int]]] = []
    ratios = []
    tok_s_base = tok_s_guard = 0.0
    # timed rounds: NO per-step invariant checking inside the windows (the
    # checker is O(pool) host work the fault-free engine doesn't pay; a
    # dedicated verification drive below runs it every step, untimed)
    for r in range(args.repeats):
        if r > 0:
            eng_base.reset()
            eng_guard.reset()
        base_toks, _, dt_b, _, n_b = drive(eng_base, prompts, args.max_new)
        _, _, dt_g, _, n_g = drive(eng_guard, prompts, args.max_new)
        tok_s_base, tok_s_guard = n_b / dt_b, n_g / dt_g
        ratios.append(tok_s_guard / tok_s_base)
        print(f"resilience,round,{r},tok_s_fault_free,{tok_s_base:.1f},"
              f"tok_s_guarded,{tok_s_guard:.1f},"
              f"ratio,{ratios[-1]:.3f}")
    ratio = float(max(ratios))

    # verification drive: the identical storm once more (injector resets
    # with the engine, so it replays bit-for-bit) with the invariant
    # checker after EVERY step. Also the correctness read: the corrupted
    # request was quarantined, and every OTHER request's greedy stream is
    # byte-equal to the fault-free run (the storm cost throughput, never
    # tokens)
    eng_guard.reset()
    guard_toks, guard_reasons, _, checks_total, _ = drive(
        eng_guard, prompts, args.max_new, check_each_step=True)
    victims = sorted(i for i, why in enumerate(guard_reasons)
                     if why == "quarantined")
    mismatched = sorted(
        i for i, t in enumerate(guard_toks)
        if i not in victims and t != base_toks[i])
    m = eng_guard.metrics
    print(f"resilience,guarded,faults,{m.faults_injected},"
          f"retries,{m.transient_retries},quarantined,{m.quarantined},"
          f"preemptions,{m.preemptions},"
          f"guard_transitions,{len(eng_guard.guard.transitions)},"
          f"invariant_checks,{checks_total}")
    print(f"resilience,guarded,victims,{victims},"
          f"nonvictim_mismatches,{mismatched}")

    # the unguarded drive demonstrates the failure the guard prevents:
    # same storm, no audit — the corruption lands and SOME greedy stream
    # silently diverges from the fault-free run
    plain_toks, _, _, checks_p, _ = drive(
        eng_plain, prompts, args.max_new, check_each_step=True)
    divergent = sorted(i for i, t in enumerate(plain_toks)
                       if t != base_toks[i])
    corrupted = inj_plain.corrupted_req_ids()
    print(f"resilience,unguarded,corrupted_req_ids,{corrupted},"
          f"divergent_indices,{divergent}")

    failures = []
    if ratio < args.min_ratio:
        failures.append(f"guarded tok/s ratio {ratio:.3f} < "
                        f"{args.min_ratio}")
    if mismatched:
        failures.append(f"guarded non-victim streams diverged: "
                        f"{mismatched}")
    if not victims:
        failures.append("guarded drive quarantined nothing (kv_corrupt "
                        "missed or audit failed)")
    if not divergent:
        failures.append("unguarded drive did not diverge (the injected "
                        "corruption had no effect?)")

    if args.fault_log:
        inj_guard.save_log(args.fault_log)
        print(f"resilience,fault_log,{args.fault_log}")
    if args.out:
        import sys
        sys.path.insert(0, ".")
        from benchmarks.provenance import provenance
        rec = {
            "bench": "resilience",
            "provenance": provenance(
                mode="smoke" if args.smoke else "measured"),
            "workload": {"pairs": args.pairs, "max_new": args.max_new,
                         "prefix_len": PREFIX_LEN, "tail_len": TAIL_LEN,
                         "block_size": BLOCK_SIZE,
                         "num_blocks": NUM_BLOCKS,
                         "max_batch": MAX_BATCH, "seed": args.seed},
            "tok_s_fault_free": round(tok_s_base, 2),
            "tok_s_guarded": round(tok_s_guard, 2),
            "tok_s_ratio_guarded_over_fault_free": round(ratio, 4),
            "min_ratio_gate": args.min_ratio,
            "faults_injected": m.faults_injected,
            "transient_retries": m.transient_retries,
            "quarantined_indices": victims,
            "guard_transitions": eng_guard.guard.transitions,
            "invariant_checks": checks_total + checks_p,
            "invariant_violations": 0,
            "leaked_blocks": 0,
            "guarded_nonvictim_mismatches": mismatched,
            "unguarded_corrupted_req_ids": corrupted,
            "unguarded_divergent_indices": divergent,
            "gates_passed": not failures,
        }
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
            f.write("\n")
        print(f"resilience,record,{args.out}")

    if failures:
        raise AssertionError("resilience gates failed: " +
                             "; ".join(failures))
    print(f"resilience,ratio_guarded_over_fault_free,{ratio:.3f}")
    return ratio


if __name__ == "__main__":
    main()
