"""Provenance-aware bench regression gate: fresh smoke runs vs committed
baselines, same measurement mode only.

The repo carries two kinds of bench truth. The ``BENCH_*.json`` records
are FULL-workload snapshots (committed by the PR that landed each
optimization) — authoritative but expensive, and some were produced under
a different measurement mode (Pallas interpreter, analytic model, wall
clock) than whatever machine is running CI today. Comparing a fresh
*smoke* number against a committed *full* number, or an interpreter
number against a compiled one, is apples-to-oranges; that is exactly the
trap this gate refuses.

So the gate keeps its own committed baseline store, ``BENCH_trajectory.json``
(repo root): one entry per bench, recorded at SMOKE scale with an explicit
``(measurement_mode, scale)`` stamp via ``--record``. ``--check`` reruns
every bench at the recorded scale and fails on a >10% regression
(``--threshold``) **only when the fresh run's mode and scale match the
baseline's** — a mode mismatch (e.g. baseline recorded under the
interpreter, CI suddenly on TPU) demotes the entry to report-only rather
than producing a bogus verdict. Timing-kind entries get up to
``--retries`` reruns before a regression verdict sticks (smoke-scale wall
clock on shared CI runners is noisy; deterministic entries — modeled byte
ratios, token-count savings — get no such slack). The committed full
``BENCH_*.json`` headlines are cross-referenced into the report for
trend-reading but never gated across modes/scales.

    PYTHONPATH=src:. python benchmarks/bench_trajectory.py --record
    PYTHONPATH=src:. python benchmarks/bench_trajectory.py --check \
        --report trajectory_report.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _mode_backend(prefix: str) -> str:
    import jax
    return f"{prefix}-{jax.default_backend()}"


def _mode_kernels() -> str:
    import jax
    return ("compiled-tpu" if jax.default_backend() == "tpu"
            else "pallas-interpret")


# Each entry: how to run the bench at smoke scale (returns its headline
# float), the measurement mode that number was produced under, whether it
# is wall-clock ("timing") or derived from counts/models ("deterministic"),
# and the committed full record to cross-reference (file, key) if any.
def _entries():
    from benchmarks import (autotune_bench, decode_paged_bench, fleet_bench,
                            kv_int8_bench, prefill_paged_bench,
                            prefix_cache_bench, resilience_bench,
                            restore_bench, serve_throughput)
    return {
        "decode_paged": {
            "run": lambda: decode_paged_bench.main(["--smoke"]),
            "metric": "ratio_per_head_over_grouped",
            "mode": _mode_kernels, "kind": "timing",
            "full": ("BENCH_decode.json", "ratio_per_head_over_grouped")},
        "prefill_paged": {
            "run": lambda: prefill_paged_bench.main(["--smoke"]),
            "metric": "ratio_dense_over_chunked",
            "mode": lambda: _mode_backend("measured"), "kind": "timing",
            "full": ("BENCH_prefill.json", "ratio_dense_over_chunked")},
        "kv_int8": {
            "run": lambda: kv_int8_bench.main(["--smoke"]),
            "metric": "tok_s_ratio_int8_over_bf16",
            "mode": lambda: _mode_backend("measured"), "kind": "timing",
            "full": ("BENCH_kv_int8.json", "tok_s_ratio_int8_over_bf16")},
        "prefix_cache": {
            "run": lambda: prefix_cache_bench.main(["--smoke"]),
            "metric": "ratio_cached_over_cold",
            "mode": lambda: _mode_backend("measured"), "kind": "timing",
            "full": None},
        "serve_throughput": {
            "run": lambda: serve_throughput.main(["--fast"]),
            "metric": "tok_s_ratio_paged_over_static",
            "mode": lambda: _mode_backend("measured"), "kind": "timing",
            "full": None},
        "autotune": {
            "run": lambda: autotune_bench.main(["--smoke"]),
            "metric": "ratio_best_static_over_per_step",
            "mode": lambda: "analytic-cost-model",
            "kind": "deterministic",
            "full": ("BENCH_autotune.json",
                     "ratio_best_static_over_per_step")},
        "resilience": {
            "run": lambda: resilience_bench.main(["--smoke"]),
            "metric": "tok_s_ratio_guarded_over_fault_free",
            "mode": lambda: _mode_backend("measured"), "kind": "timing",
            "full": ("BENCH_resilience.json",
                     "tok_s_ratio_guarded_over_fault_free")},
        "fleet": {
            # tokens per supervision tick (the lockstep device-parallel
            # throughput model) — tick counts are deterministic, so no
            # timing-noise retries apply
            "run": lambda: fleet_bench.main(["--smoke"]),
            "metric": "scaling_ratio_fleet_over_single",
            "mode": lambda: "tick-model", "kind": "deterministic",
            "full": ("BENCH_fleet.json",
                     "scaling_ratio_fleet_over_single")},
        "restore": {
            # prefill tokens a cold restart recomputes per token the warm
            # (snapshot-restored radix tree) restart computes — token
            # counts are deterministic, so no timing-noise retries apply
            "run": lambda: restore_bench.main(["--smoke"]),
            "metric": "cold_over_warm_prefill_tokens",
            "mode": lambda: "token-count", "kind": "deterministic",
            "full": ("BENCH_restore.json",
                     "cold_over_warm_prefill_tokens")},
    }


def _run_entry(name, ent):
    print(f"# bench_trajectory: running {name} (smoke)")
    return float(ent["run"]())


def record(args) -> int:
    from benchmarks.provenance import provenance
    # --only re-records a subset IN PLACE: untouched entries survive with
    # their original values (a full rewrite would silently drop every
    # baseline the restricted run skipped)
    entries = {}
    if args.only and os.path.exists(args.baseline):
        with open(args.baseline) as f:
            entries = json.load(f).get("entries", {})
    for name, ent in _entries().items():
        if args.only and name not in args.only:
            continue
        entries[name] = {
            "metric": ent["metric"], "value": round(_run_entry(name, ent), 4),
            "measurement_mode": ent["mode"](), "scale": "smoke",
            "kind": ent["kind"], "direction": "higher"}
    rec = {"bench": "trajectory-baselines",
           "provenance": provenance(mode="smoke"), "entries": entries}
    with open(args.baseline, "w") as f:
        json.dump(rec, f, indent=2)
        f.write("\n")
    print(f"bench_trajectory,recorded,{len(entries)},{args.baseline}")
    return 0


def check(args) -> int:
    if not os.path.exists(args.baseline):
        print(f"bench_trajectory,error,no baseline {args.baseline} "
              f"(run --record and commit it)")
        return 2
    with open(args.baseline) as f:
        base = json.load(f)
    report = {"baseline": args.baseline,
              "baseline_provenance": base.get("provenance", {}),
              "threshold": args.threshold, "entries": {},
              "full_records": {}}
    failures = []
    for name, ent in _entries().items():
        if args.only and name not in args.only:
            continue
        b = base.get("entries", {}).get(name)
        fresh_mode = ent["mode"]()
        row = {"metric": ent["metric"], "kind": ent["kind"],
               "fresh_mode": fresh_mode, "scale": "smoke"}
        if b is None:
            # new bench with no recorded baseline: report-only, the next
            # --record picks it up
            row.update(status="no-baseline",
                       fresh=round(_run_entry(name, ent), 4))
            report["entries"][name] = row
            continue
        row["baseline"] = b["value"]
        row["baseline_mode"] = b["measurement_mode"]
        if b["measurement_mode"] != fresh_mode or b.get("scale") != "smoke":
            # provenance mismatch: a verdict here would compare different
            # instruments — surface, don't gate
            row.update(status="mode-mismatch-not-gated",
                       fresh=round(_run_entry(name, ent), 4))
            report["entries"][name] = row
            continue
        tries = 1 + (args.retries if ent["kind"] == "timing" else 0)
        best, fresh = -float("inf"), 0.0
        for i in range(tries):
            fresh = _run_entry(name, ent)
            best = max(best, fresh)
            reg = (b["value"] - best) / b["value"] if b["value"] else 0.0
            if reg <= args.threshold:
                break
            if i + 1 < tries:
                print(f"# bench_trajectory: {name} regressed "
                      f"{reg * 100:.1f}% — retrying ({i + 1}/{tries - 1})")
        reg = (b["value"] - best) / b["value"] if b["value"] else 0.0
        row.update(fresh=round(best, 4), regression=round(reg, 4),
                   status="ok" if reg <= args.threshold else "REGRESSED")
        if reg > args.threshold:
            failures.append(
                f"{name}: {ent['metric']} {best:.4f} vs baseline "
                f"{b['value']:.4f} (-{reg * 100:.1f}%, mode {fresh_mode})")
        report["entries"][name] = row

    # cross-reference the committed full-workload records (never gated:
    # different scale by construction, often different mode)
    for name, ent in _entries().items():
        if not ent["full"]:
            continue
        path, key = ent["full"]
        if os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
            report["full_records"][name] = {
                "file": path, "metric": key, "value": rec.get(key),
                "measurement_mode": rec.get("provenance", {}).get(
                    "measurement_mode"), "scale": "full", "gated": False}

    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        print(f"bench_trajectory,report,{args.report}")
    for name, row in report["entries"].items():
        print(f"bench_trajectory,{name},{row['metric']},"
              f"fresh,{row.get('fresh', 'n/a')},baseline,"
              f"{row.get('baseline', 'n/a')},status,{row['status']}")
    if failures:
        print("bench_trajectory,FAIL," + "; ".join(failures))
        return 1
    print("bench_trajectory,ok,no same-mode regressions "
          f"> {args.threshold * 100:.0f}%")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--record", action="store_true",
                   help="run every bench at smoke scale and (re)write the "
                        "baseline store")
    g.add_argument("--check", action="store_true",
                   help="rerun at the recorded scale and fail on same-mode "
                        "regressions beyond --threshold")
    ap.add_argument("--baseline", default="BENCH_trajectory.json")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="--check: write the full comparison (incl. the "
                         "non-gated full-record cross-reference) as JSON")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="max tolerated same-mode fractional regression")
    ap.add_argument("--retries", type=int, default=2,
                    help="extra reruns granted to TIMING benches before a "
                         "regression verdict sticks (deterministic "
                         "benches get none)")
    ap.add_argument("--only", nargs="*", default=None,
                    help="restrict to these bench names")
    args = ap.parse_args(argv)
    return record(args) if args.record else check(args)


if __name__ == "__main__":
    sys.exit(main())
