"""Beyond-paper ablation of the Table-I bitwidths.

The paper fixes one operating point (Inp Q(6,2), Unnormed Q(1,15), PowSum
Q(10,6), Recip/Outp Q(1,7)). This sweep varies the output/reciprocal and
unnormed precisions and reports softmax error vs the exact base-2 softmax —
the accuracy-per-bit curve a hardware team would use to re-cost the units
(each dropped bit shrinks the Normalization Unit datapath linearly).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import quant
import repro.core.softermax as sm


def run(rows=256, V=384, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, V)) * 4, jnp.float32)
    exact = sm.softmax_base2(x)
    out = []
    for out_frac in (4, 5, 6, 7, 8, 10):
        for un_frac in (7, 11, 15):
            bw = quant.SoftermaxBitwidths(
                unnormed=quant.QFormat(1, un_frac, signed=False),
                recip=quant.QFormat(1, out_frac, signed=False),
                outp=quant.QFormat(1, out_frac, signed=False),
            )
            y = sm.softermax_fixed(x, bitwidths=bw)
            err = float(jnp.abs(y - exact).max())
            mean_err = float(jnp.abs(y - exact).mean())
            out.append({
                "out_bits": 1 + out_frac, "unnormed_bits": 1 + un_frac,
                "max_err": err, "mean_err": mean_err,
            })
    return out


def main():
    for r in run():
        print(f"table1_ablation,out_bits={r['out_bits']},"
              f"unnormed_bits={r['unnormed_bits']},"
              f"max_err={r['max_err']:.5f},mean_err={r['mean_err']:.6f}")


if __name__ == "__main__":
    main()
