"""Radix prefix cache: shared-system-prompt workload, cache on vs off.

Replays the canonical chat/few-shot serving shape — every request carries
the same system prompt + few-shot exemplars (``--shared-len`` tokens) and a
short unique user turn (``--unique-len``) — through ``ContinuousEngine``
twice at an EQUAL pool budget:

* **off** — every prompt prefills end to end (the PR-1 baseline);
* **on**  — the radix tree shares the prefix blocks: after the first
  admissions publish the prefix, each later request splices it by reference
  and prefills only its unique suffix from the first uncached offset.

Rounds are interleaved (both engines sample the same host-noise windows)
and repeated; the cache-on engine keeps its tree across rounds, so steady
state (every prefix resident) is what the median measures. Reported and
asserted, full mode:

* prefill-token savings  = computed-prefill-tokens(off) / (on)  >= 1.8x
* end-to-end throughput  = tok/s(on) / tok/s(off)               >= 1.3x
* greedy outputs identical per request, cache on vs off, every round.

``--smoke`` shrinks the workload (tiny reduced model, few requests, 2
rounds) so the whole bench runs in seconds under the tier-1 ``slow``
pytest marker; it still asserts savings and equality but only reports
throughput (CI boxes are too noisy to gate on a small-run ratio).

Prints ``prefix_cache_bench,...`` CSV lines, last one the tok/s ratio.

    PYTHONPATH=src python benchmarks/prefix_cache_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np


def make_prompts(n: int, shared_len: int, unique_len: int, vocab: int,
                 seed: int) -> List[np.ndarray]:
    """System prompt + few-shot block shared verbatim; user turn unique."""
    rng = np.random.default_rng(seed)
    system = rng.integers(1, vocab, (shared_len,))
    return [np.concatenate([system, rng.integers(1, vocab, (unique_len,))]
                           ).astype(np.int32) for _ in range(n)]


def make_driver(cfg, params, prompts, *, prefix_cache, block_size,
                num_blocks, max_batch, max_len, max_new):
    """Build one warmed engine; drive() replays the workload once and
    returns (per-request token lists, delivered tokens, elapsed seconds,
    prefill tokens computed this round)."""
    from repro.serve import ContinuousEngine
    eng = ContinuousEngine(cfg, params, block_size=block_size,
                           num_blocks=num_blocks, max_batch=max_batch,
                           max_len=max_len, prefix_cache=prefix_cache)
    eng.warmup()

    def drive():
        computed0 = eng.metrics.prefill_tokens
        t0 = time.time()
        handles = [eng.submit(p, max_new) for p in prompts]
        results = eng.run()
        elapsed = time.time() - t0
        toks: Dict[int, List[int]] = {
            i: results[h.req_id].tokens for i, h in enumerate(handles)}
        delivered = sum(len(t) for t in toks.values())
        return toks, delivered, elapsed, \
            eng.metrics.prefill_tokens - computed0

    return eng, drive


def main(argv=None) -> float:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--shared-len", type=int, default=480,
                    help="system-prompt + few-shot tokens shared by every "
                         "request (long enough that prefill is "
                         "compute-bound, the regime the cache targets)")
    ap.add_argument("--unique-len", type=int, default=16,
                    help="unique user-turn tokens per request")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--num-blocks", type=int, default=160)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=5,
                    help="interleaved rounds; medians reported")
    ap.add_argument("--evict-policy", choices=("lru", "fifo"), default="lru")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast mode for CI (asserts savings + "
                         "equality; throughput reported, not gated)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = 8
        args.shared_len = 224
        args.unique_len = 16
        args.max_new = 4
        args.num_blocks = 80
        args.repeats = 2

    import jax
    from repro.models.registry import get_config, model_fns, reduce_config
    cfg = reduce_config(get_config(args.arch))
    params = model_fns(cfg).init(jax.random.PRNGKey(0))

    plen = args.shared_len + args.unique_len
    max_len = plen + args.max_new
    prompts = make_prompts(args.requests, args.shared_len, args.unique_len,
                           cfg.vocab_size, args.seed)
    print(f"prefix_cache_bench,workload,requests,{args.requests},"
          f"shared,{args.shared_len},unique,{args.unique_len},"
          f"max_new,{args.max_new},budget_blocks,{args.num_blocks}")

    common = dict(block_size=args.block_size, num_blocks=args.num_blocks,
                  max_batch=args.max_batch, max_len=max_len,
                  max_new=args.max_new)
    eng_on, drive_on = make_driver(cfg, params, prompts, prefix_cache=True,
                                   **common)
    _, drive_off = make_driver(cfg, params, prompts, prefix_cache=False,
                               **common)

    on_tok_s, off_tok_s, on_computed, off_computed = [], [], [], []
    for rnd in range(args.repeats):
        toks_off, d_off, e_off, c_off = drive_off()
        toks_on, d_on, e_on, c_on = drive_on()
        assert toks_on == toks_off, (
            f"round {rnd}: cached greedy decode diverged from cold")
        off_tok_s.append(d_off / e_off)
        on_tok_s.append(d_on / e_on)
        off_computed.append(c_off)
        on_computed.append(c_on)

    # steady state: every round after the first finds the prefix resident;
    # medians absorb the cold round and host noise
    savings = float(np.median(off_computed) / np.median(on_computed))
    ratio = float(np.median(on_tok_s) / np.median(off_tok_s))
    cs = eng_on.prefix_cache.stats
    m = eng_on.metrics
    print(f"prefix_cache_bench,off,tok_s,{np.median(off_tok_s):.2f},"
          f"prefill_tokens_per_round,{np.median(off_computed):.0f}")
    print(f"prefix_cache_bench,on,tok_s,{np.median(on_tok_s):.2f},"
          f"prefill_tokens_per_round,{np.median(on_computed):.0f},"
          f"hit_tokens,{cs.hit_tokens},evictions,{cs.evictions},"
          f"cow_copies,{m.cow_copies},shared_blocks_peak,"
          f"{m.shared_blocks_peak}")
    print(f"prefix_cache_bench,prefill_savings,{savings:.2f}")
    print(f"prefix_cache_bench,ratio_cached_over_cold,{ratio:.2f}")

    assert savings >= 1.8, (
        f"prefill-token savings {savings:.2f}x < 1.8x")
    if not args.smoke:
        assert ratio >= 1.3, f"tok/s ratio {ratio:.2f}x < 1.3x"
    return ratio


if __name__ == "__main__":
    main()
