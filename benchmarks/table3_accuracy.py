"""Table III analogue: Softermax-aware finetuning recovers accuracy.

The paper finetunes BERT on GLUE/SQuAD with softermax and reports parity
with the quantized baseline. Offline, we run the scaled proxy: pretrain a
small BERT-family transformer with standard softmax on the synthetic LM
task, then "finetune" three variants — standard softmax, softermax (float),
and softermax_fixed (bit-faithful Table-I fixed point with STE) — and report
final eval losses. The claim checked: softermax variants land within noise
of the baseline (paper: <0.5% worst-case drop; average ~0 or better).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.data import SyntheticLMData
from repro.models.registry import get_config, model_fns, reduce_config
from repro.train import make_train_step

SEQ, BATCH = 64, 16


def _eval_loss(fns, params, cfg, n=4, seed=77):
    data = SyntheticLMData(cfg.vocab_size, SEQ, BATCH, seed=seed)
    tot = 0.0
    for _ in range(n):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        loss, _ = fns.loss(params, batch)
        tot += float(loss)
    return tot / n


def run(pretrain_steps=60, finetune_steps=40):
    base_cfg = reduce_config(get_config("bert-base")).replace(
        causal=True,                       # LM proxy task
        softmax_impl="softmax")
    fns = model_fns(base_cfg)
    params = fns.init(jax.random.PRNGKey(0))
    tc = TrainConfig(total_steps=pretrain_steps, warmup_steps=5,
                     learning_rate=3e-3)
    step = jax.jit(make_train_step(fns.loss, tc))
    data = SyntheticLMData(base_cfg.vocab_size, SEQ, BATCH, seed=1)
    from repro.optim import adamw
    opt = adamw.init_state(params)
    for _ in range(pretrain_steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, _ = step(params, opt, batch)

    results = {}
    for impl in ("softmax", "softermax", "softermax_fixed"):
        cfg_i = base_cfg.replace(softmax_impl=impl)
        fns_i = model_fns(cfg_i)
        tc_f = TrainConfig(total_steps=finetune_steps, warmup_steps=2,
                           learning_rate=1e-3)
        step_i = jax.jit(make_train_step(fns_i.loss, tc_f))
        p_i, o_i = params, adamw.init_state(params)
        ft_data = SyntheticLMData(base_cfg.vocab_size, SEQ, BATCH, seed=2)
        for _ in range(finetune_steps):
            batch = {k: jnp.asarray(v) for k, v in next(ft_data).items()}
            p_i, o_i, _ = step_i(p_i, o_i, batch)
        results[impl] = _eval_loss(fns_i, p_i, cfg_i)
    # zero-shot drop-in (no softermax-aware finetuning) for contrast
    cfg_z = base_cfg.replace(softmax_impl="softermax_fixed")
    results["softermax_fixed_no_finetune"] = _eval_loss(
        model_fns(cfg_z), params, cfg_z)
    return results


def main():
    r = run()
    base = r["softmax"]
    for k, v in r.items():
        print(f"table3,{k},{v:.4f},delta_vs_baseline={v - base:+.4f}")


if __name__ == "__main__":
    main()
