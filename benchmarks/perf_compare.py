"""Baseline vs optimized roofline comparison across the full 40-cell grid.

Reads artifacts/dryrun (baseline sharding) and artifacts/dryrun_opt
(--optimized) and prints per-cell bound times + speedups — the §Perf
"optimized sweep" evidence.
"""
from __future__ import annotations

import glob
import json
import os

BASE = "artifacts/dryrun"
OPT = "artifacts/dryrun_opt"


def _load(root, mesh):
    out = {}
    for p in glob.glob(os.path.join(root, mesh, "*.json")):
        r = json.load(open(p))
        out[(r["arch"], r["shape"])] = r
    return out


def _bound(r):
    rf = r["roofline"]
    # recompute with analytic flops (same upgrade path as roofline_report)
    from benchmarks.roofline_report import _upgrade
    rf = _upgrade(r)["roofline"]
    return max(rf["compute_s"], rf["memory_s"], rf["collective_s"]), rf


def main(mesh="16x16"):
    base = _load(BASE, mesh)
    opt = _load(OPT, mesh)
    print(f"# perf_compare mesh={mesh}: bound seconds (max roofline term), "
          "baseline vs optimized")
    rows = []
    for key in sorted(base):
        b, o = base[key], opt.get(key)
        if b.get("skipped") or o is None or o.get("skipped"):
            continue
        tb, rb = _bound(b)
        to, ro = _bound(o)
        rows.append((key, tb, to, tb / to if to else float("inf"),
                     rb["roofline_fraction"], ro["roofline_fraction"]))
    for (arch, shape), tb, to, sp, fb, fo in rows:
        print(f"perf,{mesh},{arch},{shape},bound={tb:.4g}->{to:.4g}s,"
              f"speedup={sp:.2f}x,frac={fb:.4f}->{fo:.4f}")
    import numpy as np
    sps = [r[3] for r in rows]
    print(f"# geomean speedup over {len(rows)} cells: "
          f"{float(np.exp(np.mean(np.log(sps)))):.2f}x")
    print("# note: long_500k 'regressions' are the CPU backend's bf16->f32 "
          "dot legalization re-converting weights per step (EXPERIMENTS.md "
          "§Roofline methodology); on TPU bf16 weight reads HALVE that term.")


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "16x16")
