"""Durable serving state: SIGKILL mid-workload, restore, byte-identical.

The crash-consistency gate for serve/snapshot.py + the journal durability
layer.  A child process serves a deterministic workload through a
1-replica ``FleetSupervisor`` with per-tick snapshots and a write-ahead
journal, and SIGKILLs **itself** (no atexit, no flush — real process
death) once an adversarial state condition holds:

* ``midprefill``     — a long prompt is mid-chunked-prefill
  (``0 < n_prefilled < prompt_len``), so the last snapshot carries a
  partially-resident prompt and chunk cursor;
* ``midcow``         — a shared non-block-aligned prefix has triggered a
  copy-on-write (``cow_copies > 0``) and a first-wave request has
  already completed, so the snapshot carries a COW'd partial tail next
  to its still-shared radix sibling, plus finished chains that exist
  nowhere but the tree;
* ``postquarantine`` — a kv_corrupt fault fired and the guard quarantined
  the victim, so the snapshot carries a purged subtree and the journal a
  ``quarantined`` terminal.

The parent then restores **in-process** from the child's artifacts
(snapshot warm start with fsck, journal-suffix adoption, recompute
resubmission of in-flight requests) and drives the workload to drain.

Gates (the bench fails loudly on any):

* the child actually died by SIGKILL at every kill point, after at least
  one durable snapshot;
* every recovered greedy stream (tokens AND finish reason, including the
  quarantined victim) is byte-identical to an uninterrupted in-process
  reference run of the same workload;
* `check_invariants` passes immediately after restore (fsck) and zero
  blocks leak once the recovered run drains;
* the recovered run's new journal replays to exactly the tracker's
  terminal state (completed streams match the journal);
* a deliberately corrupted snapshot demonstrably falls back to **cold**
  recovery — and still reproduces byte-identical streams from the
  journal alone, rather than serving poisoned KV;
* warm restart beats cold restart for fresh traffic extending prompts
  that completed before the crash — chains only the snapshot remembers
  — both deterministically: fewer prefill tokens computed (the restored
  radix tree re-hits) and fewer supervision ticks to first token (one
  suffix chunk instead of re-prefilling the whole stem chunk by chunk).

Writes ``BENCH_restore.json`` (``--out``) with a provenance header; the
child journals/snapshots live under ``--artifacts`` for CI upload.

    PYTHONPATH=src:. python benchmarks/restore_bench.py [--smoke] \
        [--out BENCH_restore.json] [--artifacts restore_artifacts]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

BLOCK_SIZE = 8
NUM_BLOCKS = 48
MAX_BATCH = 3
PREFIX_LEN = 12                  # 1.5 blocks: the shared tail block is
#                                  partial, so a re-hit must COW it
TAIL_LEN = 8
PREFILL_CHUNK = 8
KILL_CASES = ("midprefill", "midcow", "postquarantine")
MAX_TICKS = 20_000               # runaway backstop, not a tuning knob
CHILD_EXIT_NO_KILL = 3           # child drained without hitting the
#                                  kill condition: a bench bug


def _setup():
    import jax

    from repro.models.registry import get_config, model_fns, reduce_config
    cfg = reduce_config(get_config("qwen3-4b"))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, params


def case_workload(case: str, vocab: int, seed: int, n_req: int
                  ) -> List[Tuple[int, np.ndarray]]:
    """Deterministic ``[(arrival_tick, prompt)]`` per (case, seed) — the
    child, the reference run, and the recovery all rebuild it bit-for-bit
    from the same RNG stream."""
    rng = np.random.default_rng(seed + 17 * KILL_CASES.index(case))
    if case == "midprefill":
        # long documents: several PREFILL_CHUNK-token chunks each, so
        # there is always a partially-prefilled request to kill over
        plen = 4 * PREFILL_CHUNK
        return [(0, rng.integers(1, vocab, (plen,)).astype(np.int32))
                for _ in range(n_req)]
    # two tenants sharing non-block-aligned PREFIX_LEN prefixes; the
    # second wave re-hits the published partial tail block (COW). The
    # quarantine case reuses the same shape (victims carry shared blocks)
    prefixes = [rng.integers(1, vocab, (PREFIX_LEN,)).astype(np.int32)
                for _ in range(2)]
    arrivals = []
    for i in range(n_req):
        tail = rng.integers(1, vocab, (TAIL_LEN,)).astype(np.int32)
        tick = 0 if i < 2 else 4 + 2 * (i - 2)
        arrivals.append((tick, np.concatenate([prefixes[i % 2], tail])))
    return arrivals


def fresh_batch(arrivals, vocab: int, seed: int, n: int) -> List[np.ndarray]:
    """New requests extending the original workload's prompts with fresh
    tails — the warm-vs-cold restart measurement traffic.  A warm
    (snapshot-restored) radix tree serves the whole shared stem as prefix
    hits; a cold tree has to prefill it chunk by chunk."""
    rng = np.random.default_rng(seed + 9999)
    return [np.concatenate([arrivals[i % len(arrivals)][1],
                            rng.integers(1, vocab, (TAIL_LEN,))
                            .astype(np.int32)])
            for i in range(n)]


def make_factory(cfg, params, case: str, max_new: int,
                 prefill_chunk: Optional[int] = None):
    from repro.serve import ContinuousEngine, EngineGuard

    if prefill_chunk is None:
        prefill_chunk = PREFILL_CHUNK if case == "midprefill" else 0

    def factory():
        eng = ContinuousEngine(
            cfg, params, block_size=BLOCK_SIZE, num_blocks=NUM_BLOCKS,
            max_batch=MAX_BATCH,
            max_len=4 * PREFILL_CHUNK + TAIL_LEN + max_new + 2,
            max_admit_per_step=2, retry_backoff_s=0.0,
            prefill_chunk=prefill_chunk,
            guard=(EngineGuard() if case == "postquarantine" else None))
        eng.warmup()
        return eng
    return factory


def build_fleet(factory, case: str, journal=None, snapshot_dir=None,
                snapshot_every: int = 0):
    """One-replica supervised fleet; the quarantine case gets the
    deterministic kv_corrupt plan attached to the serving engine."""
    from repro.serve import (FaultInjector, FaultPlan, FaultSpec,
                             FleetSupervisor, Router)
    eng = factory()
    if case == "postquarantine":
        plan = FaultPlan(seed=0, specs=[
            FaultSpec("kv_corrupt", step=4, duration=2)])
        eng.attach_faults(FaultInjector(plan))
    return FleetSupervisor([eng], router=Router("affinity"),
                           journal=journal, snapshot_dir=snapshot_dir,
                           snapshot_every=snapshot_every,
                           max_attempts=1000)


def kill_condition(case: str, sup) -> bool:
    if int(sup.c_snapshots.value) < 1:
        return False           # die only once a durable snapshot exists
    eng = sup.replicas[0].engine
    if case == "midprefill":
        return any(0 < r.n_prefilled < r.prompt_len
                   for r in eng.sched.running)
    if case == "midcow":
        # COW has fired AND a first-wave request already completed: the
        # snapshot then carries chains whose requests are terminal in
        # the journal — a cold resume never re-places those, so their
        # KV survives only in the warm tree (the warm-vs-cold phase
        # extends exactly those prompts)
        return (eng.pool.stats.cow_copies > 0
                and any(t.result is not None
                        for t in sup.tracker.requests.values()))
    return any(t.result is not None
               and t.result.finish_reason == "quarantined"
               for t in sup.tracker.requests.values())


def drive(sup, arrivals, max_new: int, kill_case: Optional[str] = None):
    """Submit each request on its arrival tick; tick until drained.  In
    the child, SIGKILL ourselves the moment the kill condition holds —
    between ticks, exactly where a real crash would land."""
    pending = sorted(arrivals, key=lambda a: a[0])
    i = 0
    while i < len(pending) or sup.has_work():
        while i < len(pending) and pending[i][0] <= sup.ticks:
            sup.submit(pending[i][1], max_new)
            i += 1
        sup.tick()
        if kill_case is not None and kill_condition(kill_case, sup):
            os.kill(os.getpid(), signal.SIGKILL)
        if sup.ticks > MAX_TICKS:
            raise RuntimeError(f"workload did not drain in {MAX_TICKS}")
    return sup


def streams_of(sup) -> Dict[int, Tuple[List[int], str]]:
    return {rid: (list(t.result.tokens), t.result.finish_reason)
            for rid, t in sup.tracker.requests.items()
            if t.result is not None}


# ---------------------------------------------------------------------------
# child: serve until the kill point, then die for real
# ---------------------------------------------------------------------------

def run_child(args) -> None:
    from repro.serve import Journal
    cfg, params = _setup()
    arrivals = case_workload(args.child, cfg.vocab_size, args.seed,
                             args.n_req)
    factory = make_factory(cfg, params, args.child, args.max_new)
    os.makedirs(args.artifacts, exist_ok=True)
    # quarantine terminals must be durable before death (a lost terminal
    # just regenerates tokens, but a *reason* is not recomputable once
    # the fault plan is gone); the other cases exercise the default
    # interval policy and its bounded tail-loss window
    journal = Journal(
        path=os.path.join(args.artifacts, "journal.jsonl"),
        fsync="always" if args.child == "postquarantine" else "interval",
        fsync_every=4)
    sup = build_fleet(factory, args.child, journal=journal,
                      snapshot_dir=os.path.join(args.artifacts, "snaps"),
                      snapshot_every=1)
    drive(sup, arrivals, args.max_new, kill_case=args.child)
    print(f"restore,child,{args.child},kill_condition_never_reached")
    sys.exit(CHILD_EXIT_NO_KILL)


def spawn_child(case: str, artifacts: str, seed: int, n_req: int,
                max_new: int) -> int:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), repo,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", case,
         "--artifacts", artifacts, "--seed", str(seed),
         "--n-req", str(n_req), "--max-new", str(max_new)],
        env=env, cwd=repo, capture_output=True, text=True, timeout=1800)
    if proc.returncode != -signal.SIGKILL:
        print(proc.stdout)
        print(proc.stderr, file=sys.stderr)
    return proc.returncode


# ---------------------------------------------------------------------------
# parent: restore + verify
# ---------------------------------------------------------------------------

def recover(factory, case: str, artifacts: str, arrivals, max_new: int,
            snapshot_dir: Optional[str], journal_out: Optional[str] = None,
            extra_prompts: Optional[List[np.ndarray]] = None):
    """Resume from the child's artifacts and drive the workload to drain.
    Returns (supervisor, info) where info carries the per-phase evidence
    the gates consume."""
    from repro.serve import (FleetSupervisor, Journal, Router,
                             check_invariants, leaked_blocks, replay)
    jpath = os.path.join(artifacts, "journal.jsonl")
    newj = Journal(path=journal_out) if journal_out else None
    sup = FleetSupervisor.resume(
        factory, 1, jpath, snapshot_dir=snapshot_dir, journal=newj,
        router=Router("affinity"), max_attempts=1000)
    # fsck gate immediately after restore, before any new work
    for r in sup.replicas:
        check_invariants(r.engine.pool, r.engine.prefix_cache)
    adopted = int(sup.tracker.c_recovered.value)
    # a warm restore carries the dead process's counters (snapshots are
    # exact); warm-vs-cold must compare work done SINCE the restore
    eng0 = sup.replicas[0].engine
    pre_prefill = int(eng0.metrics.prefill_tokens)
    pre_hits = int(eng0.prefix_cache.stats.hit_tokens)
    # workload requests the dead process never journaled get submitted
    # fresh (arrival order == rid order, so the suffix lines up), plus
    # any measurement traffic — BEFORE the drive, so warm-vs-cold TTFT
    # sees the restored (or empty) radix tree, not one rebuilt mid-run
    t0 = time.time()
    for _, p in sorted(arrivals, key=lambda a: a[0])[adopted:]:
        sup.submit(p, max_new)
    extra_rids = [sup.submit(p, max_new).rid
                  for p in (extra_prompts or [])]
    # TTFT in supervision ticks (chunked-prefill steps to first token):
    # deterministic, so warm-vs-cold is compile/scheduler-noise free
    submit_tick = sup.ticks
    first_tick: Dict[int, int] = {}
    while sup.has_work():
        sup.tick()
        for rid in extra_rids:
            if rid not in first_tick and sup.tracker.requests[rid].tokens:
                first_tick[rid] = sup.ticks
        if sup.ticks - submit_tick > MAX_TICKS:
            raise RuntimeError(f"resumed run did not drain in {MAX_TICKS}")
    wall = time.time() - t0
    eng = sup.replicas[0].engine
    info = {
        "mode": sup.restore_info[0]["mode"],
        "reason": sup.restore_info[0]["reason"],
        "adopted": adopted,
        "tail_lost": int(sup.tracker.c_tail_lost.value),
        "leaked": leaked_blocks(eng.pool, eng.prefix_cache),
        "prefill_tokens": int(eng.metrics.prefill_tokens) - pre_prefill,
        "prefix_hit_tokens":
            int(eng.prefix_cache.stats.hit_tokens) - pre_hits,
        "ttft_ticks": sorted(first_tick[r] - submit_tick
                             for r in extra_rids),
        "ttft_p50_s": sup.tracker.h_ttft.quantile(0.5),
        "wall_s": wall,
    }
    if newj is not None:
        st = replay(newj.records)
        live = streams_of(sup)
        info["journal_matches_streams"] = all(
            list(st.requests[rid].tokens) == toks
            and st.requests[rid].finish_reason == why
            for rid, (toks, why) in live.items())
        newj.close()
    check_invariants(eng.pool, eng.prefix_cache)
    return sup, info


def corrupt_snapshot(path: str) -> None:
    """Flip a byte span in the middle of the snapshot payload — a
    section checksum must catch it."""
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.seek(size // 2)
        chunk = f.read(8)
        f.seek(size // 2)
        f.write(bytes(b ^ 0xFF for b in chunk))


def main(argv=None) -> float:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=41)
    ap.add_argument("--n-req", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--fresh", type=int, default=4,
                    help="fresh shared-stem requests for warm-vs-cold")
    ap.add_argument("--out", default=None, metavar="PATH")
    ap.add_argument("--artifacts", default="restore_artifacts",
                    metavar="DIR")
    ap.add_argument("--child", choices=KILL_CASES, default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.child:
        run_child(args)
        return 0.0
    if args.smoke:
        args.n_req, args.max_new, args.fresh = 4, 6, 2

    cfg, params = _setup()
    failures: List[str] = []
    cases: Dict[str, Dict] = {}
    shutil.rmtree(args.artifacts, ignore_errors=True)

    for case in KILL_CASES:
        adir = os.path.join(args.artifacts, case)
        arrivals = case_workload(case, cfg.vocab_size, args.seed,
                                 args.n_req)
        factory = make_factory(cfg, params, case, args.max_new)

        # uninterrupted in-process reference: the byte-identity oracle
        ref = streams_of(drive(build_fleet(factory, case), arrivals,
                               args.max_new))

        rc = spawn_child(case, adir, args.seed, args.n_req, args.max_new)
        if rc != -signal.SIGKILL:
            failures.append(f"{case}: child exited {rc}, expected SIGKILL")
            cases[case] = {"child_rc": rc}
            continue

        sup, info = recover(
            factory, case, adir, arrivals, args.max_new,
            snapshot_dir=os.path.join(adir, "snaps"),
            journal_out=os.path.join(adir, "recovered_journal.jsonl"))
        got = streams_of(sup)
        mismatched = [rid for rid in ref
                      if got.get(rid) != ref[rid]]
        info.update({"child_rc": rc, "requests": len(ref),
                     "mismatched": mismatched})
        cases[case] = info
        print(f"restore,{case},mode,{info['mode']},adopted,"
              f"{info['adopted']},tail_lost,{info['tail_lost']},"
              f"mismatched,{mismatched},leaked,{info['leaked']},"
              f"journal_ok,{info['journal_matches_streams']}")
        if info["mode"] != "warm":
            failures.append(f"{case}: expected warm restore, got "
                            f"{info['mode']} ({info['reason']})")
        if mismatched:
            failures.append(f"{case}: recovered streams diverged: "
                            f"{mismatched}")
        if info["leaked"]:
            failures.append(f"{case}: {info['leaked']} leaked blocks")
        if not info["journal_matches_streams"]:
            failures.append(f"{case}: recovered journal does not replay "
                            f"to the delivered streams")

    # -- corrupted snapshot: must fall back cold, never serve poison ------
    case = "midcow"
    adir = os.path.join(args.artifacts, case)
    cdir = os.path.join(args.artifacts, "corrupted")
    corrupted: Dict = {}
    if os.path.isdir(os.path.join(adir, "snaps")):
        shutil.copytree(adir, cdir)
        corrupt_snapshot(os.path.join(cdir, "snaps", "replica0.snap"))
        arrivals = case_workload(case, cfg.vocab_size, args.seed,
                                 args.n_req)
        factory = make_factory(cfg, params, case, args.max_new)
        ref = streams_of(drive(build_fleet(factory, case), arrivals,
                               args.max_new))
        sup, corrupted = recover(
            factory, case, cdir, arrivals, args.max_new,
            snapshot_dir=os.path.join(cdir, "snaps"))
        got = streams_of(sup)
        corrupted["mismatched"] = [r for r in ref if got.get(r) != ref[r]]
        print(f"restore,corrupted,mode,{corrupted['mode']},"
              f"reason,{corrupted['reason'][:60]!r},"
              f"mismatched,{corrupted['mismatched']}")
        if corrupted["mode"] != "cold":
            failures.append("corrupted snapshot was not detected: "
                            f"restore mode {corrupted['mode']}")
        if corrupted["mismatched"]:
            failures.append("cold-fallback streams diverged: "
                            f"{corrupted['mismatched']}")
    else:
        failures.append("corrupted-snapshot phase skipped: no midcow "
                        "artifacts")

    # -- warm vs cold restart: chunked-prefill TTFT + prefill savings -----
    # resume the midcow artifacts twice (with and without the snapshot
    # dir) and submit fresh requests extending the FIRST-WAVE prompts —
    # requests that completed before the kill.  The journal adopts those
    # as terminal on both paths, so a cold resume never re-places them:
    # their chains survive only in the snapshot's radix tree.  (In-flight
    # prompts would be a bogus probe — their recompute republishes the
    # stems chunk-by-chunk on the cold path too.)  The measurement
    # engines prefill chunked so first-token latency counts supervision
    # ticks per stem chunk; that's legal against the unchunked child's
    # snapshot because the fingerprint covers state geometry, not
    # serving policy, and greedy streams are chunk-invariant.  Both
    # TTFT-in-ticks and prefill-token counts are deterministic — no
    # timing-noise retries needed.
    case = "midcow"
    adir = os.path.join(args.artifacts, case)
    arrivals = case_workload(case, cfg.vocab_size, args.seed, args.n_req)
    factory = make_factory(cfg, params, case, args.max_new,
                           prefill_chunk=PREFILL_CHUNK)
    fresh = fresh_batch(arrivals[:2], cfg.vocab_size, args.seed,
                        args.fresh)
    best: Dict[str, Dict] = {}
    for kind, sdir in (("cold", None),
                       ("warm", os.path.join(adir, "snaps"))):
        _, best[kind] = recover(factory, case, adir, arrivals,
                                args.max_new, snapshot_dir=sdir,
                                extra_prompts=fresh)
    warm, cold = best["warm"], best["cold"]
    ratio = cold["prefill_tokens"] / max(1, warm["prefill_tokens"])
    warm_ttft = warm["ttft_ticks"][len(warm["ttft_ticks"]) // 2]
    cold_ttft = cold["ttft_ticks"][len(cold["ttft_ticks"]) // 2]
    print(f"restore,warm_vs_cold,prefill_tokens_warm,"
          f"{warm['prefill_tokens']},prefill_tokens_cold,"
          f"{cold['prefill_tokens']},ratio,{ratio:.2f}")
    print(f"restore,warm_vs_cold,ttft_ticks_warm,{warm['ttft_ticks']},"
          f"ttft_ticks_cold,{cold['ttft_ticks']},hit_tokens_warm,"
          f"{warm['prefix_hit_tokens']},hit_tokens_cold,"
          f"{cold['prefix_hit_tokens']}")
    if warm["mode"] != "warm" or cold["mode"] != "cold":
        failures.append(f"warm/cold phase modes wrong: "
                        f"{warm['mode']}/{cold['mode']}")
    if warm["prefill_tokens"] >= cold["prefill_tokens"]:
        failures.append(
            f"warm restart did not save prefill: {warm['prefill_tokens']}"
            f" >= {cold['prefill_tokens']} tokens")
    if warm_ttft >= cold_ttft:
        failures.append(
            f"warm-restart TTFT p50 {warm_ttft} ticks did not beat "
            f"cold {cold_ttft} ticks")

    if args.out:
        sys.path.insert(0, ".")
        from benchmarks.provenance import provenance
        rec = {
            "bench": "restore",
            "provenance": provenance(
                mode="smoke" if args.smoke else "measured"),
            "workload": {
                "requests_per_case": args.n_req, "max_new": args.max_new,
                "fresh_requests": args.fresh, "seed": args.seed,
                "prefix_len": PREFIX_LEN, "tail_len": TAIL_LEN,
                "prefill_chunk": PREFILL_CHUNK,
                "block_size": BLOCK_SIZE, "num_blocks": NUM_BLOCKS,
                "max_batch": MAX_BATCH},
            # headline (top-level so trajectory cross-reference finds it)
            "cold_over_warm_prefill_tokens": round(ratio, 4),
            "kill_cases": cases,
            "corrupted_snapshot": corrupted,
            "warm_restart": {
                "warm_prefill_tokens": warm["prefill_tokens"],
                "cold_prefill_tokens": cold["prefill_tokens"],
                "cold_over_warm_prefill_tokens": round(ratio, 4),
                "warm_prefix_hit_tokens": warm["prefix_hit_tokens"],
                "cold_prefix_hit_tokens": cold["prefix_hit_tokens"],
                "warm_ttft_ticks": warm["ttft_ticks"],
                "cold_ttft_ticks": cold["ttft_ticks"],
                "warm_ttft_p50_ticks": warm_ttft,
                "cold_ttft_p50_ticks": cold_ttft,
                # wall-clock TTFT rides along for reference; it is noisy
                # on CPU (per-engine recompiles) and never gated
                "warm_ttft_p50_ms_wall": round(warm["ttft_p50_s"] * 1e3,
                                               3),
                "cold_ttft_p50_ms_wall": round(cold["ttft_p50_s"] * 1e3,
                                               3)},
            "gates_passed": not failures,
        }
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
            f.write("\n")
        print(f"restore,record,{args.out}")

    if failures:
        raise AssertionError("restore gates failed: " +
                             "; ".join(failures))
    print(f"restore,cold_over_warm_prefill_tokens,{ratio:.3f}")
    return ratio


if __name__ == "__main__":
    main()
