"""Table IV: unit- and PE-level area/energy ratios from the analytical 7nm
model, reported against the paper's measured ratios."""
from __future__ import annotations

from repro.core import energy_model


def run():
    return energy_model.table4(seq_len=384, width=32)


def main():
    for unit, r in run().items():
        print(f"table4,{unit},area={r['area_ratio']:.3f}"
              f"(paper {r['paper_area']:.2f}),"
              f"energy={r['energy_ratio']:.3f}(paper {r['paper_energy']:.2f})")


if __name__ == "__main__":
    main()
