"""Resilience layer: deterministic fault injection (serve/faults.py),
request-lifecycle hardening (typed submit errors, cancellation, deadlines,
bounded retry), and the EngineGuard degradation ladder with quarantine
(serve/guard.py) — end to end through the continuous engine."""
import jax
import numpy as np
import pytest

from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import (FAULT_KINDS, CapacityExceededError,
                         ContinuousEngine, DuplicateRequestError,
                         EmptyPromptError, EngineGuard, EngineSheddingError,
                         FaultInjector, FaultPlan, FaultSpec, GuardConfig,
                         GuardSignals, ManualClock, SubmitError, Telemetry,
                         TransientFault, canned_plan)
from repro.serve.guard import DEGRADED, HEALTHY, SHEDDING
from repro.serve.invariants import check_invariants, leaked_blocks

_rng = np.random.default_rng(31)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen3-4b"))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, **kw):
    kw.setdefault("block_size", 8)
    kw.setdefault("num_blocks", 24)
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 24)
    kw.setdefault("retry_backoff_s", 0.0)   # tests never need real backoff
    return ContinuousEngine(cfg, params, **kw)


def _prompt(cfg, n):
    return _rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)


# ---------------------------------------------------------------------------
# Fault plans and the injector (host-only, no model)
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_spec_validation(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec("meteor_strike", step=0)
        with pytest.raises(ValueError, match="step index or prob"):
            FaultSpec("slow_step")
        with pytest.raises(ValueError, match="duration"):
            FaultSpec("slow_step", step=0, duration=0)

    def test_json_roundtrip(self, tmp_path):
        plan = FaultPlan(seed=42, specs=[
            FaultSpec("kv_corrupt", step=3, duration=2),
            FaultSpec("pool_pressure", prob=0.25, duration=4,
                      magnitude=0.5),
        ])
        assert FaultPlan.from_json(plan.to_json()) == plan
        p = tmp_path / "plan.json"
        plan.save(str(p))
        assert FaultPlan.load(str(p)) == plan

    def test_canned_plans_cover_every_kind(self):
        from repro.serve.faults import (ENGINE_FAULT_KINDS,
                                        FLEET_FAULT_KINDS,
                                        canned_fleet_plan)
        assert {s.kind for s in canned_plan().specs} == \
            set(ENGINE_FAULT_KINDS)
        assert {s.kind for s in canned_fleet_plan().specs} == \
            set(FLEET_FAULT_KINDS)
        assert set(ENGINE_FAULT_KINDS) | set(FLEET_FAULT_KINDS) == \
            set(FAULT_KINDS)


class TestFaultInjector:
    def _fire_steps(self, inj, n=48):
        out = []
        for s in range(n):
            inj.begin_step(s)
            out.extend(dict(e) for e in inj.log[len(out):])
        return out

    def test_probabilistic_plan_replays_bit_for_bit(self):
        plan = FaultPlan(seed=9, specs=[
            FaultSpec("admit_stall", prob=0.2, duration=2),
            FaultSpec("slow_step", prob=0.15, magnitude=0.01),
        ])
        a = self._fire_steps(FaultInjector(plan))
        b = self._fire_steps(FaultInjector(plan))
        assert a and a == b             # something fired, identically
        inj = FaultInjector(plan)
        first = self._fire_steps(inj)
        inj.reset()
        assert self._fire_steps(inj) == first
        other = self._fire_steps(FaultInjector(
            FaultPlan(seed=10, specs=plan.specs)))
        assert other != a               # the seed is load-bearing

    def test_windows_and_consumption_hooks(self):
        inj = FaultInjector(FaultPlan(seed=0, specs=[
            FaultSpec("admit_stall", step=0),
            FaultSpec("pool_pressure", step=1, magnitude=0.25),
            FaultSpec("slow_step", step=2, magnitude=0.5),
            FaultSpec("kv_corrupt", step=3, duration=2),
            FaultSpec("numerics_spike", step=4, magnitude=0.9),
            FaultSpec("step_fault", step=5, duration=2),
        ]))
        inj.begin_step(0)
        assert inj.admission_stalled()
        assert inj.pool_pressure_target(16) == 0
        inj.begin_step(1)
        assert not inj.admission_stalled()
        assert inj.pool_pressure_target(16) == 4
        inj.begin_step(2)
        assert inj.stall_seconds() == 0.5
        inj.begin_step(3)
        assert inj.take_kv_corrupt()
        assert not inj.take_kv_corrupt()   # one victim per window
        inj.begin_step(4)                  # window still open, already used
        assert not inj.take_kv_corrupt()
        assert inj.numerics_spike() == 0.9
        inj.begin_step(5)
        with pytest.raises(TransientFault):
            inj.check_step_fault()
        with pytest.raises(TransientFault):
            inj.check_step_fault()         # duration == raise budget
        inj.check_step_fault()             # budget spent: clean
        assert inj.faults_injected == len(inj.log) == 6

    def test_replay_artifact(self, tmp_path):
        import json
        inj = FaultInjector(canned_plan())
        for s in range(30):
            inj.begin_step(s)
        p = tmp_path / "replay.json"
        inj.save_log(str(p))
        doc = json.loads(p.read_text())
        from repro.serve.faults import ENGINE_FAULT_KINDS
        assert FaultPlan.from_json(json.dumps(doc["plan"])) == canned_plan()
        assert {e["kind"] for e in doc["injections"]} == \
            set(ENGINE_FAULT_KINDS)


# ---------------------------------------------------------------------------
# The degradation ladder (pure state machine)
# ---------------------------------------------------------------------------


class TestGuardLadder:
    def test_escalation_is_immediate(self):
        g = EngineGuard()
        assert g.state == HEALTHY and g.submit_allowed()
        ch = g.observe(GuardSignals(pool_util=0.90), step=1)
        assert ch == (HEALTHY, DEGRADED, "pool_util 0.90")
        ch = g.observe(GuardSignals(pool_util=0.99), step=2)
        assert ch[1] == SHEDDING
        assert not g.submit_allowed() and not g.admit_allowed()
        assert g.effective_max_admit(4) == 0
        assert g.transitions == [(1, HEALTHY, DEGRADED, "pool_util 0.90"),
                                 (2, DEGRADED, SHEDDING, "pool_util 0.99")]

    def test_healthy_to_shedding_in_one_step(self):
        g = EngineGuard()
        ch = g.observe(GuardSignals(pool_util=1.0))
        assert ch[0] == HEALTHY and ch[1] == SHEDDING

    def test_recovery_is_hysteretic(self):
        g = EngineGuard(GuardConfig(recover_steps=2))
        g.observe(GuardSignals(pool_util=1.0))
        assert g.state == SHEDDING
        assert g.observe(GuardSignals()) is None      # 1 clean: not yet
        g.observe(GuardSignals(pool_util=1.0))        # dirty resets streak
        assert g.observe(GuardSignals()) is None
        ch = g.observe(GuardSignals())                # 2 consecutive clean
        assert ch[1] == DEGRADED and "recovered" in ch[2]
        g.observe(GuardSignals())
        ch = g.observe(GuardSignals())
        assert ch[1] == HEALTHY and g.state == HEALTHY

    def test_every_signal_reaches_severity(self):
        cfgd = GuardConfig(queue_wait_degraded=1.0, queue_wait_shedding=5.0,
                           step_time_hung_s=0.1)
        for sig, want in [
                (GuardSignals(logit_error=0.3), "logit_error"),
                (GuardSignals(queue_wait=2.0), "queue_wait"),
                (GuardSignals(step_seconds=0.2), "step_seconds")]:
            g = EngineGuard(cfgd)
            old, new, reason = g.observe(sig)
            assert new == DEGRADED and reason.startswith(want)
        g = EngineGuard(cfgd)
        assert g.observe(GuardSignals(queue_wait=6.0))[1] == SHEDDING

    def test_policy_knobs(self):
        g = EngineGuard()
        assert g.effective_prefill_budget(8) == 8
        assert not g.should_quarantine(0.49)
        assert g.should_quarantine(0.5)
        g.observe(GuardSignals(pool_util=0.9))
        assert g.effective_max_admit(4) == 2
        assert g.effective_prefill_budget(8) == 4
        assert g.effective_prefill_budget(0) == 0    # uncapped stays uncapped
        g.reset()
        assert g.state == HEALTHY and g.transitions == []


# ---------------------------------------------------------------------------
# Submit validation (typed front-door errors)
# ---------------------------------------------------------------------------


class TestSubmitValidation:
    def test_typed_errors(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params, num_blocks=2)
        with pytest.raises(EmptyPromptError):
            eng.submit(np.zeros((0,), np.int32), 4)
        with pytest.raises(SubmitError, match="1-D"):
            eng.submit(np.ones((2, 3), np.int32), 4)
        with pytest.raises(SubmitError, match="max_new"):
            eng.submit(_prompt(cfg, 4), 0)
        with pytest.raises(CapacityExceededError, match="max_len"):
            eng.submit(_prompt(cfg, 20), 8)          # 28 > max_len 24
        with pytest.raises(CapacityExceededError, match="num_blocks"):
            eng.submit(_prompt(cfg, 16), 8)          # 3 blocks > pool of 2
        with pytest.raises(SubmitError, match="deadline_s"):
            eng.submit(_prompt(cfg, 4), 2, deadline_s=0.0)
        h = eng.submit(_prompt(cfg, 4), 2)
        with pytest.raises(DuplicateRequestError):
            eng.submit(_prompt(cfg, 4), 2, req_id=h.req_id)
        # every rejection stays a ValueError (pre-PR 8 catch sites)
        for exc in (SubmitError, EmptyPromptError, DuplicateRequestError,
                    CapacityExceededError):
            assert issubclass(exc, ValueError)
        # nothing was enqueued by the rejected submissions
        assert len(eng.sched.waiting) == 1


# ---------------------------------------------------------------------------
# Cancellation and deadlines
# ---------------------------------------------------------------------------


class TestCancel:
    def test_cancel_waiting_is_idempotent(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params)
        h = eng.submit(_prompt(cfg, 8), 4)
        assert eng.cancel(h.req_id)
        assert not eng.cancel(h.req_id)              # already finished
        assert not eng.cancel(12345)                 # unknown id
        assert h.finish_reason == "cancelled"
        assert eng.metrics.cancelled == 1
        assert not eng.sched.has_work()
        assert h.req_id in eng.pop_finished()

    def test_cancel_running_frees_blocks_and_pins(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params)
        ha = eng.submit(_prompt(cfg, 12), 8)
        hb = eng.submit(_prompt(cfg, 12), 8)
        eng.step()                                   # both admitted+decoding
        assert ha.req_id in eng.pool._tables
        assert eng.cancel(ha.req_id)
        assert ha.req_id not in eng.pool._tables     # table released
        assert not eng.prefix_cache._held.get(ha.req_id)
        check_invariants(eng.pool, eng.prefix_cache)
        res = eng.run()                              # b unaffected
        assert res[hb.req_id].finish_reason == "length"
        assert len(res[hb.req_id].tokens) == 8
        assert leaked_blocks(eng.pool, eng.prefix_cache) == 0


class TestDeadlines:
    def test_deadline_cancels_and_counts(self, setup):
        cfg, params = setup
        clock = ManualClock(tick=0.001)
        eng = _engine(cfg, params, clock=clock, deadline_s=10.0)
        h_doomed = eng.submit(_prompt(cfg, 8), 8, deadline_s=0.5)
        h_fine = eng.submit(_prompt(cfg, 8), 2)
        clock.advance(1.0)                           # past doomed's deadline
        res = eng.run()
        assert res[h_doomed.req_id].finish_reason == "deadline"
        assert res[h_fine.req_id].finish_reason == "length"
        assert eng.metrics.deadline_misses == 1
        assert eng.metrics.cancelled == 1            # deadline is a cancel
        assert leaked_blocks(eng.pool, eng.prefix_cache) == 0

    def test_ttft_budget_cancels_before_first_token(self, setup):
        cfg, params = setup
        clock = ManualClock(tick=0.001)
        eng = _engine(cfg, params, clock=clock, ttft_budget_s=0.5)
        h = eng.submit(_prompt(cfg, 8), 4)
        clock.advance(1.0)                           # never admitted in time
        eng.step()
        assert h.finish_reason == "deadline"
        assert eng.metrics.deadline_misses == 1

    def test_ttft_budget_spares_streaming_requests(self, setup):
        cfg, params = setup
        clock = ManualClock(tick=0.001)
        eng = _engine(cfg, params, clock=clock)
        h = eng.submit(_prompt(cfg, 8), 4, ttft_budget_s=0.5)
        eng.step()                                   # first token dispatched
        assert h.t_first_token > 0.0
        clock.advance(1.0)                           # TTFT already met
        res = eng.run()
        assert res[h.req_id].finish_reason == "length"
        assert eng.metrics.deadline_misses == 0


# ---------------------------------------------------------------------------
# Transient faults and bounded retry
# ---------------------------------------------------------------------------


class TestTransientRetry:
    def test_retry_absorbs_the_fault_window(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params)                   # 3 retries default
        eng.attach_faults(FaultInjector(FaultPlan(seed=0, specs=[
            FaultSpec("step_fault", step=0, duration=2)])))
        h = eng.submit(_prompt(cfg, 8), 4)
        res = eng.run()
        assert res[h.req_id].finish_reason == "length"
        assert eng.metrics.transient_retries == 2    # both raises absorbed
        assert eng.metrics.faults_injected >= 1

    def test_retry_exhaustion_propagates(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params, step_fault_retries=1)
        eng.attach_faults(FaultInjector(FaultPlan(seed=0, specs=[
            FaultSpec("step_fault", step=0, duration=5)])))
        h = eng.submit(_prompt(cfg, 8), 4)
        with pytest.raises(TransientFault):
            eng.step()
        check_invariants(eng.pool, eng.prefix_cache)  # raise-before-mutate
        eng.attach_faults(None)                      # operator intervention
        res = eng.run()
        assert res[h.req_id].finish_reason == "length"
        assert len(res[h.req_id].tokens) == 4


# ---------------------------------------------------------------------------
# Guard + engine: shedding, recovery, quarantine
# ---------------------------------------------------------------------------


class TestGuardedEngine:
    def test_shedding_front_door_and_recovery(self, setup):
        cfg, params = setup
        guard = EngineGuard(GuardConfig(pool_util_degraded=0.01,
                                        pool_util_shedding=0.02,
                                        recover_steps=1))
        eng = _engine(cfg, params, guard=guard, prefix_cache=False)
        h = eng.submit(_prompt(cfg, 8), 4)
        eng.step()                                   # blocks allocated →
        assert guard.state == SHEDDING               # util over both bars
        with pytest.raises(EngineSheddingError, match="shedding"):
            eng.submit(_prompt(cfg, 8), 4)
        assert eng.metrics.shed == 1
        res = eng.run()                              # admitted work drains
        assert res[h.req_id].finish_reason == "length"
        eng.step()                                   # idle clean steps:
        eng.step()                                   # shed → degraded →
        assert guard.state == HEALTHY                # healthy (recover=1)
        eng.submit(_prompt(cfg, 8), 2)               # front door reopens
        eng.run()

    def test_kv_corruption_is_quarantined_and_purged(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params, guard=EngineGuard())
        eng.attach_faults(FaultInjector(FaultPlan(seed=0, specs=[
            FaultSpec("kv_corrupt", step=0, duration=1)])))
        ha = eng.submit(_prompt(cfg, 12), 6)         # prefills first: victim
        hb = eng.submit(_prompt(cfg, 12), 6)
        res = eng.run()
        assert res[ha.req_id].finish_reason == "quarantined"
        assert res[hb.req_id].finish_reason == "length"
        assert len(res[hb.req_id].tokens) == 6
        assert eng.metrics.quarantined == 1
        assert eng.metrics.readback_audits >= 2
        # the victim's poisoned prompt blocks were purged from the tree:
        # a resubmission of the same prompt gets no prefix hit
        assert eng.prefix_cache.lookup(ha.prompt) == 0
        assert eng.faults.corrupted_req_ids() == [ha.req_id]
        check_invariants(eng.pool, eng.prefix_cache)
        assert leaked_blocks(eng.pool, eng.prefix_cache) == 0


# ---------------------------------------------------------------------------
# reset()/drain lifecycle hygiene (same-step finish + preempt)
# ---------------------------------------------------------------------------


class TestResetDrainHygiene:
    def test_same_step_finish_and_preempt_leaves_no_pins(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params)
        ha = eng.submit(_prompt(cfg, 8), 1)          # finishes on step 0
        hb = eng.submit(_prompt(cfg, 8), 6)
        eng.step()                                   # a finishes; storm b
        eng.sched.force_preempt(1)
        assert ha.finish_reason == "length"
        assert hb.n_preemptions == 1
        check_invariants(eng.pool, eng.prefix_cache)
        res = eng.run()                              # b readmits + finishes
        assert len(res[hb.req_id].tokens) == 6
        assert not any(eng.prefix_cache._held.values())
        assert leaked_blocks(eng.pool, eng.prefix_cache) == 0
        eng.reset()                                  # tree flushed, no pins
        assert eng.pool.num_free == eng.pool.num_blocks
        assert eng.prefix_cache.cached_blocks == 0

    def test_reset_releases_injected_pool_pressure(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params)
        inj = FaultInjector(FaultPlan(seed=0, specs=[
            FaultSpec("pool_pressure", step=0, duration=50,
                      magnitude=0.5)]))
        eng.attach_faults(inj)
        h = eng.submit(_prompt(cfg, 8), 2)
        res = eng.run()
        assert res[h.req_id].finish_reason == "length"
        assert eng._fault_pressure_blocks > 0        # window still open
        eng.reset()
        assert eng._fault_pressure_blocks == 0
        assert eng.pool.num_free == eng.pool.num_blocks
        assert inj.log == []                         # injector reset too


# ---------------------------------------------------------------------------
# Telemetry: terminal states and resilience counters
# ---------------------------------------------------------------------------


class TestTerminalTelemetry:
    def test_traces_and_counters(self, setup):
        cfg, params = setup
        tel = Telemetry(clock=ManualClock(tick=0.001))
        eng = _engine(cfg, params, telemetry=tel)
        h_cancel = eng.submit(_prompt(cfg, 8), 4)
        h_doomed = eng.submit(_prompt(cfg, 8), 4, deadline_s=0.5)
        h_done = eng.submit(_prompt(cfg, 8), 2)
        eng.cancel(h_cancel.req_id)
        tel.clock.advance(1.0)
        eng.run()
        reasons = {tr.req_id: tr.finish_reason for tr in tel.finished_traces}
        assert reasons[h_cancel.req_id] == "cancelled"
        assert reasons[h_doomed.req_id] == "deadline"
        assert reasons[h_done.req_id] == "length"
        reg = tel.registry
        assert reg.get("requests_cancelled_total").value == 2
        assert reg.get("deadline_misses_total").value == 1
        # e2e latency stays completion-only (no cut-short samples)
        assert reg.get("serve_e2e_seconds").count == 1

    def test_fault_and_guard_metrics_exported(self, setup):
        cfg, params = setup
        tel = Telemetry(clock=ManualClock(tick=0.001))
        guard = EngineGuard(GuardConfig(pool_util_degraded=0.01,
                                        pool_util_shedding=0.02,
                                        recover_steps=1))
        eng = _engine(cfg, params, telemetry=tel, guard=guard)
        eng.attach_faults(FaultInjector(FaultPlan(seed=0, specs=[
            FaultSpec("slow_step", step=0, magnitude=0.25)])))
        eng.submit(_prompt(cfg, 8), 2)
        eng.run()
        reg = tel.registry
        assert reg.get("fault_injected_total").value >= 1
        assert reg.get("guard_transitions_total").value >= 1
        assert reg.get("guard_state") is not None
        from repro.serve.metrics import parse_prometheus_text
        fams = parse_prometheus_text(reg.prometheus_text())
        for name in ("fault_injected_total", "requests_cancelled_total",
                     "requests_shed_total", "deadline_misses_total",
                     "guard_state"):
            assert name in fams, name


# ---------------------------------------------------------------------------
# The resilience bench's CI mode (slow: three engines + verification drives)
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestBenchSmoke:
    def test_resilience_bench_smoke(self):
        import pathlib
        import sys
        root = pathlib.Path(__file__).resolve().parent.parent
        sys.path.insert(0, str(root))
        try:
            from benchmarks import resilience_bench
            ratio = resilience_bench.main(["--smoke"])
        finally:
            sys.path.pop(0)
        assert ratio >= 0.70
