"""Core algorithm tests: the paper's Figure-3 progression + §III.C claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.softermax as sm
from repro.core.numerics import LOG2_E, NEG_INF


def _rand(shape, scale=5.0, seed=0):
    return jnp.array(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32)
        * scale)


class TestBaseReplacement:
    def test_base2_folded_equals_softmax_e(self):
        x = _rand((8, 100))
        np.testing.assert_allclose(
            sm.softmax_base2(x, fold_log2e=True), sm.softmax_e(x),
            atol=2e-6)

    def test_base2_is_permutation_equivariant_simplex(self):
        x = _rand((4, 64))
        y = sm.softmax_base2(x)
        np.testing.assert_allclose(jnp.sum(y, -1), 1.0, atol=1e-5)
        assert bool(jnp.all(y >= 0))

    def test_base2_equals_softmax_of_scaled_input(self):
        # softmax_2(x) == softmax_e(x * ln2) — the finetuning target's math
        x = _rand((4, 64))
        np.testing.assert_allclose(
            sm.softmax_base2(x), sm.softmax_e(x * np.log(2.0)), atol=2e-6)


class TestOnlineNormalization:
    def test_paper_worked_example(self):
        # §III.C: [2,1,3] gives d = 1.75 with base-2 online renormalization
        x = jnp.array([[2.0, 1.0, 3.0]])
        m = jnp.max(jnp.ceil(x))
        d = jnp.sum(jnp.exp2(x - m))
        assert float(m) == 3.0
        np.testing.assert_allclose(float(d), 1.75, atol=1e-7)

    def test_online_matches_two_pass(self):
        x = _rand((16, 257))
        np.testing.assert_allclose(
            sm.softmax_online(x), sm.softmax_e(x), atol=2e-6)

    def test_block_online_matches_closed_form(self):
        x = _rand((8, 300))
        for block in (16, 128, 300):
            np.testing.assert_allclose(
                sm.softermax_online_scan(x, block=block), sm.softermax(x),
                atol=2e-6)


class TestIntegerMax:
    def test_intmax_preserves_distribution(self):
        # integer max changes shared scaling only: softermax == softmax_base2
        x = _rand((32, 128))
        np.testing.assert_allclose(
            sm.softermax(x), sm.softmax_base2(x), atol=2e-6)

    def test_renorm_factors_are_exact_powers_of_two(self):
        x = _rand((4, 64))
        m = jnp.max(jnp.ceil(x), -1)
        assert bool(jnp.all(m == jnp.round(m)))  # integer exponents
        f = jnp.exp2(m - (m + 3))                # 2^(-3): exact in fp
        np.testing.assert_array_equal(f, 0.125)


class TestMaskingAndEdgeCases:
    def test_fully_masked_row_is_finite(self):
        x = jnp.full((2, 32), NEG_INF)
        for fn in (sm.softermax, sm.softmax_base2, sm.softmax_e):
            assert bool(jnp.all(jnp.isfinite(fn(x))))

    def test_single_element_row(self):
        x = jnp.array([[3.7]])
        np.testing.assert_allclose(sm.softermax(x), 1.0, atol=2e-7)

    def test_large_dynamic_range(self):
        x = jnp.array([[100.0, -100.0, 0.0]])
        y = sm.softermax(x)
        np.testing.assert_allclose(y[0, 0], 1.0, atol=1e-6)
        assert bool(jnp.all(jnp.isfinite(y)))


class TestFixedPoint:
    def test_fixed_point_close_to_exact(self):
        x = _rand((16, 64), scale=4.0)
        err = jnp.abs(sm.softermax_fixed(x) - sm.softmax_base2(x)).max()
        # pre-finetuning error budget: a few output ulps (Q(1,7) = 1/128)
        assert float(err) < 8 / 128

    def test_fixed_point_rows_normalized(self):
        x = _rand((16, 64), scale=4.0)
        s = jnp.sum(sm.softermax_fixed(x), -1)
        np.testing.assert_allclose(s, 1.0, atol=0.06)

    def test_fixed_point_is_differentiable_ste(self):
        x = _rand((4, 16))
        g = jax.grad(lambda t: jnp.sum(sm.softermax_fixed(t) ** 2))(x)
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.abs(g).max()) > 0


class TestAttentionSoftmaxDispatch:
    @pytest.mark.parametrize("impl", ["softmax", "base2", "base2_folded",
                                      "softermax", "softermax_fixed"])
    def test_all_impls_normalize(self, impl):
        x = _rand((2, 3, 32))
        y = sm.attention_softmax(x, impl=impl)
        np.testing.assert_allclose(jnp.sum(y, -1), 1.0, atol=0.06)

    def test_unknown_impl_raises(self):
        with pytest.raises(ValueError):
            sm.attention_softmax(_rand((2, 4)), impl="nope")
