"""Radix-tree prefix cache: tree/refcount invariants under random
interleavings, eviction policy order, copy-on-write tails, strict pool
frees, and cached-vs-cold greedy equality through the engine."""
import jax
import numpy as np
import pytest

from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import (FAULT_REQ, CacheStats, ContinuousEngine,
                         FaultInjector, FaultPlan, FaultSpec, PagedKVCache,
                         RadixCache, Scheduler, TransientFault)
# the PR 2 invariant checker, promoted to the library (serve/invariants.py)
# so the resilience bench can assert the identical contract mid-flight;
# this module keeps driving it through random (now chaotic) interleavings
from repro.serve.invariants import check_invariants, leaked_blocks

_rng = np.random.default_rng(23)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen3-4b"))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# Metadata-level: tree, refcounts, eviction, COW — no model involved
# ---------------------------------------------------------------------------


class TestRadixTree:
    def _cache(self, cfg, n=12, bs=4, policy="lru"):
        pool = PagedKVCache(cfg, num_blocks=n, block_size=bs)
        return pool, RadixCache(pool, policy)

    def test_insert_match_release_roundtrip(self, setup):
        cfg, _ = setup
        pool, cache = self._cache(cfg)
        prompt = np.arange(1, 11, dtype=np.int32)          # 10 toks, bs 4
        pool.alloc(7, 3)
        cache.insert(7, prompt)            # 2 full nodes + 1 partial tail
        assert cache.cached_blocks == 3
        assert cache.evictable_blocks() == 0               # pinned by req 7
        check_invariants(pool, cache)
        # identical prompt: 2 full blocks + 2 of 3 tail rows (cap len-1)
        assert cache.lookup(prompt) == 9
        # a diverging prompt matches only the shared full blocks
        other = np.concatenate([prompt[:8], [99, 98]]).astype(np.int32)
        assert cache.lookup(other) == 8
        assert cache.release(7) == 0       # tree kept every block resident
        assert cache.evictable_blocks() == 3
        check_invariants(pool, cache)

    def test_admit_splices_and_cows(self, setup):
        cfg, _ = setup
        pool, cache = self._cache(cfg)
        prompt = np.arange(1, 11, dtype=np.int32)
        pool.alloc(1, 3)
        cache.insert(1, prompt)
        cache.release(1)
        hit = cache.admit(2, prompt, ensure_free=1)
        assert hit == 9
        table = pool.blocks_of(2)
        assert len(table) == 3
        # first two spliced by reference (shared with the tree)…
        tree_blocks = {nd.block for nd in cache._walk()}
        assert table[0] in tree_blocks and table[1] in tree_blocks
        assert pool.refcount(table[0]) == 2
        # …the tail copied-on-write into a block only req 2 owns
        assert table[2] not in tree_blocks
        assert pool.refcount(table[2]) == 1
        assert pool.stats.cow_copies == 1
        check_invariants(pool, cache)
        cache.release(2)
        check_invariants(pool, cache)

    def test_admit_cow_with_no_free_blocks_leaves_no_state(self, setup):
        """Bare-API admit (ensure_free=0) needing a COW block while the
        free list is empty must raise before pinning or splicing anything."""
        cfg, _ = setup
        pool, cache = self._cache(cfg, n=2, bs=4)
        prompt = np.arange(1, 7, dtype=np.int32)   # 1 full + 2-token tail
        pool.alloc(1, 2)
        cache.insert(1, prompt)
        cache.release(1)
        assert pool.num_free == 0                  # tree owns both blocks
        from repro.serve.kv_pool import PoolExhausted
        with pytest.raises(PoolExhausted):
            cache.admit(2, prompt)
        assert 2 not in cache._held and 2 not in pool._tables
        assert all(nd.ref == 0 for nd in cache._walk())
        check_invariants(pool, cache)

    def test_eviction_lru_order_and_pinning(self, setup):
        cfg, _ = setup
        pool, cache = self._cache(cfg, n=12, bs=4, policy="lru")
        a = np.arange(1, 5, dtype=np.int32)        # one block each
        b = np.arange(11, 15, dtype=np.int32)
        pool.alloc(1, 1), cache.insert(1, a), cache.release(1)
        pool.alloc(2, 1), cache.insert(2, b), cache.release(2)
        blk_a = next(nd.block for nd in cache._walk() if nd.key == tuple(a))
        blk_b = next(nd.block for nd in cache._walk() if nd.key == tuple(b))
        # touch `a` (admit pins + touches, then release) so `b` is the LRU
        cache.admit(3, np.concatenate([a, [9]]).astype(np.int32))
        cache.release(3)
        assert cache.evict(1) == 1
        remaining = {nd.block for nd in cache._walk()}
        assert blk_b not in remaining and blk_a in remaining
        check_invariants(pool, cache)
        # pinned paths are never evicted
        cache.admit(4, np.concatenate([a, [9]]).astype(np.int32))
        assert cache.evict(8) == 0         # `a` pinned by running req 4
        assert cache.cached_blocks == 1
        cache.release(4)
        assert cache.evict(8) == 1
        assert cache.cached_blocks == 0
        check_invariants(pool, cache)

    def test_eviction_fifo_order(self, setup):
        cfg, _ = setup
        pool, cache = self._cache(cfg, policy="fifo")
        a = np.arange(1, 5, dtype=np.int32)
        b = np.arange(11, 15, dtype=np.int32)
        pool.alloc(1, 1), cache.insert(1, a), cache.release(1)
        pool.alloc(2, 1), cache.insert(2, b), cache.release(2)
        blk_a = next(nd.block for nd in cache._walk() if nd.key == tuple(a))
        cache.admit(3, np.concatenate([a, [9]]).astype(np.int32))
        cache.release(3)                   # LRU would now evict `b` first
        assert cache.evict(1) == 1
        assert blk_a not in {nd.block for nd in cache._walk()}

    def test_parent_becomes_evictable_leaf_first(self, setup):
        cfg, _ = setup
        pool, cache = self._cache(cfg)
        prompt = np.arange(1, 13, dtype=np.int32)  # 3 full blocks
        pool.alloc(1, 3)
        cache.insert(1, prompt)
        cache.release(1)
        assert cache.cached_blocks == 3
        assert cache.evict(3) == 3         # leaf, then parent, then root kid
        assert cache.cached_blocks == 0
        check_invariants(pool, cache)

    def test_full_node_covers_partial_tail_insert(self, setup):
        """A full-block node already serves any shorter tail's rows:
        inserting prompt [1..7] after [1..8] must not donate a duplicate
        (5,6,7) leaf next to the (5,6,7,8) block."""
        cfg, _ = setup
        pool, cache = self._cache(cfg)
        full = np.arange(1, 9, dtype=np.int32)     # 2 full blocks
        pool.alloc(1, 2)
        cache.insert(1, full)
        cache.release(1)
        assert cache.cached_blocks == 2
        shorter = np.arange(1, 8, dtype=np.int32)  # 1 full + 3-token tail
        hit = cache.admit(2, shorter, ensure_free=1)
        assert hit == 6                  # 1 full block + COW run of 2
        pool.alloc(2, 2 - pool.n_blocks_of(2))
        cache.insert(2, shorter)
        assert cache.cached_blocks == 2  # tail covered by the full node
        assert cache.stats.evictions == 0
        check_invariants(pool, cache)
        cache.release(2)
        check_invariants(pool, cache)

    def test_evict_until_free_reaches_target(self, setup):
        cfg, _ = setup
        pool, cache = self._cache(cfg, n=6, bs=4)
        for rid in (1, 2, 3):
            pool.alloc(rid, 1)
            cache.insert(rid, np.arange(rid * 10, rid * 10 + 4,
                                        dtype=np.int32))
            cache.release(rid)
        assert pool.num_free == 3 and cache.cached_blocks == 3
        assert cache.evict_until_free(5)
        assert pool.num_free == 5 and cache.cached_blocks == 1
        assert not cache.evict_until_free(7)     # only 6 blocks exist
        assert cache.cached_blocks == 0
        check_invariants(pool, cache)

    def test_duplicate_insert_keeps_incumbent(self, setup):
        cfg, _ = setup
        pool, cache = self._cache(cfg)
        prompt = np.arange(1, 9, dtype=np.int32)   # 2 full blocks
        pool.alloc(1, 2)
        cache.insert(1, prompt)
        pool.alloc(2, 2)                   # same prompt computed cold
        cache.insert(2, prompt)            # concurrently (same admit batch)
        assert cache.cached_blocks == 2    # no duplicate nodes
        check_invariants(pool, cache)
        cache.release(1)
        check_invariants(pool, cache)
        cache.release(2)                   # req 2's duplicates fully freed
        assert pool.num_free + cache.cached_blocks == pool.num_blocks
        check_invariants(pool, cache)


class TestStrictFree:
    def test_double_free_raises(self, setup):
        cfg, _ = setup
        pool = PagedKVCache(cfg, num_blocks=4, block_size=4)
        pool.alloc(1, 2)
        pool.free(1)
        with pytest.raises(ValueError, match="double free"):
            pool.free(1)

    def test_unknown_req_free_raises(self, setup):
        cfg, _ = setup
        pool = PagedKVCache(cfg, num_blocks=4, block_size=4)
        with pytest.raises(ValueError, match="no block table"):
            pool.free(42)

    def test_share_unresident_block_raises(self, setup):
        cfg, _ = setup
        pool = PagedKVCache(cfg, num_blocks=4, block_size=4)
        with pytest.raises(ValueError, match="not resident"):
            pool.share(1, [3])


# ---------------------------------------------------------------------------
# Random interleavings keep the pool/tree/scheduler mutually consistent
# ---------------------------------------------------------------------------


OPS = ("submit", "admit", "step", "preempt", "evict", "finish",
       "cancel", "inject")


def _drive_interleaving(cfg, ops, choices):
    """Execute one op sequence against a scheduler+cache stack with a
    probabilistic fault injector attached, mimicking the engine's calling
    convention (admit → publish → count-based decode) and checking the
    refcount/free-list contract after every op. The "cancel" and "inject"
    ops mix client cancellation, pool-pressure hostage blocks, forced
    preemption storms, and transient block-growth faults into the
    interleaving; the injector is seeded, so every sequence replays."""
    pool = PagedKVCache(cfg, num_blocks=12, block_size=4)
    cache = RadixCache(pool)
    sched = Scheduler(pool, max_batch=3, max_len=32, cache=cache)
    inj = FaultInjector(FaultPlan(seed=13, specs=[
        FaultSpec("admit_stall", prob=0.1),
        FaultSpec("step_fault", prob=0.1),
    ]))
    sched.faults = pool.faults = inj
    prefixes = [np.arange(1, 5), np.arange(1, 9), np.arange(11, 23)]

    def grow():
        # the engine's bounded retry, minus the backoff (host-only test);
        # a raise must leave the pool untouched (raise-before-mutate)
        for _ in range(8):
            try:
                return sched.ensure_decode_blocks()
            except TransientFault:
                check_invariants(pool, cache)
        raise AssertionError("injected step_fault never cleared")

    for i, op in enumerate(ops):
        c = choices[i % len(choices)]
        inj.begin_step(i)
        if op == "submit" and len(sched.waiting) < 4:
            pre = prefixes[c % len(prefixes)]
            suf = np.asarray([50 + c, 60 + c, 70 + c][:1 + c % 3])
            sched.submit(np.concatenate([pre, suf]).astype(np.int32),
                         max_new=1 + c % 5)
        elif op == "admit":
            for req in sched.admit(2):
                cache.insert(req.req_id, req.prompt)   # engine's publish
        elif op == "step" and sched.running:
            grow()
            for req in sched.running:
                req.n_cached += 1
                req.n_generated += 1
            sched.evict_finished()
        elif op == "preempt" and len(sched.running) > 1:
            sched._preempt(sched.running[-1])
        elif op == "evict":
            cache.evict(1 + c % 3)
        elif op == "finish" and sched.running:
            req = sched.running[c % len(sched.running)]
            req.n_generated = req.max_new
            sched.evict_finished()
        elif op == "cancel":
            live = list(sched.waiting) + sched.running
            if live:
                sched.cancel(live[c % len(live)].req_id)
        elif op == "inject":
            if c % 2 == 0:          # pool-pressure hostage toggle
                if FAULT_REQ in pool._tables:
                    pool.free(FAULT_REQ)
                else:
                    want = min(pool.num_free, 1 + c % 2)
                    if want:
                        pool.alloc(FAULT_REQ, want)
            else:
                sched.force_preempt(1 + c % 2)
        check_invariants(pool, cache)
    # quiet the storm, then drain everything and confirm only tree blocks
    # stay resident
    sched.faults = pool.faults = None
    if FAULT_REQ in pool._tables:
        pool.free(FAULT_REQ)
    while sched.has_work():
        for req in sched.admit():
            cache.insert(req.req_id, req.prompt)
        sched.ensure_decode_blocks()
        for req in sched.running:
            req.n_cached += 1
            req.n_generated += 1
        sched.evict_finished()
        check_invariants(pool, cache)
    assert leaked_blocks(pool, cache) == 0
    assert pool.stats.shared_blocks == 0


class TestInterleavingInvariants:
    def test_seeded_random_interleavings(self, setup):
        """No-dependency fallback for the hypothesis property test below:
        many seeded random schedules through the same driver."""
        cfg, _ = setup
        for seed in range(30):
            rng = np.random.default_rng(seed)
            ops = [OPS[i] for i in rng.integers(0, len(OPS), 80)]
            choices = list(rng.integers(0, 97, 80))
            _drive_interleaving(cfg, ops, choices)

    def test_hypothesis_interleavings(self, setup):
        hypothesis = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st
        cfg, _ = setup

        @settings(max_examples=30, deadline=None)
        @given(st.lists(st.sampled_from(OPS), min_size=1, max_size=60),
               st.lists(st.integers(0, 96), min_size=1, max_size=60))
        def run(ops, choices):
            _drive_interleaving(cfg, ops, choices)

        run()


# ---------------------------------------------------------------------------
# Engine-level: cached and cold paths decode identically
# ---------------------------------------------------------------------------


class TestCachedVsCold:
    def _cold_tokens(self, cfg, params, prompts, max_new, max_len):
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                               max_batch=4, max_len=max_len,
                               prefix_cache=False)
        hs = [eng.submit(p, max_new) for p in prompts]
        res = eng.run()
        return [res[h.req_id].tokens for h in hs]

    def test_identical_prompt_resubmission(self, setup):
        """Second submission of the same prompt hits the tree (incl. the
        COW tail: 20 % 8 != 0) and must decode identically."""
        cfg, params = setup
        prompt = _rng.integers(1, cfg.vocab_size, (20,)).astype(np.int32)
        (cold,) = self._cold_tokens(cfg, params, [prompt], 6, 32)
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                               max_batch=4, max_len=32)
        h1 = eng.submit(prompt, 6)
        r1 = eng.run()
        h2 = eng.submit(prompt, 6)
        r2 = eng.run()
        assert r1[h1.req_id].tokens == cold
        assert r2[h2.req_id].tokens == cold
        assert r2[h2.req_id].n_prefix_hit == 19     # 2 blocks + COW(3 rows)
        assert eng.pool.stats.cow_copies >= 1
        check_invariants(eng.pool, eng.prefix_cache)

    def test_shared_prefix_batch_matches_nocache(self, setup):
        cfg, params = setup
        shared = _rng.integers(1, cfg.vocab_size, (16,))
        prompts = [np.concatenate(
            [shared, _rng.integers(1, cfg.vocab_size, (n,))]
        ).astype(np.int32) for n in (5, 9, 13)]
        cold = self._cold_tokens(cfg, params, prompts, 5, 48)
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                               max_batch=4, max_len=48)
        hs = [eng.submit(p, 5) for p in prompts]
        res = eng.run()
        for h, want in zip(hs, cold):
            assert res[h.req_id].tokens == want
        assert eng.metrics.prefix_hit_tokens >= 16  # 3rd request reused
        assert eng.metrics.prefill_savings > 1.0
        check_invariants(eng.pool, eng.prefix_cache)

    def test_scarce_pool_evicts_instead_of_failing(self, setup):
        """The pool only fits one trajectory + a little; each admission
        evicts the previous request's cached blocks and everything still
        decodes to the cold answer."""
        cfg, params = setup
        prompts = [_rng.integers(1, cfg.vocab_size, (16,)).astype(np.int32)
                   for _ in range(3)]
        cold = self._cold_tokens(cfg, params, prompts, 8, 32)
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=4,
                               max_batch=4, max_len=32)
        hs = [eng.submit(p, 8) for p in prompts]
        res = eng.run()
        for h, want in zip(hs, cold):
            assert res[h.req_id].tokens == want
        assert eng.prefix_cache.stats.evictions > 0
        assert eng.metrics.preemptions == 0
        check_invariants(eng.pool, eng.prefix_cache)

    def test_warmup_flushes_cache(self, setup):
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=32,
                               max_batch=4, max_len=40)
        eng.warmup()
        assert eng.prefix_cache.cached_blocks == 0
        assert eng.pool.num_free == 32
        assert eng.prefix_cache.stats == CacheStats()
        check_invariants(eng.pool, eng.prefix_cache)


@pytest.mark.slow
class TestBenchSmoke:
    def test_prefix_cache_bench_smoke(self):
        """The benchmark's CI mode: asserts >=1.8x prefill-token savings
        and cached-vs-cold greedy equality on a tiny workload."""
        import pathlib
        import sys
        root = pathlib.Path(__file__).resolve().parent.parent
        sys.path.insert(0, str(root / "benchmarks"))
        try:
            import prefix_cache_bench
            ratio = prefix_cache_bench.main(["--smoke"])
        finally:
            sys.path.pop(0)
        assert ratio > 0
