"""Durability layer (PR 10): crash-consistent snapshots (serve/snapshot.py
— versioned container, per-section checksums, exact pool/radix/scheduler/
engine rebuild, recompute requeue), journal durability (per-record CRC,
torn-tail valid-prefix recovery, snapshot anchors, compaction, fsync
policies), and fleet warm restart (`FleetSupervisor.resume`)."""
import json
import os

import jax
import numpy as np
import pytest

from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import (ContinuousEngine, FleetSupervisor, Journal,
                         JournalCorrupt, ManualClock, Router, Snapshot,
                         SnapshotCorrupt, apply_snapshot, check_invariants,
                         engine_fingerprint, leaked_blocks, replay,
                         requeue_inflight, restore_engine, snapshot_state,
                         state_digest, write_snapshot)

_rng = np.random.default_rng(23)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen3-4b"))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, **kw):
    kw.setdefault("block_size", 8)
    kw.setdefault("num_blocks", 24)
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 48)
    kw.setdefault("retry_backoff_s", 0.0)
    eng = ContinuousEngine(cfg, params, **kw)
    eng.warmup()
    return eng


def _prompt(cfg, n):
    return _rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)


def _shared_prompts(cfg, n_req, prefix_len=12, tail_len=6, seed=5):
    """Prompts sharing a non-block-aligned prefix: re-hits COW the
    partial tail block."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, cfg.vocab_size, (prefix_len,)).astype(np.int32)
    return [np.concatenate(
        [prefix, rng.integers(1, cfg.vocab_size, (tail_len,))
         .astype(np.int32)]) for _ in range(n_req)]


def _streams(finished):
    return {rid: (list(r.tokens), r.finish_reason)
            for rid, r in finished.items()}


def _sections_equal(a: Snapshot, b: Snapshot):
    assert a.meta == b.meta
    assert set(a.sections) == set(b.sections)
    for name in a.sections:
        x, y = a.sections[name], b.sections[name]
        if isinstance(x, np.ndarray):
            assert x.dtype == y.dtype and x.shape == y.shape, name
            assert np.array_equal(np.asarray(x, np.float32) if
                                  str(x.dtype) == "bfloat16" else x,
                                  np.asarray(y, np.float32) if
                                  str(y.dtype) == "bfloat16" else y), name
        else:
            assert x == y, name


# ---------------------------------------------------------------------------
# Journal durability: CRCs, torn tails, anchors, compaction, fsync
# ---------------------------------------------------------------------------


def _journal(path, fsync="interval", **kw):
    j = Journal(path=str(path), clock=ManualClock(tick=0.25),
                fsync=fsync, **kw)
    j.append("submit", rid=0, prompt_len=3, max_new=4, prompt=[5, 6, 7])
    j.append("placement", rid=0, replica=0, engine_rid=0, attempt=0,
             reason="submit", resume_base=0)
    j.append("token", rid=0, replica=0, pos=0, toks=[11, 12])
    j.append("submit", rid=1, prompt_len=2, max_new=2, prompt=[8, 9])
    j.append("token", rid=0, replica=0, pos=2, toks=[13, 14])
    j.append("terminal", rid=0, reason="length", n_tokens=4)
    return j


class TestJournalDurability:
    def test_records_carry_seq_and_crc(self, tmp_path):
        p = tmp_path / "wal.jsonl"
        j = _journal(p)
        j.close()
        lines = [json.loads(x) for x in open(p) if x.strip()]
        assert [r["seq"] for r in lines] == list(range(len(lines)))
        assert all(isinstance(r["crc"], int) for r in lines)
        loaded = Journal.load(str(p))          # strict: everything valid
        assert loaded.tail_lost == 0 and loaded.dups_dropped == 0
        assert [r["kind"] for r in loaded.records] == \
            [r["kind"] for r in j.records]

    def test_bitflip_detected_by_crc(self, tmp_path):
        p = tmp_path / "wal.jsonl"
        _journal(p).close()
        lines = open(p).read().splitlines()
        # flip a token value in a middle record: still valid JSON+seq,
        # only the CRC can catch it
        lines[2] = lines[2].replace("11", "91", 1)
        (tmp_path / "evil.jsonl").write_text("\n".join(lines) + "\n")
        with pytest.raises(JournalCorrupt, match="line 3"):
            Journal.load(str(tmp_path / "evil.jsonl"))
        j = Journal.load(str(tmp_path / "evil.jsonl"), strict=False)
        # valid-prefix semantics: everything from the flipped record on
        # is dropped, not resurrected
        assert [r["kind"] for r in j.records] == ["submit", "placement"]
        assert j.tail_lost == 4
        j.replay()                             # prefix is a legal history

    def test_torn_and_garbage_tails(self, tmp_path):
        p = tmp_path / "wal.jsonl"
        _journal(p).close()
        for tail in ('{"kind": "tok',          # torn mid-record write
                     "\x00\x00garbage\n",      # preallocated junk
                     '{"kind": "token"}\n{"a"'):   # missing crc + torn
            q = tmp_path / "torn.jsonl"
            q.write_text(open(p).read() + tail)
            with pytest.raises(JournalCorrupt):
                Journal.load(str(q))
            j = Journal.load(str(q), strict=False)
            assert len(j.records) == 6
            assert j.tail_lost >= 1
            st = j.replay()
            assert st.requests[0].tokens == [11, 12, 13, 14]

    def test_duplicate_records_dropped(self, tmp_path):
        p = tmp_path / "wal.jsonl"
        _journal(p).close()
        lines = open(p).read().splitlines(keepends=True)
        dup = "".join(lines[:3] + [lines[2]] + lines[3:])   # replayed write
        q = tmp_path / "dup.jsonl"
        q.write_text(dup)
        with pytest.raises(JournalCorrupt, match="seq"):
            Journal.load(str(q))
        j = Journal.load(str(q), strict=False)
        assert j.dups_dropped == 1
        assert len(j.records) == 6             # the dup is dropped, the
        st = j.replay()                        # suffix after it is kept
        assert st.requests[0].tokens == [11, 12, 13, 14]

    def test_anchor_compaction_and_from_anchor(self, tmp_path):
        p = tmp_path / "wal.jsonl"
        j = _journal(p)
        full = state_digest(j.replay())
        j.anchor(note="mid")
        j.append("submit", rid=2, prompt_len=2, max_new=2, prompt=[3, 4])
        j.append("token", rid=2, replica=0, pos=0, toks=[5])
        # anchored replay == full replay
        assert state_digest(j.replay(from_anchor=True)) == \
            state_digest(j.replay())
        dropped = j.compact()
        assert dropped == 6                    # pre-anchor records gone
        assert j.records[0]["kind"] == "snapshot"
        j.close()
        loaded = Journal.load(str(p))          # compacted file stands alone
        st = loaded.replay()
        assert st.requests[0].tokens == [11, 12, 13, 14]
        assert st.requests[2].tokens == [5]
        assert full["requests"]["0"] == \
            state_digest(st)["requests"]["0"]

    def test_anchor_digest_mismatch_rejected(self, tmp_path):
        j = _journal(tmp_path / "wal.jsonl")
        j.anchor()
        j.records[-1]["digest"]["requests"]["0"]["tokens"] = [9, 9]
        with pytest.raises(JournalCorrupt, match="disagrees"):
            j.replay()

    def test_fsync_policies(self, tmp_path):
        for policy in ("none", "interval", "always"):
            j = _journal(tmp_path / f"{policy}.jsonl", fsync=policy)
            j.close()
            assert len(Journal.load(str(tmp_path / f"{policy}.jsonl"))
                       .records) == 6
        with pytest.raises(ValueError, match="fsync"):
            Journal(path=str(tmp_path / "x.jsonl"), fsync="sometimes")


# ---------------------------------------------------------------------------
# Snapshot container: checksums + corruption detection (no engine needed)
# ---------------------------------------------------------------------------


class TestSnapshotContainer:
    def _snap(self):
        return Snapshot(meta={"fingerprint": {"demo": 1}},
                        sections={"arr": np.arange(24, dtype=np.int32)
                                  .reshape(4, 6),
                                  "meta": {"free": [1, 2], "clock": 7}})

    def test_roundtrip_and_atomic_write(self, tmp_path):
        p = str(tmp_path / "s.snap")
        info = self._snap().write(p)
        assert info["sections"] == ["arr", "meta"]
        assert os.path.getsize(p) == info["nbytes"]
        assert not [f for f in os.listdir(tmp_path)
                    if f.startswith(".snap.")]     # no temp litter
        _sections_equal(self._snap(), Snapshot.read(p))

    def test_corruption_is_detected(self, tmp_path):
        p = str(tmp_path / "s.snap")
        self._snap().write(p)
        blob = bytearray(open(p, "rb").read())
        for mutation, match in (
                (lambda b: b[-8:-4] == b"\x00" * 4 or
                 b.__setitem__(slice(-8, -4), b"\xff\xff\xff\xff"),
                 "checksum mismatch in section"),
                (lambda b: b.__setitem__(slice(0, 3), b"XXX"),
                 "bad magic"),
                (lambda b: b.__setitem__(slice(len(b) - 2, len(b)), b""),
                 "truncated"),):
            bad = bytearray(blob)
            mutation(bad)
            q = str(tmp_path / "bad.snap")
            open(q, "wb").write(bytes(bad))
            with pytest.raises(SnapshotCorrupt, match=match):
                Snapshot.read(q)

    def test_header_tamper_detected(self, tmp_path):
        p = str(tmp_path / "s.snap")
        self._snap().write(p)
        blob = open(p, "rb").read()
        nl = blob.find(b"\n")
        tampered = blob[:nl + 1] + \
            blob[nl + 1:].replace(b'"version": 1', b'"version": 9', 1)
        open(p, "wb").write(tampered)
        with pytest.raises(SnapshotCorrupt, match="header checksum"):
            Snapshot.read(p)


# ---------------------------------------------------------------------------
# Engine snapshot/restore: byte-identical continuation
# ---------------------------------------------------------------------------


class TestEngineSnapshot:
    def _mid_workload(self, cfg, params, **kw):
        """An engine a few steps into a shared-prefix workload, with
        requests in every interesting phase."""
        eng = _engine(cfg, params, **kw)
        for p in _shared_prompts(cfg, 3):
            eng.submit(p, 6)
        for _ in range(3):
            eng.step()
        return eng

    def test_restored_engine_continues_byte_identical(self, setup, tmp_path):
        cfg, params = setup
        eng = self._mid_workload(cfg, params)
        path = str(tmp_path / "mid.snap")
        write_snapshot(eng, path)

        fresh = _engine(cfg, params)
        apply_snapshot(fresh, Snapshot.read(path))
        check_invariants(fresh.pool, fresh.prefix_cache)
        # identical decode rows, queues, and PRNG stream -> identical run
        assert _streams(fresh.run()) == _streams(eng.run())
        assert leaked_blocks(fresh.pool, fresh.prefix_cache) == 0

    def test_midcow_state_roundtrips(self, setup, tmp_path):
        cfg, params = setup
        eng = _engine(cfg, params)
        prompts = _shared_prompts(cfg, 4, seed=11)
        eng.submit(prompts[0], 4)
        eng.run()                        # publish the shared prefix
        for p in prompts[1:]:
            eng.submit(p, 4)             # re-hits COW the partial block
        steps = 0
        while eng.pool.stats.cow_copies == 0 and steps < 50:
            eng.step()
            steps += 1
        assert eng.pool.stats.cow_copies > 0
        path = str(tmp_path / "cow.snap")
        write_snapshot(eng, path)
        fresh = _engine(cfg, params)
        apply_snapshot(fresh, Snapshot.read(path))
        _sections_equal(snapshot_state(fresh), Snapshot.read(path))
        assert _streams(fresh.run()) == _streams(eng.run())

    def test_purged_pinned_nodes_snapshot_cleanly(self, setup, tmp_path):
        """purge() detaches tree nodes that other in-flight requests
        still pin (their pins unwind at release).  The snapshot keeps
        only live-tree pins, so serializing a post-quarantine engine
        must neither crash nor restore an inconsistent tree."""
        cfg, params = setup
        eng = _engine(cfg, params)
        prompts = _shared_prompts(cfg, 3, seed=7)
        eng.submit(prompts[0], 4)
        eng.run()                        # publish the shared prefix
        victim = eng.submit(prompts[1], 4)
        eng.submit(prompts[2], 4)        # sibling pins the shared path
        eng.step()                       # admit + match both sharers
        assert eng.prefix_cache.purge(victim.req_id) > 0
        live = {id(n) for n in eng.prefix_cache._walk()}
        assert any(id(n) not in live
                   for pins in eng.prefix_cache._held.values()
                   for n in pins)        # a detached node IS still pinned
        path = str(tmp_path / "purged.snap")
        write_snapshot(eng, path)
        fresh = _engine(cfg, params)
        apply_snapshot(fresh, Snapshot.read(path))
        check_invariants(fresh.pool, fresh.prefix_cache)
        assert _streams(fresh.run()) == _streams(eng.run())
        assert leaked_blocks(fresh.pool, fresh.prefix_cache) == 0

    def test_int8_scale_siblings_roundtrip(self, setup, tmp_path):
        cfg, params = setup
        kw = dict(kv_dtype="int8")
        eng = self._mid_workload(cfg, params, **kw)
        path = str(tmp_path / "int8.snap")
        write_snapshot(eng, path)
        snap = Snapshot.read(path)
        assert "pool.k_scale" in snap.sections   # scales travel with KV
        assert "pool.v_scale" in snap.sections
        fresh = _engine(cfg, params, **kw)
        apply_snapshot(fresh, snap)
        _sections_equal(snapshot_state(fresh), snap)
        assert _streams(fresh.run()) == _streams(eng.run())

    def test_fingerprint_mismatch_rejected(self, setup, tmp_path):
        cfg, params = setup
        eng = self._mid_workload(cfg, params)
        path = str(tmp_path / "geom.snap")
        write_snapshot(eng, path)
        other = _engine(cfg, params, num_blocks=32)
        with pytest.raises(SnapshotCorrupt, match="fingerprint"):
            apply_snapshot(other, Snapshot.read(path))
        eng.run()

    def test_requeue_inflight_recompute_contract(self, setup, tmp_path):
        cfg, params = setup
        eng = self._mid_workload(cfg, params)
        reference = _streams(eng.run())  # uninterrupted oracle

        eng2 = self._mid_workload(cfg, params)   # same deterministic state
        path = str(tmp_path / "rq.snap")
        write_snapshot(eng2, path)

        fresh = _engine(cfg, params)
        apply_snapshot(fresh, Snapshot.read(path))
        specs = requeue_inflight(fresh)
        assert specs == sorted(specs, key=lambda s: s["rid"])
        assert leaked_blocks(fresh.pool, fresh.prefix_cache) == 0
        done = dict(fresh.pop_finished())        # finished-at-snapshot set
        emitted = {}
        for s in specs:                          # [prompt ‖ emitted] resume
            emitted[s["rid"]] = s["tokens"]
            h = fresh.submit(
                np.asarray(s["prompt"] + s["tokens"], np.int32),
                s["max_new"] - len(s["tokens"]),
                temperature=s["temperature"])
            emitted[h.req_id] = emitted.pop(s["rid"])
        for rid, req in fresh.run().items():
            done[rid] = req
        got = {}
        for rid, req in done.items():
            got[rid] = (emitted.get(rid, []) + list(req.tokens),
                        req.finish_reason)
        assert sorted(got.values()) == sorted(reference.values())
        assert leaked_blocks(fresh.pool, fresh.prefix_cache) == 0

    def test_restore_engine_cold_fallback_on_corruption(self, setup,
                                                        tmp_path):
        cfg, params = setup
        eng = self._mid_workload(cfg, params)
        path = str(tmp_path / "bad.snap")
        write_snapshot(eng, path)
        blob = bytearray(open(path, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        open(path, "wb").write(bytes(blob))

        factory = lambda: _engine(cfg, params)   # noqa: E731
        restored, specs, info = restore_engine(factory, path)
        assert info["mode"] == "cold"
        assert "checksum" in info["reason"]
        assert specs == []
        # the fallback engine is pristine: no poisoned KV, no queues
        assert not restored.sched.running and not restored.sched.waiting
        assert leaked_blocks(restored.pool, restored.prefix_cache) == 0
        eng.run()

        restored2, _, info2 = restore_engine(
            factory, str(tmp_path / "nope.snap"))
        assert info2["mode"] == "cold" and "missing" in info2["reason"]


# ---------------------------------------------------------------------------
# Property: serialize -> deserialize is the identity on serving state
# ---------------------------------------------------------------------------

_OPS = ("submit_shared", "submit_fresh", "step", "step", "drain_one")


def _apply_ops(eng, cfg, ops, rng):
    for op in ops:
        try:
            if op == "submit_shared":
                eng.submit(_shared_prompts(cfg, 1, seed=3)[0]
                           if rng.random() < 0.5 else
                           _shared_prompts(cfg, 1, seed=4)[0], 4)
            elif op == "submit_fresh":
                eng.submit(rng.integers(1, cfg.vocab_size, (10,))
                           .astype(np.int32), 3)
            elif op == "step":
                eng.step()
            elif op == "drain_one":
                eng.drain()
                eng.pop_finished()
        except Exception:
            pass                         # capacity refusals are fine here


def _assert_roundtrip_identity(eng, spare, tmp_path, tag):
    """snapshot -> file -> snapshot must be the identity, and the restored
    state must satisfy every pool/radix invariant."""
    path = str(tmp_path / f"{tag}.snap")
    before = write_snapshot(eng, path)
    snap = Snapshot.read(path)
    requeue_inflight(spare)              # recycle the spare to idle
    spare.pop_finished()
    apply_snapshot(spare, snap)          # fsck: invariants on restore
    again = snapshot_state(spare)
    _sections_equal(snap, again)
    # and the re-serialized bytes index identically
    info = again.write(str(tmp_path / f"{tag}2.snap"))
    assert info["nbytes"] == before["nbytes"]


class TestSerializeDeserializeProperty:
    def test_seeded_roundtrip_identity(self, setup, tmp_path):
        """No-dependency fallback for the hypothesis property test below:
        a seeded sweep of random op schedules, checking at every prefix
        that serialize -> deserialize is the identity."""
        cfg, params = setup
        eng = _engine(cfg, params)
        spare = _engine(cfg, params)
        rng = np.random.default_rng(0)
        for i in range(6):
            _apply_ops(eng, cfg, rng.choice(_OPS, size=4), rng)
            _assert_roundtrip_identity(eng, spare, tmp_path, f"s{i}")

    def test_hypothesis_roundtrip_identity(self, setup, tmp_path):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st
        cfg, params = setup
        eng = _engine(cfg, params)
        spare = _engine(cfg, params)
        rng = np.random.default_rng(1)
        counter = iter(range(10 ** 6))

        @settings(max_examples=10, deadline=None)
        @given(st.lists(st.sampled_from(_OPS), min_size=1, max_size=6))
        def prop(ops):
            _apply_ops(eng, cfg, ops, rng)
            _assert_roundtrip_identity(eng, spare, tmp_path,
                                       f"h{next(counter)}")

        prop()


# ---------------------------------------------------------------------------
# Fleet warm restart
# ---------------------------------------------------------------------------


class TestFleetResume:
    def _run_and_abandon(self, cfg, params, tmp_path, ticks=4):
        """A supervised fleet some ticks into a workload, then simply
        dropped — the in-process stand-in for SIGKILL (restore_bench
        covers real process death)."""
        factory = lambda: _engine(cfg, params)   # noqa: E731
        prompts = _shared_prompts(cfg, 3, seed=9)

        ref = FleetSupervisor([factory()], router=Router("affinity"),
                              max_attempts=100)
        for p in prompts:
            ref.submit(p, 6)
        ref.run_until_drained()
        reference = {rid: (list(t.result.tokens), t.result.finish_reason)
                     for rid, t in ref.tracker.requests.items()}

        jpath = str(tmp_path / "wal.jsonl")
        sdir = str(tmp_path / "snaps")
        sup = FleetSupervisor(
            [factory()], router=Router("affinity"), max_attempts=100,
            journal=Journal(path=jpath, fsync="always"),
            snapshot_dir=sdir, snapshot_every=2)
        for p in prompts:
            sup.submit(p, 6)
        for _ in range(ticks):
            sup.tick()
        assert sup.has_work()            # died mid-flight, not drained
        assert int(sup.c_snapshots.value) >= 1
        return factory, jpath, sdir, reference

    def test_resume_warm_byte_identical(self, setup, tmp_path):
        cfg, params = setup
        factory, jpath, sdir, reference = self._run_and_abandon(
            cfg, params, tmp_path)
        newj = Journal(path=str(tmp_path / "wal2.jsonl"))
        sup = FleetSupervisor.resume(
            factory, 1, jpath, snapshot_dir=sdir, journal=newj,
            router=Router("affinity"), max_attempts=100)
        assert sup.restore_info[0]["mode"] == "warm"
        assert int(sup.tracker.c_recovered.value) == len(reference)
        sup.run_until_drained()
        got = {rid: (list(t.result.tokens), t.result.finish_reason)
               for rid, t in sup.tracker.requests.items()}
        assert got == reference
        eng = sup.replicas[0].engine
        assert leaked_blocks(eng.pool, eng.prefix_cache) == 0
        # the new journal replays to exactly the delivered streams
        st = replay(newj.records)
        assert {r: (list(v.tokens), v.finish_reason)
                for r, v in st.requests.items()} == reference

    def test_resume_without_snapshots_is_cold_but_correct(self, setup,
                                                          tmp_path):
        cfg, params = setup
        factory, jpath, _sdir, reference = self._run_and_abandon(
            cfg, params, tmp_path)
        sup = FleetSupervisor.resume(
            factory, 1, jpath, snapshot_dir=None,
            router=Router("affinity"), max_attempts=100)
        assert sup.restore_info[0]["mode"] == "cold"
        sup.run_until_drained()
        got = {rid: (list(t.result.tokens), t.result.finish_reason)
               for rid, t in sup.tracker.requests.items()}
        assert got == reference          # journal-only recompute suffices
