"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.flash_attention.ops import flash_attention_op, scale_queries
from repro.kernels.flash_decode import decode_ref, flash_decode
from repro.kernels.softermax import softermax_op, softermax_rows_ref
from repro.kernels.softermax_quant import (softermax_quant_op,
                                           softermax_quant_ref)

_rng = np.random.default_rng(7)


def _arr(shape, dtype=jnp.float32, scale=3.0):
    x = _rng.normal(size=shape).astype(np.float32) * scale
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestSoftermaxKernel:
    @pytest.mark.parametrize("shape,bv", [
        ((4, 128), 128), ((8, 1024), 256), ((5, 300), 128),
        ((16, 64), 128), ((3, 7, 130), 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, shape, bv, dtype):
        x = _arr(shape, dtype)
        got = softermax_op(x, block_v=bv, interpret=True)
        want = softermax_rows_ref(x.astype(jnp.float32)).astype(dtype)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=TOL[dtype])

    def test_base2_ablation(self):
        x = _arr((8, 384))
        got = softermax_op(x, intmax=False, interpret=True)
        want = softermax_rows_ref(x, intmax=False)
        np.testing.assert_allclose(got, want, atol=2e-6)

    def test_masked_rows(self):
        x = jnp.full((4, 256), -1e9, jnp.float32)
        got = softermax_op(x, interpret=True)
        assert bool(jnp.all(jnp.isfinite(got)))


class TestSoftermaxQuantKernel:
    @pytest.mark.parametrize("shape", [(6, 64), (4, 300), (8, 37), (2, 16)])
    def test_bit_faithful_vs_ref(self, shape):
        x = _arr(shape, scale=6.0)
        got = softermax_quant_op(x, interpret=True)
        want = softermax_quant_ref(x)
        # ≤ 1 output ulp (Q(1,7)) — see kernels/softermax_quant/ref.py
        assert float(jnp.abs(got - want).max()) <= 2 ** -7 + 1e-6

    def test_quant_grid(self):
        x = _arr((4, 64), scale=6.0)
        got = np.asarray(softermax_quant_op(x, interpret=True))
        # outputs live exactly on the Q(1,7) grid
        np.testing.assert_allclose(got * 128, np.round(got * 128), atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D,causal", [
        (2, 4, 2, 256, 256, 64, True),
        (1, 8, 8, 200, 200, 64, True),
        (2, 4, 1, 128, 384, 64, True),     # decode-extension offset
        (1, 2, 2, 96, 96, 128, False),
        (1, 6, 3, 130, 130, 64, False),
    ])
    def test_matches_oracle(self, B, Hq, Hkv, Sq, Sk, D, causal):
        q = scale_queries(_arr((B, Hq, Sq, D), scale=1.0), D, base2=True)
        k = _arr((B, Hkv, Sk, D), scale=1.0)
        v = _arr((B, Hkv, Sk, D), scale=1.0)
        got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
        want = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.bfloat16])
    def test_bf16(self, dtype):
        q = scale_queries(_arr((1, 2, 128, 64), dtype, 1.0), 64, base2=True)
        k = _arr((1, 2, 128, 64), dtype, 1.0)
        v = _arr((1, 2, 128, 64), dtype, 1.0)
        got = flash_attention(q, k, v, causal=True, interpret=True)
        want = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=3e-2)

    def test_custom_vjp_grads_flow(self):
        q = scale_queries(_arr((1, 2, 64, 32), scale=1.0), 32, base2=True)
        k = _arr((1, 2, 64, 32), scale=1.0)
        v = _arr((1, 2, 64, 32), scale=1.0)

        def f(q, k, v):
            return jnp.sum(flash_attention_op(q, k, v, True, True, 32, 32,
                                              True) ** 2)

        gq, gk, gv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        for g in (gq, gk, gv):
            assert bool(jnp.all(jnp.isfinite(g)))
            assert float(jnp.abs(g).max()) > 0


class TestFlashDecode:
    @pytest.mark.parametrize("B,Hq,Hkv,S,D", [
        (2, 4, 2, 512, 64), (3, 8, 1, 300, 64), (1, 2, 2, 1024, 128),
    ])
    def test_matches_oracle(self, B, Hq, Hkv, S, D):
        q = _arr((B, Hq, D), scale=1.0) / np.sqrt(D)
        k = _arr((B, Hkv, S, D), scale=1.0)
        v = _arr((B, Hkv, S, D), scale=1.0)
        lens = jnp.asarray(_rng.integers(1, S + 1, size=(B,)), jnp.int32)
        got = flash_decode(q, k, v, lens, block_k=128, interpret=True)
        want = decode_ref(q, k, v, lens)
        np.testing.assert_allclose(got, want, atol=2e-5)

    def test_length_zero_safe(self):
        q = _arr((1, 2, 64), scale=1.0)
        k = _arr((1, 2, 128, 64), scale=1.0)
        v = _arr((1, 2, 128, 64), scale=1.0)
        got = flash_decode(q, k, v, jnp.zeros((1,), jnp.int32),
                           interpret=True)
        assert bool(jnp.all(jnp.isfinite(got)))


class TestFlashBackwardKernel:
    """Pallas flash backward (dq/dk/dv recomputed from saved (m,d) stats)
    vs reference autodiff, incl. the base-2 ln(2) Jacobian factor."""

    @pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D,causal", [
        (1, 2, 1, 128, 128, 32, True),
        (2, 4, 2, 96, 96, 64, True),
        (1, 2, 2, 64, 192, 32, True),   # decode-extension offset
        (1, 2, 2, 80, 80, 32, False),
    ])
    def test_grads_match_reference(self, B, Hq, Hkv, Sq, Sk, D, causal):
        q = scale_queries(_arr((B, Hq, Sq, D), scale=1.0), D, base2=True)
        k = _arr((B, Hkv, Sk, D), scale=1.0)
        v = _arr((B, Hkv, Sk, D), scale=1.0)
        do = _arr((B, Hq, Sq, D), scale=1.0)

        def f_kernel(q, k, v):
            return jnp.sum(flash_attention_op(q, k, v, causal, True,
                                              64, 64, True) * do)

        def f_ref(q, k, v):
            return jnp.sum(attention_ref(q, k, v, causal=causal,
                                         intmax=True) * do)

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            rel = float(jnp.abs(a - b).max()
                        / jnp.maximum(jnp.abs(b).max(), 1e-9))
            assert rel < 2e-4, rel

    def test_forward_stats_shapes(self):
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention)
        q = _arr((1, 2, 70, 32), scale=0.3)
        k = _arr((1, 2, 70, 32), scale=1.0)
        v = _arr((1, 2, 70, 32), scale=1.0)
        o, m, d = flash_attention(q, k, v, causal=True, block_q=32,
                                  block_k=32, interpret=True,
                                  return_stats=True)
        assert m.shape == (1, 2, 70, 1) and d.shape == (1, 2, 70, 1)
        # intmax: saved maxima are integral
        np.testing.assert_allclose(np.asarray(m), np.round(np.asarray(m)))
