"""Serving observability: metric registry, streaming histograms, telemetry
hooks, step timeline export, numerics monitor, structured logging."""
import json as jsonlib
import logging
import math

import jax
import numpy as np
import pytest

from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import (ContinuousEngine, Counter, Gauge, Histogram,
                         ManualClock, MetricRegistry, Telemetry,
                         parse_prometheus_text)

_rng = np.random.default_rng(7)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen3-4b"))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, tel=None, **kw):
    kw.setdefault("block_size", 8)
    kw.setdefault("num_blocks", 32)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 48)
    return ContinuousEngine(cfg, params, telemetry=tel, **kw)


def _drive(eng, n_req=3, prompt_len=16, max_new=6, seed=3):
    rng = np.random.default_rng(seed)
    for _ in range(n_req):
        eng.submit(rng.integers(1, 100, (prompt_len,)).astype(np.int32),
                   max_new)
    return eng.run()


class TestHistogram:
    def test_quantiles_match_numpy_within_bucket_width(self):
        h = Histogram("h")
        samples = np.random.default_rng(0).lognormal(-4.0, 1.0, 5000)
        for x in samples:
            h.observe(x)
        for q in (0.50, 0.90, 0.99):
            exact = float(np.quantile(samples, q))
            # log-bucket ladder: estimate is within one 25% bucket width
            assert abs(h.quantile(q) - exact) / exact < h.growth - 1.0
        assert h.count == len(samples)
        assert h.sum == pytest.approx(samples.sum())

    def test_quantile_clamped_to_observed_extremes(self):
        h = Histogram("h")
        h.observe(3e-3)
        assert h.quantile(0.0) == h.quantile(1.0) == 3e-3
        assert h.min == h.max == 3e-3

    def test_empty_and_garbage_observations(self):
        h = Histogram("h")
        assert h.quantile(0.5) == 0.0 and h.mean == 0.0
        h.observe(-1.0)
        h.observe(math.nan)
        h.observe(math.inf)
        assert h.count == 0      # clock glitches must not poison p99
        h.observe(1e9)           # overflow bucket still counted
        assert h.count == 1 and h.quantile(0.99) == 1e9

    def test_bucket_edges_are_geometric(self):
        h = Histogram("h", lo=1e-3, growth=2.0, n_buckets=4)
        assert h.upper_edge(0) == 1e-3
        assert h.upper_edge(2) == pytest.approx(4e-3)
        assert math.isinf(h.upper_edge(len(h.counts) - 1))

    def test_merge_is_as_if_observed_here(self):
        # quantiles of the merge == quantiles of one histogram that saw
        # every sample — and both track numpy within one bucket width
        rng = np.random.default_rng(1)
        a_s = rng.lognormal(-4.0, 1.0, 3000)
        b_s = rng.lognormal(-2.0, 0.5, 2000)
        a, b, one = Histogram("h"), Histogram("h"), Histogram("h")
        for x in a_s:
            a.observe(x)
            one.observe(x)
        for x in b_s:
            b.observe(x)
            one.observe(x)
        a.merge(b)
        both = np.concatenate([a_s, b_s])
        assert a.count == one.count == len(both)
        assert a.sum == pytest.approx(one.sum)
        assert a.min == one.min and a.max == one.max
        for q in (0.5, 0.9, 0.99):
            assert a.quantile(q) == one.quantile(q)
            exact = float(np.quantile(both, q))
            assert abs(a.quantile(q) - exact) / exact < a.growth - 1.0
        # merging an empty histogram is the identity
        before = list(a.counts)
        a.merge(Histogram("h"))
        assert list(a.counts) == before

    def test_merge_rejects_mismatched_ladder(self):
        a = Histogram("h", lo=1e-6, growth=1.25)
        for bad in (Histogram("h", lo=1e-3, growth=1.25),
                    Histogram("h", lo=1e-6, growth=2.0),
                    Histogram("h", lo=1e-6, growth=1.25, n_buckets=8)):
            with pytest.raises(ValueError, match="ladder"):
                a.merge(bad)


class TestRegistry:
    def test_counter_monotonic_and_gauge_max(self):
        reg = MetricRegistry()
        c = reg.counter("c_total")
        c.inc()
        c.inc(2)
        assert c.value == 3
        with pytest.raises(ValueError):
            c.inc(-1)
        g = reg.gauge("g")
        g.set(2.0)
        g.max(1.0)
        assert g.value == 2.0
        g.max(5.0)
        assert g.value == 5.0

    def test_get_or_create_and_kind_conflict(self):
        reg = MetricRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(ValueError):
            reg.gauge("x")
        with pytest.raises(ValueError):
            reg.counter("bad name")

    def test_prometheus_roundtrip(self):
        reg = MetricRegistry()
        reg.counter("req_total", "requests").inc(4)
        reg.gauge("pool_util").set(0.25)
        h = reg.histogram("lat_seconds", "latency")
        for x in (1e-4, 2e-3, 5e-2, 5e-2, 1e9):
            h.observe(x)
        fams = parse_prometheus_text(reg.prometheus_text())
        assert fams["req_total"]["type"] == "counter"
        assert fams["req_total"]["samples"][0][2] == 4.0
        assert fams["pool_util"]["samples"][0][2] == 0.25
        hist = fams["lat_seconds"]
        assert hist["type"] == "histogram"
        names = {s[0] for s in hist["samples"]}
        assert names == {"lat_seconds_bucket", "lat_seconds_sum",
                         "lat_seconds_count"}
        count = [s for s in hist["samples"] if s[0] == "lat_seconds_count"]
        assert count[0][2] == 5.0

    def test_parser_rejects_malformed(self):
        with pytest.raises(ValueError):
            parse_prometheus_text("this is { not a sample\n")
        # non-cumulative buckets caught
        bad = ('# TYPE h histogram\n'
               'h_bucket{le="1.0"} 5\nh_bucket{le="+Inf"} 3\n'
               'h_sum 1.0\nh_count 3\n')
        with pytest.raises(ValueError, match="cumulative"):
            parse_prometheus_text(bad)
        # +Inf bucket must equal _count
        bad = ('# TYPE h histogram\n'
               'h_bucket{le="+Inf"} 3\nh_sum 1.0\nh_count 4\n')
        with pytest.raises(ValueError, match="_count"):
            parse_prometheus_text(bad)

    def test_jsonl_sink_appends_snapshots(self, tmp_path):
        reg = MetricRegistry()
        reg.counter("n_total").inc()
        p = tmp_path / "m.jsonl"
        reg.write_jsonl(str(p), extra={"run": 1})
        reg.counter("n_total").inc()
        reg.write_jsonl(str(p), extra={"run": 2})
        lines = [jsonlib.loads(s) for s in p.read_text().splitlines()]
        assert [r["run"] for r in lines] == [1, 2]
        assert [r["metrics"]["n_total"] for r in lines] == [1.0, 2.0]

    def test_collect_aggregates_replicas(self):
        # per-replica registries folded into a front-end aggregate:
        # counters/histograms sum, gauges max, prefix filters
        reps = []
        for i in range(3):
            r = MetricRegistry()
            r.counter("serve_req_total").inc(i + 1)
            r.gauge("serve_pool_peak").set(10.0 * (i + 1))
            h = r.histogram("serve_tpot_seconds")
            for x in np.random.default_rng(i).lognormal(-4, 1, 500):
                h.observe(x)
            r.counter("other_total").inc(100)
            reps.append(r)
        agg = MetricRegistry().collect(*reps, prefix="serve_")
        assert agg.get("serve_req_total").value == 6
        assert agg.get("serve_pool_peak").value == 30.0
        assert agg.get("serve_tpot_seconds").count == 1500
        assert agg.get("other_total") is None
        # kind mismatch across replicas raises instead of silently mixing
        bad = MetricRegistry()
        bad.gauge("serve_req_total")
        with pytest.raises(ValueError):
            agg.collect(bad)


class TestTelemetryEngine:
    """End-to-end hooks on a real engine with a deterministic clock."""

    def test_lifecycle_traces_and_histograms(self, setup):
        cfg, params = setup
        tel = Telemetry(clock=ManualClock(tick=1e-4))
        eng = _engine(cfg, params, tel)
        res = _drive(eng, n_req=3, max_new=6)
        assert sorted(res) == [0, 1, 2]
        assert tel.c_submitted.value == tel.c_finished.value == 3
        assert len(tel.finished_traces) == 3 and not tel.traces
        for tr in tel.finished_traces:
            assert tr.prompt_len == 16 and tr.n_tokens == 6
            assert (tr.t_submit <= tr.t_admit <= tr.t_first_token
                    <= tr.t_finish)
            assert tr.queue_wait >= 0 and tr.ttft > 0 and tr.e2e > 0
            assert tr.tpot_mean > 0
            names = [e[0] for e in tr.events]
            assert names[0] == "submit" and names[-1] == "finish"
            assert "first_token" in names
        assert tel.quantiles("ttft")["count"] == 3
        assert tel.quantiles("e2e")["count"] == 3
        # TPOT: dispatch-time gaps between consecutive tokens per request
        assert tel.quantiles("tpot")["count"] == 3 * (6 - 1)
        assert tel.quantiles("serve_step_seconds")["count"] > 0
        with pytest.raises(KeyError):
            tel.quantiles("nope")

    def test_engine_gauges_mirror_metrics(self, setup):
        cfg, params = setup
        tel = Telemetry(clock=ManualClock(tick=1e-4))
        eng = _engine(cfg, params, tel)
        _drive(eng)
        snap = tel.registry.snapshot()
        assert snap["serve_tokens_out"] == eng.metrics.tokens_out
        assert snap["serve_prefills"] == eng.metrics.prefills
        assert snap["serve_pool_token_capacity"] == 32 * 8
        assert snap["pool_blocks_peak"] == eng.pool.stats.peak_in_use
        assert snap["cache_lookup_tokens"] == \
            eng.prefix_cache.stats.lookup_tokens

    def test_chrome_trace_is_valid_and_loadable(self, setup, tmp_path):
        cfg, params = setup
        tel = Telemetry(clock=ManualClock(tick=1e-4))
        eng = _engine(cfg, params, tel)
        _drive(eng, n_req=2)
        p = tmp_path / "trace.json"
        tel.save_chrome_trace(str(p), meta={"arch": cfg.name})
        trace = jsonlib.loads(p.read_text())
        evs = trace["traceEvents"]
        assert trace["otherData"]["arch"] == cfg.name
        assert trace["otherData"]["dropped_events"] == 0
        phases = {e["name"] for e in evs if e["ph"] == "X"}
        assert {"step", "prefill", "decode", "drain"} <= phases
        for e in evs:
            assert e["ph"] in ("X", "i", "M")
            if e["ph"] == "X":
                assert e["ts"] >= 0 and e["dur"] > 0
        # one named lane per request plus the engine lane
        lanes = {e["tid"]: e["args"]["name"] for e in evs
                 if e["ph"] == "M" and e["name"] == "thread_name"}
        assert lanes[0] == "engine"
        assert {"req 0", "req 1"} <= set(lanes.values())
        # request lifecycle instants live on that request's lane
        instants = [e for e in evs if e["ph"] == "i"]
        assert {e["tid"] for e in instants} == {1, 2}

    def test_timeline_bounded_drops_counted(self, setup):
        cfg, params = setup
        tel = Telemetry(clock=ManualClock(tick=1e-4),
                        max_timeline_events=4)
        eng = _engine(cfg, params, tel)
        _drive(eng)
        assert len(tel.timeline.events) == 4
        assert tel.timeline.dropped > 0
        assert tel.timeline.to_chrome()["otherData"]["dropped_events"] \
            == tel.timeline.dropped

    def test_prometheus_export_of_live_run(self, setup, tmp_path):
        cfg, params = setup
        tel = Telemetry(clock=ManualClock(tick=1e-4))
        eng = _engine(cfg, params, tel)
        _drive(eng)
        p = tmp_path / "metrics.prom"
        tel.save_metrics(str(p))
        fams = parse_prometheus_text(p.read_text())
        for name in ("serve_ttft_seconds", "serve_tpot_seconds",
                     "serve_e2e_seconds", "serve_queue_wait_seconds",
                     "serve_step_seconds", "serve_requests_finished_total",
                     "serve_tokens_out", "pool_blocks_peak",
                     "cache_hit_rate"):
            assert name in fams, name

    def test_telemetry_does_not_change_tokens(self, setup):
        cfg, params = setup
        eng_off = _engine(cfg, params, None)
        eng_on = _engine(cfg, params, Telemetry(numerics_every=0))
        res_off = _drive(eng_off)
        res_on = _drive(eng_on)
        for rid in res_off:
            assert res_off[rid].tokens == res_on[rid].tokens

    def test_run_reset_rerun_reports_identically(self, setup):
        cfg, params = setup
        tel = Telemetry(clock=ManualClock(tick=1e-4))
        eng = _engine(cfg, params, tel)
        _drive(eng)
        first = dataclasses_asdict(eng.metrics)
        snap1 = tel.registry.snapshot()
        traces1 = [tr.to_dict() for tr in tel.finished_traces]

        eng.reset()
        # coherent zero: engine aggregates, pool/cache stats, telemetry
        assert eng.metrics.steps == 0 and eng.metrics.tokens_out == 0
        assert eng.pool.stats.peak_in_use == 0
        assert tel.c_finished.value == 0 and not tel.finished_traces
        assert tel.registry.snapshot()["serve_ttft_seconds"]["count"] == 0

        _drive(eng)
        second = dataclasses_asdict(eng.metrics)
        snap2 = tel.registry.snapshot()
        traces2 = [tr.to_dict() for tr in tel.finished_traces]
        # wall_s accumulates from a different clock base the second time,
        # so it matches only to float rounding; everything else exactly
        assert first.pop("wall_s") == pytest.approx(second.pop("wall_s"))
        assert first == second
        assert snap1.pop("serve_wall_seconds") == \
            pytest.approx(snap2.pop("serve_wall_seconds"))
        assert snap1 == snap2
        # per-request derived latencies identical; absolute stamps (and
        # req_ids — allocation is not an aggregate) shift
        for a, b in zip(traces1, traces2):
            for k in ("prompt_len", "n_tokens", "queue_wait", "ttft",
                      "e2e", "tpot_mean", "n_preemptions"):
                assert a[k] == pytest.approx(b[k]), k

    def test_reset_refuses_with_work_in_flight(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params, Telemetry(clock=ManualClock(tick=1e-4)))
        eng.submit(np.arange(1, 9, dtype=np.int32), 4)
        with pytest.raises(RuntimeError, match="in.?flight|queued"):
            eng.reset()
        eng.run()
        eng.reset()                     # fine once drained


class TestNumericsMonitor:
    def test_live_logit_error_within_paper_bound(self, setup):
        cfg, params = setup
        tel = Telemetry(clock=ManualClock(tick=1e-4), numerics_every=1,
                        numerics_max_tokens=16)
        eng = _engine(cfg, params, tel, kv_dtype="int8")
        assert eng.quantized
        _drive(eng, n_req=2)
        assert tel.c_probes.value == 2
        err = tel.registry.get("numerics_logit_error_max").value
        assert 0.0 < err <= 0.1         # PR 4's bounded-logit-error, live
        n = tel.registry.get("numerics_probe_tokens").value
        assert n == 16 and (int(n) & (int(n) - 1)) == 0   # pow2 prefix
        assert tel.registry.get("numerics_score_intmax_max").value > 0
        assert tel.registry.get("numerics_kv_amax_max").value > 0

    def test_probe_sampling_interval(self, setup):
        cfg, params = setup
        tel = Telemetry(clock=ManualClock(tick=1e-4), numerics_every=2,
                        numerics_max_tokens=16)
        eng = _engine(cfg, params, tel, kv_dtype="int8")
        _drive(eng, n_req=3)
        assert tel.c_probes.value == 2  # prefills 1 and 3 of 3

    def test_probe_noop_on_unquantized_engine(self, setup):
        cfg, params = setup
        tel = Telemetry(clock=ManualClock(tick=1e-4), numerics_every=1)
        eng = _engine(cfg, params, tel)
        assert not eng.quantized
        _drive(eng)
        assert tel.c_probes.value == 0
        assert tel.registry.get("numerics_logit_error") is None


class TestLogging:
    def _fresh(self, name):
        logging.getLogger(name).handlers.clear()
        return name

    def test_json_mode_emits_valid_json(self, capsys):
        from repro.utils.logging import get_logger
        log = get_logger(self._fresh("t.json"), json=True)
        log.info("hello %d", 7)
        out = capsys.readouterr().out.strip()
        rec = jsonlib.loads(out)
        assert rec["msg"] == "hello 7"
        assert rec["level"] == "INFO" and rec["logger"] == "t.json"
        assert "ts" in rec

    def test_no_double_emit_and_mode_switch_in_place(self, capsys):
        from repro.utils.logging import get_logger
        name = self._fresh("t.dedup")
        log = get_logger(name)
        get_logger(name)                 # second call must not re-attach
        log.info("once")
        lines = capsys.readouterr().out.strip().splitlines()
        assert len(lines) == 1 and lines[0].endswith(":: once")
        assert len(logging.getLogger(name).handlers) == 1
        log = get_logger(name, json=True)   # swap formatter, same handler
        assert len(logging.getLogger(name).handlers) == 1
        log.info("swapped")
        assert jsonlib.loads(
            capsys.readouterr().out.strip())["msg"] == "swapped"


class TestProvenance:
    def test_header_keys_and_mode(self):
        from benchmarks.provenance import provenance
        rec = provenance(mode="smoke")
        for k in ("git_commit", "timestamp_utc", "jax_version", "backend",
                  "device", "platform", "python"):
            assert k in rec, k
        assert rec["measurement_mode"] == "smoke"
        assert "measurement_mode" not in provenance()
        jsonlib.dumps(rec)               # artifact header must be JSON-able


def dataclasses_asdict(m):
    # run() stamps wall_s from the injected clock, so even it is
    # deterministic under ManualClock — the comparison stays fully strict
    import dataclasses
    return dataclasses.asdict(m)
