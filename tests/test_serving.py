"""Continuous-batching serving subsystem: pool, scheduler, paged kernel,
end-to-end engine equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode
from repro.kernels.flash_decode_paged import (flash_decode_paged,
                                              paged_decode_ref)
from repro.kernels.flash_decode_paged.ref import gather_kv
from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import (ContinuousEngine, PagedKVCache, PoolExhausted,
                         Scheduler, ServeEngine)

_rng = np.random.default_rng(11)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen3-4b"))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, params


class TestPagedKVCache:
    def _pool(self, cfg, n=8, bs=8):
        return PagedKVCache(cfg, num_blocks=n, block_size=bs)

    def test_alloc_free_roundtrip(self, setup):
        cfg, _ = setup
        pool = self._pool(cfg)
        blocks = pool.alloc(1, 3)
        assert len(blocks) == 3 and 0 not in blocks  # block 0 reserved
        assert pool.num_free == 5 and pool.utilization == 3 / 8
        assert pool.free(1) == 3
        assert pool.num_free == 8 and pool.stats.blocks_in_use == 0

    def test_oom_raises_and_leaves_pool_consistent(self, setup):
        cfg, _ = setup
        pool = self._pool(cfg, n=4)
        pool.alloc(1, 3)
        with pytest.raises(PoolExhausted):
            pool.alloc(2, 2)
        assert pool.num_free == 1            # failed alloc took nothing
        pool.alloc(2, 1)
        with pytest.raises(PoolExhausted):
            pool.append_block(2)

    def test_blocks_for_and_tables(self, setup):
        cfg, _ = setup
        pool = self._pool(cfg, bs=8)
        assert pool.blocks_for(1) == 1
        assert pool.blocks_for(8) == 1
        assert pool.blocks_for(9) == 2
        pool.alloc(7, 2)
        t = pool.table_array([7, 99], width=4)
        assert t.shape == (2, 4)
        assert list(t[0, :2]) == pool.blocks_of(7)
        assert t[0, 2:].tolist() == [0, 0] and t[1].tolist() == [0] * 4

    def test_pool_shape_has_garbage_block(self, setup):
        cfg, _ = setup
        pool = self._pool(cfg, n=6, bs=4)
        assert pool.k.shape == (cfg.n_layers, 7, cfg.n_kv_heads, 4,
                                cfg.head_dim_)


class TestScheduler:
    def _sched(self, cfg, n_blocks=8, bs=8, max_batch=4):
        pool = PagedKVCache(cfg, num_blocks=n_blocks, block_size=bs)
        return Scheduler(pool, max_batch=max_batch, max_len=64)

    def _prompt(self, n=8):
        return _rng.integers(1, 100, (n,)).astype(np.int32)

    def test_fifo_admission_order_and_capacity(self, setup):
        cfg, _ = setup
        s = self._sched(cfg, n_blocks=3, bs=8)
        r1 = s.submit(self._prompt(16), 4)   # 2 blocks
        r2 = s.submit(self._prompt(16), 4)   # 2 blocks — won't fit
        r3 = s.submit(self._prompt(8), 4)    # would fit, but FIFO blocks it
        admitted = s.admit()
        assert [r.req_id for r in admitted] == [r1.req_id]
        assert [r.req_id for r in s.waiting] == [r2.req_id, r3.req_id]

    def test_evict_returns_blocks_and_readmits(self, setup):
        cfg, _ = setup
        s = self._sched(cfg, n_blocks=2, bs=8)
        r1 = s.submit(self._prompt(16), 1)
        r2 = s.submit(self._prompt(16), 1)
        assert len(s.admit()) == 1
        r1.n_generated = 1                   # r1 done (max_new=1)
        done = s.evict_finished()
        assert done[0].req_id == r1.req_id and s.pool.num_free == 2
        assert [r.req_id for r in s.admit()] == [r2.req_id]

    def test_admission_reserves_whole_trajectory(self, setup):
        cfg, _ = setup
        s = self._sched(cfg, n_blocks=4, bs=8)
        r1 = s.submit(self._prompt(8), 8)    # 1 block now + 1 reserved
        r2 = s.submit(self._prompt(8), 8)
        r3 = s.submit(self._prompt(8), 8)    # trajectory won't fit
        assert [r.req_id for r in s.admit()] == [r1.req_id, r2.req_id]
        assert s.pool.num_free == 2          # but both are spoken for
        assert [r.req_id for r in s.waiting] == [r3.req_id]
        # growth draws down the reservation, never the safety net
        # (requests grow only once prefill completed and they joined decode)
        r1.state = r2.state = "decoding"
        r1.n_cached = r2.n_cached = 8
        assert s.ensure_decode_blocks() == []
        assert s.pool.num_free == 0

    def test_preemption_safety_net_victim_is_youngest(self, setup):
        """Reservation makes preemption unreachable in normal operation;
        overrunning a reservation (future features: ignore-eos, parallel
        sampling) must still preempt the youngest request."""
        cfg, _ = setup
        s = self._sched(cfg, n_blocks=4, bs=8)
        r1 = s.submit(self._prompt(8), 8)
        r2 = s.submit(self._prompt(8), 8)
        s.admit()
        r1.state = r2.state = "decoding"     # prefilled + joined the batch
        r1.tokens.append(1), r2.tokens.append(1)
        r1.n_generated = r2.n_generated = 1
        r1.n_cached = r2.n_cached = 8
        s.ensure_decode_blocks()             # both grow into reservations
        r1.n_cached = r2.n_cached = 16       # overrun: pool is now dry
        preempted = s.ensure_decode_blocks()
        assert [r.req_id for r in preempted] == [r2.req_id]
        assert r2.state == "queued" and r2.tokens == [] and \
            r2.n_preemptions == 1
        assert s.tokens_discarded == 1       # r2's generated token
        assert s.waiting[0].req_id == r2.req_id     # head of the queue
        assert [r.req_id for r in s.running] == [r1.req_id]

    def test_submit_rejects_trajectory_larger_than_pool(self, setup):
        cfg, _ = setup
        s = self._sched(cfg, n_blocks=3, bs=8)
        with pytest.raises(ValueError):
            s.submit(self._prompt(8), 24)    # needs 4 > 3 blocks

    def test_submit_rejects_over_max_len(self, setup):
        cfg, _ = setup
        s = self._sched(cfg)
        with pytest.raises(ValueError):
            s.submit(self._prompt(60), 10)   # 70 > max_len 64


class TestPagedDecodeKernel:
    @pytest.mark.parametrize("B,Hq,Hkv,D,BS,nb", [
        (2, 4, 2, 16, 8, 4), (3, 8, 1, 32, 16, 3), (1, 2, 2, 64, 32, 2),
    ])
    def test_matches_contiguous_on_ragged_lengths(self, B, Hq, Hkv, D, BS,
                                                  nb):
        N = B * nb + 1
        q = jnp.asarray(_rng.normal(size=(B, Hq, D)), jnp.float32) / \
            np.sqrt(D)
        kp = jnp.asarray(_rng.normal(size=(N, Hkv, BS, D)), jnp.float32)
        vp = jnp.asarray(_rng.normal(size=(N, Hkv, BS, D)), jnp.float32)
        # disjoint per-sequence tables over blocks 1..N-1 (0 = garbage)
        perm = _rng.permutation(np.arange(1, N))[:B * nb]
        bt = jnp.asarray(perm.reshape(B, nb), jnp.int32)
        lens = jnp.asarray(_rng.integers(1, nb * BS + 1, (B,)), jnp.int32)

        got = flash_decode_paged(q, kp, vp, bt, lens, interpret=True)
        k = gather_kv(kp, bt)
        v = gather_kv(vp, bt)
        want = flash_decode(q, k, v, lens, block_k=BS, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        ref = paged_decode_ref(q, kp, vp, bt, lens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5)

    def test_zero_length_rows_are_finite_zeros(self):
        q = jnp.asarray(_rng.normal(size=(2, 2, 16)), jnp.float32)
        kp = jnp.asarray(_rng.normal(size=(5, 2, 8, 16)), jnp.float32)
        vp = jnp.asarray(_rng.normal(size=(5, 2, 8, 16)), jnp.float32)
        bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
        out = flash_decode_paged(q, kp, vp, bt, jnp.zeros((2,), jnp.int32),
                                 interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.zeros_like(np.asarray(out)))


class TestContinuousEngine:
    def test_greedy_matches_static_engine(self, setup):
        cfg, params = setup
        prompts = _rng.integers(1, cfg.vocab_size, (2, 20)).astype(np.int32)
        static = ServeEngine(cfg, params, max_len=26).generate(prompts, 6)

        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=32,
                               max_batch=4, max_len=32)
        handles = [eng.submit(p, 6) for p in prompts]
        res = eng.run()
        for h, want in zip(handles, static.tokens):
            assert res[h.req_id].tokens == want.tolist()

    def test_mixed_lengths_and_streaming(self, setup):
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=32,
                               max_batch=4, max_len=48)
        lens = (5, 12, 24)
        handles = [eng.submit(
            _rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32), 4)
            for n in lens]
        streamed = {}
        res = eng.run(on_token=lambda rid, toks:
                      streamed.setdefault(rid, []).extend(toks))
        for h in handles:
            assert len(res[h.req_id].tokens) == 4
            assert streamed[h.req_id] == res[h.req_id].tokens
        # every block is either free or retained by the prefix-cache tree
        cached = eng.prefix_cache.cached_blocks
        assert eng.pool.stats.blocks_in_use == cached
        assert eng.pool.num_free + cached == 32
        assert eng.metrics.tok_per_s > 0

    def test_scarce_pool_queues_and_recovers(self, setup):
        """Pool holds one trajectory at a time: requests serialize through
        the FIFO (no preemption thrash) and all finish."""
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=4,
                               max_batch=4, max_len=48)
        handles = [eng.submit(
            _rng.integers(1, cfg.vocab_size, (16,)).astype(np.int32), 10)
            for _ in range(3)]
        res = eng.run()
        assert eng.metrics.preemptions == 0
        for h in handles:
            assert len(res[h.req_id].tokens) == 10
        # the tree keeps the last request's prompt blocks resident; the
        # rest of the scarce pool was evicted to admit each successor
        assert eng.pool.num_free + eng.prefix_cache.cached_blocks == 4
        assert eng.prefix_cache.stats.evictions > 0

    def test_mixed_temperature_batch(self, setup):
        """Greedy and sampled requests share one decode batch (the engine
        falls back to host-side sampling for the sampled rows)."""
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=32,
                               max_batch=4, max_len=32, seed=7)
        h_greedy = eng.submit(
            _rng.integers(1, cfg.vocab_size, (12,)).astype(np.int32), 5)
        h_sampled = eng.submit(
            _rng.integers(1, cfg.vocab_size, (12,)).astype(np.int32), 5,
            temperature=1.0)
        res = eng.run()
        for h in (h_greedy, h_sampled):
            toks = res[h.req_id].tokens
            assert len(toks) == 5
            assert all(0 <= t < cfg.vocab_size for t in toks)

    def test_unsupported_family_rejected(self, setup):
        cfg = reduce_config(get_config("rwkv6-7b"))
        with pytest.raises(ValueError):
            ContinuousEngine(cfg, params=None)
