import numpy as np
import pytest

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py uses 512 placeholders.


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_collection_modifyitems(config, items):
    """Skip Pallas *compiled* paths cleanly when no TPU backend is present.

    Tests marked ``tpu`` exercise the compiled kernels themselves; off-TPU
    they are skipped (not failed) — the same kernel dataflow still runs in
    CI through ``interpret=True``, and the serving dispatch falls back to
    the pure-JAX refs (``tests/test_prefill_paged.py`` asserts both
    fallbacks agree with the oracle, so a CPU-only box still validates the
    kernel math end to end)."""
    import jax
    if jax.default_backend() == "tpu":
        return
    skip = pytest.mark.skip(
        reason="no TPU backend: compiled Pallas paths run only on TPU "
               "(interpret-mode fallback is asserted separately)")
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip)
