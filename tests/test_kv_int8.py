"""Int8 quantized paged KV pool: quantize/dequantize roundtrip properties,
fused dequant-on-gather kernel parity, scales traveling with shared/copied
blocks, engine equality through decode / one-shot suffix prefill / chunked
prefill / COW fork / prefix-cache rehit, and the per-step prefill token
budget + partial-tail publishing satellites."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode_paged import (flash_decode_paged,
                                              gather_kv_dequant,
                                              gather_scales,
                                              paged_decode_ref)
from repro.kernels.flash_prefill_paged import (flash_prefill_paged,
                                               paged_prefill_ref,
                                               paged_prefill_split_ref)
from repro.models.attention import dequantize_kv, quantize_kv
from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import ContinuousEngine, PagedKVCache
from repro.serve.kv_pool import KV_DTYPES
from repro.serve.paged_step import paged_prefill, scatter_prefill
from repro.serve.scheduler import Scheduler

_rng = np.random.default_rng(31)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen3-4b"))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# Quantize/dequantize roundtrip (seeded sweep + hypothesis when available)
# ---------------------------------------------------------------------------


def _check_roundtrip(rows: jnp.ndarray) -> None:
    """The three storage invariants of the int8 pool rows:
    * round-to-nearest on the per-row grid — error <= scale/2 per value,
    * codes saturate in [-127, 127] with the amax element at +/-127,
    * re-quantization is code-exact (quantize . dequantize . quantize ==
      quantize), the invariant ``paged_step._fake_quant_kv`` relies on to
      let prefill attend rows the scatter then re-quantizes."""
    q, sc = quantize_kv(rows)
    assert q.dtype == jnp.int8
    scn = np.asarray(sc)
    assert (scn > 0).all()
    err = np.abs(np.asarray(dequantize_kv(q, sc, jnp.float32)) -
                 np.asarray(rows))
    assert (err <= scn[..., None] * 0.5 + 1e-7).all()
    qn = np.asarray(q, np.int32)
    assert qn.min() >= -127 and qn.max() <= 127
    amax = np.abs(np.asarray(rows)).max(-1)
    big = amax > 1e-5
    assert (np.abs(qn).max(-1)[big] == 127).all()
    fq = dequantize_kv(q, sc, jnp.float32)
    q2, sc2 = quantize_kv(fq)
    np.testing.assert_array_equal(qn, np.asarray(q2, np.int32))
    np.testing.assert_allclose(scn, np.asarray(sc2), rtol=1e-5)


class TestQuantizeRoundtrip:
    def test_seeded_random_rows(self):
        """No-dependency fallback for the hypothesis property test below:
        many seeded random row blocks through the same checker, spanning
        magnitudes from denormal-ish to saturating."""
        for seed in range(40):
            rng = np.random.default_rng(seed)
            r, d = int(rng.integers(1, 5)), int(rng.integers(1, 33))
            mag = 10.0 ** rng.uniform(-6, 2)
            _check_roundtrip(jnp.asarray(
                rng.normal(scale=mag, size=(r, d)), jnp.float32))

    def test_edge_rows(self):
        for rows in ([[0.0, 0.0]], [[1e-9, -1e-9]], [[127.0, -127.0]],
                     [[5.0]], [[-0.3, 0.3, 0.1499]]):
            _check_roundtrip(jnp.asarray(rows, jnp.float32))

    def test_hypothesis_roundtrip(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=40, deadline=None)
        @given(st.lists(
            st.lists(st.floats(-64.0, 64.0, allow_nan=False, width=32),
                     min_size=1, max_size=24),
            min_size=1, max_size=4).filter(
                lambda rs: len({len(r) for r in rs}) == 1))
        def run(rows):
            _check_roundtrip(jnp.asarray(np.asarray(rows, np.float32)))

        run()


# ---------------------------------------------------------------------------
# Pool storage: scales travel with blocks
# ---------------------------------------------------------------------------


def _fill_block(pool, block, seed):
    """Scatter one block of random K/V rows (quantize-on-scatter)."""
    cfg = pool.cfg
    rng = np.random.default_rng(seed)
    L, Hkv, BS, Dh = (cfg.n_layers, cfg.n_kv_heads, pool.block_size,
                      cfg.head_dim_)
    ks = jnp.asarray(rng.normal(size=(L, 1, Hkv, BS, Dh)), jnp.float32)
    vs = jnp.asarray(rng.normal(size=(L, 1, Hkv, BS, Dh)), jnp.float32)
    pool.k, pool.v, pool.k_scale, pool.v_scale = scatter_prefill(
        pool.k, pool.v, ks, vs, jnp.asarray([block], jnp.int32),
        pool.k_scale, pool.v_scale)


class TestInt8Pool:
    def test_kv_dtype_validation_and_shapes(self, setup):
        cfg, _ = setup
        with pytest.raises(ValueError):
            PagedKVCache(cfg, 4, 8, kv_dtype="fp8")
        pool = PagedKVCache(cfg, 6, 4, kv_dtype="int8")
        assert pool.quantized and pool.k.dtype == jnp.int8
        assert pool.k_scale.shape == (cfg.n_layers, 7, cfg.n_kv_heads, 4)
        assert pool.k_scale.dtype == jnp.float32
        plain = PagedKVCache(cfg, 6, 4)
        assert not plain.quantized and plain.k_scale is None
        assert set(KV_DTYPES) == {"auto", "bf16", "int8"}

    def test_equal_hbm_capacity_ratio(self, setup):
        """At production head dims the int8 pool holds >= 1.8x the tokens
        of a bf16 pool in the same HBM (per-row f32 scales included)."""
        cfg, _ = setup
        prod = cfg.replace(head_dim=64)
        b_bf16 = PagedKVCache.bytes_per_block(prod, 16, "bf16")
        b_int8 = PagedKVCache.bytes_per_block(prod, 16, "int8")
        assert b_bf16 / b_int8 >= 1.8
        # and the accounting matches the real arrays (usable + garbage blk)
        pool = PagedKVCache(cfg, 6, 4, kv_dtype="int8")
        assert pool.hbm_bytes == \
            7 * PagedKVCache.bytes_per_block(cfg, 4, "int8")

    def test_copy_block_carries_scales(self, setup):
        cfg, _ = setup
        pool = PagedKVCache(cfg, 6, 4, kv_dtype="int8")
        (a,) = pool.alloc(1, 1)
        _fill_block(pool, a, seed=7)
        (b,) = pool.alloc(2, 1)
        pool.copy_block(a, b)
        np.testing.assert_array_equal(np.asarray(pool.k[:, a]),
                                      np.asarray(pool.k[:, b]))
        np.testing.assert_array_equal(np.asarray(pool.k_scale[:, a]),
                                      np.asarray(pool.k_scale[:, b]))
        np.testing.assert_array_equal(np.asarray(pool.v_scale[:, a]),
                                      np.asarray(pool.v_scale[:, b]))
        assert np.asarray(pool.k_scale[:, a]).min() > 0

    def test_shared_blocks_gather_identical_rows(self, setup):
        """share() splices by reference: two tables that contain the same
        physical block dequantize identical rows — the scales are indexed
        by block id, so sharing carries them automatically."""
        cfg, _ = setup
        pool = PagedKVCache(cfg, 6, 4, kv_dtype="int8")
        (a,) = pool.alloc(1, 1)
        _fill_block(pool, a, seed=9)
        pool.share(2, [a])
        assert pool.refcount(a) == 2
        t1 = jnp.asarray([[a]], jnp.int32)
        g1 = gather_kv_dequant(pool.k[0], pool.k_scale[0], t1)
        g2 = gather_kv_dequant(pool.k[0], pool.k_scale[0],
                               jnp.asarray([[a]], jnp.int32))
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        s = gather_scales(pool.k_scale[0], t1)       # (1, Hkv, BS)
        np.testing.assert_array_equal(
            np.asarray(s)[0], np.asarray(pool.k_scale[0, a]))


# ---------------------------------------------------------------------------
# Fused dequant-on-gather kernel parity
# ---------------------------------------------------------------------------


def _int8_pool_arrays(B, Hkv, D, BS, W):
    N = B * W + 1
    kp = jnp.asarray(_rng.integers(-127, 128, (N, Hkv, BS, D)), jnp.int8)
    vp = jnp.asarray(_rng.integers(-127, 128, (N, Hkv, BS, D)), jnp.int8)
    ksc = jnp.asarray(_rng.uniform(0.004, 0.03, (N, Hkv, BS)), jnp.float32)
    vsc = jnp.asarray(_rng.uniform(0.004, 0.03, (N, Hkv, BS)), jnp.float32)
    bt = jnp.asarray(_rng.permutation(np.arange(1, N))[:B * W].reshape(B, W),
                     jnp.int32)
    return kp, vp, ksc, vsc, bt


class TestInt8KernelParity:
    @pytest.mark.parametrize("B,Hq,Hkv,D,BS,nb", [
        (2, 4, 2, 16, 8, 4), (3, 8, 1, 32, 16, 3),
    ])
    def test_decode_kernel_matches_ref(self, B, Hq, Hkv, D, BS, nb):
        kp, vp, ksc, vsc, bt = _int8_pool_arrays(B, Hkv, D, BS, nb)
        q = jnp.asarray(_rng.normal(size=(B, Hq, D)), jnp.float32) / \
            np.sqrt(D)
        lens = jnp.asarray(_rng.integers(1, nb * BS + 1, (B,)), jnp.int32)
        got = flash_decode_paged(q, kp, vp, bt, lens, k_scale=ksc,
                                 v_scale=vsc, interpret=True)
        want = paged_decode_ref(q, kp, vp, bt, lens, k_scale=ksc,
                                v_scale=vsc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        # and the int8 ref equals the dense ref on the dequantized cache
        kd = gather_kv_dequant(kp, ksc, bt)
        vd = gather_kv_dequant(vp, vsc, bt)
        from repro.kernels.flash_decode.ref import decode_ref
        dense = decode_ref(q, kd, vd, lens)
        np.testing.assert_allclose(np.asarray(want), np.asarray(dense),
                                   atol=1e-5)

    @pytest.mark.parametrize("Sq,pos0,bq", [(7, 5, 8), (16, 21, 8),
                                            (33, 13, 16)])
    def test_prefill_kernel_matches_ref(self, Sq, pos0, bq):
        B, Hq, Hkv, D, BS = 2, 4, 2, 16, 8
        W = -(-(pos0 + Sq) // BS)
        kp, vp, ksc, vsc, bt = _int8_pool_arrays(B, Hkv, D, BS, W)
        q = jnp.asarray(_rng.normal(size=(B, Hq, Sq, D)), jnp.float32) / \
            np.sqrt(D)
        p0 = jnp.asarray([pos0, max(pos0 - 3, 0)], jnp.int32)
        got = flash_prefill_paged(q, kp, vp, bt, p0, k_scale=ksc,
                                  v_scale=vsc, interpret=True, block_q=bq)
        want = paged_prefill_ref(q, kp, vp, bt, p0, k_scale=ksc,
                                 v_scale=vsc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        cq = -(-Sq // BS)
        split = paged_prefill_split_ref(q, kp, vp, bt, p0,
                                        tail_blocks=2 * cq + 1,
                                        k_scale=ksc, v_scale=vsc)
        np.testing.assert_allclose(np.asarray(split), np.asarray(want),
                                   atol=1e-5)

    @pytest.mark.tpu
    def test_compiled_matches_interpret(self):
        B, Hq, Hkv, D, BS, Sq, pos0 = 1, 4, 2, 128, 16, 32, 24
        W = -(-(pos0 + Sq) // BS)
        kp, vp, ksc, vsc, bt = _int8_pool_arrays(B, Hkv, D, BS, W)
        q = jnp.asarray(_rng.normal(size=(B, Hq, Sq, D)), jnp.float32) / \
            np.sqrt(D)
        p0 = jnp.asarray([pos0], jnp.int32)
        got = flash_prefill_paged(q, kp, vp, bt, p0, k_scale=ksc,
                                  v_scale=vsc)
        want = flash_prefill_paged(q, kp, vp, bt, p0, k_scale=ksc,
                                   v_scale=vsc, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        qd = jnp.asarray(_rng.normal(size=(B, Hq, D)), jnp.float32) / \
            np.sqrt(D)
        lens = jnp.asarray([pos0 + Sq], jnp.int32)
        gd = flash_decode_paged(qd, kp, vp, bt, lens, k_scale=ksc,
                                v_scale=vsc)
        wd = flash_decode_paged(qd, kp, vp, bt, lens, k_scale=ksc,
                                v_scale=vsc, interpret=True)
        np.testing.assert_allclose(np.asarray(gd), np.asarray(wd),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# Engine: int8 through every serving path
# ---------------------------------------------------------------------------


def _run(cfg, params, prompts, max_new=6, **kw):
    kw.setdefault("block_size", 8)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 96)
    eng = ContinuousEngine(cfg, params, **kw)
    hs = [eng.submit(p, max_new) for p in prompts]
    res = eng.run()
    return [res[h.req_id].tokens for h in hs], eng


class TestInt8Engine:
    def test_bounded_logit_error_vs_full_precision(self, setup):
        """The documented accuracy guardrail: per-row int8 storage with
        fp32 accumulation perturbs prefill logits by well under 0.05 on
        the reduced config — greedy outputs only flip where the top-2 gap
        is inside that noise band."""
        cfg, params = setup
        for n in (5, 20, 37, 64):
            p = jnp.asarray(
                _rng.integers(1, cfg.vocab_size, (1, n)), jnp.int32)
            last = jnp.asarray([n - 1], jnp.int32)
            lg_f, _, _ = paged_prefill(params, p, last, cfg)
            lg_q, _, _ = paged_prefill(params, p, last, cfg,
                                       kv_quantize=True)
            err = np.abs(np.asarray(lg_f) - np.asarray(lg_q)).max()
            assert err <= 0.05, f"prompt len {n}: logit error {err}"

    def test_one_shot_greedy_matches_bf16(self, setup):
        """Greedy equality on prompts whose top-2 logit gaps exceed the
        quantization noise (the generic case for trained checkpoints;
        this seed's gaps are 0.08-0.42 vs <= 0.05 noise)."""
        cfg, params = setup
        rng = np.random.default_rng(5)
        prompts = [rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
                   for n in (5, 37, 64)]
        full, _ = _run(cfg, params, prompts)
        q8, eng = _run(cfg, params, prompts, kv_dtype="int8")
        assert full == q8
        assert eng.quantized and eng.metrics.kv_dtype == "int8"
        assert eng.metrics.pool_token_capacity == 64 * 8

    def test_int8_self_consistent_across_all_paths(self, setup):
        """Decode, one-shot suffix prefill, chunked prefill, COW fork and
        prefix-cache rehit must produce identical greedy streams within
        int8 mode: every path reads the same quantized codes (fake-quant
        at dense prefill, quantize-on-scatter elsewhere)."""
        cfg, params = setup
        rng = np.random.default_rng(5)
        shared = rng.integers(1, cfg.vocab_size, (21,)).astype(np.int32)
        prompts = [np.concatenate(
            [shared, rng.integers(1, cfg.vocab_size, (n,))]).astype(
                np.int32) for n in (13, 30, 7)]
        cold, _ = _run(cfg, params, prompts, kv_dtype="int8",
                       prefix_cache=False)
        cached, e1 = _run(cfg, params, prompts, kv_dtype="int8")
        chunked, e2 = _run(cfg, params, prompts, kv_dtype="int8",
                           prefill_chunk=16)
        assert cold == cached == chunked
        assert e1.metrics.cow_copies >= 1          # mid-block fork taken
        assert e1.metrics.prefix_hit_tokens > 0    # rehit path taken
        assert e2.metrics.prefill_chunks > len(prompts)

    def test_multi_turn_rehit_matches_cold(self, setup):
        """Generated-token publishing + readmission with an int8 pool: the
        follow-up turn reuses quantized K/V of both the prompt and the
        reply, and still decodes exactly like a cold int8 engine."""
        cfg, params = setup
        rng = np.random.default_rng(3)
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                               max_batch=4, max_len=96, prefill_chunk=16,
                               kv_dtype="int8")
        pA = rng.integers(1, cfg.vocab_size, (19,)).astype(np.int32)
        h1 = eng.submit(pA, 12)
        r1 = eng.run()
        follow = np.concatenate(
            [pA, np.asarray(r1[h1.req_id].tokens, np.int32),
             rng.integers(1, cfg.vocab_size, (7,))]).astype(np.int32)
        hit0 = eng.metrics.prefix_hit_tokens
        h2 = eng.submit(follow, 4)
        r2 = eng.run()
        assert eng.metrics.prefix_hit_tokens - hit0 >= 24
        cold, _ = _run(cfg, params, [follow], max_new=4, kv_dtype="int8",
                       prefix_cache=False)
        assert r2[h2.req_id].tokens == cold[0]

    def test_interpret_kernel_path_end_to_end(self, setup):
        """The Pallas kernels (interpret mode on CPU) serve the int8 pool
        through decode + chunked prefill with the same outputs as the
        pure-JAX refs."""
        cfg, params = setup
        icfg = cfg.replace(interpret_kernels=True)
        rng = np.random.default_rng(2)
        prompts = [rng.integers(1, cfg.vocab_size, (9,)).astype(np.int32)]
        kw = dict(num_blocks=16, max_len=24, max_new=3, prefill_chunk=8,
                  kv_dtype="int8")
        ref_toks, _ = _run(cfg, params, prompts, **kw)
        krn_toks, _ = _run(icfg, params, prompts, **kw)
        assert ref_toks == krn_toks


# ---------------------------------------------------------------------------
# Satellites: prefill budget + partial-tail publishing
# ---------------------------------------------------------------------------


class TestPrefillBudget:
    def test_chunk_schedule_caps_total_tokens(self, setup):
        cfg, _ = setup
        pool = PagedKVCache(cfg, num_blocks=64, block_size=8)
        s = Scheduler(pool, max_batch=8, max_len=256)
        rng = np.random.default_rng(0)
        for n in (64, 64, 64, 9):
            s.submit(rng.integers(1, 100, (n,)).astype(np.int32), 4)
        s.admit()
        assert len(s.prefilling) == 4
        # unbudgeted: everyone deals a chunk
        assert len(s.chunk_schedule(16, 0)) == 4
        # 40-token budget: two 16-token chunks fit, the third would overrun
        sched = s.chunk_schedule(16, 40)
        assert [r.req_id for r in sched] == [0, 1]
        # oldest always advances even when its chunk alone exceeds budget
        assert len(s.chunk_schedule(16, 4)) == 1
        # ragged final chunk counts its true size: 9-token prompt fits
        s.prefilling[0].n_prefilled = 64
        s.prefilling[1].n_prefilled = 64
        s.prefilling[2].n_prefilled = 64
        del s.running[:3]
        assert len(s.chunk_schedule(16, 12)) == 1

    def test_budget_paces_prefill_without_changing_outputs(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(17)
        prompts = [rng.integers(1, cfg.vocab_size, (72,)).astype(np.int32)
                   for _ in range(3)]
        kw = dict(num_blocks=64, max_len=96, prefill_chunk=8, max_new=4,
                  max_admit_per_step=4)
        free, _ = _run(cfg, params, prompts, **kw)
        capped, eng = _run(cfg, params, prompts, prefill_budget=8, **kw)
        assert free == capped
        # with 3 concurrent 72-token prompts at chunk 8 and an 8-token
        # per-step budget, prefill must spread over >= 27 chunk steps
        assert eng.metrics.steps > eng.metrics.prefill_chunks >= 27

    def test_budgeted_prefill_keeps_decode_alive(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(23)
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                               max_batch=4, max_len=128, prefill_chunk=8,
                               prefill_budget=8, max_admit_per_step=4)
        short = eng.submit(
            rng.integers(1, cfg.vocab_size, (8,)).astype(np.int32), 16)
        eng.step()
        assert short.state == "decoding"
        longs = [eng.submit(
            rng.integers(1, cfg.vocab_size, (64,)).astype(np.int32), 4)
            for _ in range(2)]
        decoded_during_prefill = 0
        for _ in range(60):
            n0 = short.n_generated
            eng.step()
            if any(r.state == "prefill" for r in longs) and \
                    short.n_generated > n0:
                decoded_during_prefill += 1
            if all(r.state not in ("queued", "prefill") for r in longs):
                break
        # the budget admits one 8-token chunk per step: decode advanced on
        # (nearly) every one of the >= 16 prefill steps
        assert decoded_during_prefill >= 12
        eng.run()


@pytest.mark.slow
class TestBenchSmoke:
    def test_kv_int8_bench_smoke(self):
        """The benchmark's CI mode: equal-HBM pools, greedy equality and
        the bounded-logit-error guardrail on a tiny workload; the capacity
        and tok/s ratios are reported, not gated."""
        import pathlib
        import sys
        root = pathlib.Path(__file__).resolve().parent.parent
        sys.path.insert(0, str(root / "benchmarks"))
        try:
            import kv_int8_bench
            ratio = kv_int8_bench.main(["--smoke"])
        finally:
            sys.path.pop(0)
        assert ratio > 0


class TestPartialTailPublish:
    def test_mid_prefill_partial_tail_is_published(self, setup):
        """A prompt whose chunked prefill runs mid-block (COW splice at a
        non-aligned prefix) publishes its partial tail every chunk: a twin
        admitted mid-prefill matches the tail rows too, not just the full
        blocks."""
        cfg, params = setup
        rng = np.random.default_rng(41)
        P = rng.integers(1, cfg.vocab_size, (61,)).astype(np.int32)
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                               max_batch=4, max_len=96, prefill_chunk=16)
        h1 = eng.submit(P[:21], 2)         # publishes 21 = 2 blocks + 5 tail
        eng.run()
        assert eng.prefix_cache.lookup(P) == 21
        eng.submit(P, 2)
        eng.step()                         # admit (hit 21, COW) + chunk 1
        # chunk 1 covers [21, 37): 4 full blocks + a 5-row partial tail —
        # all 37 prefilled tokens must be visible to a twin right now
        assert eng.prefix_cache.lookup(P) == 37
        eng.run()
        assert eng.prefix_cache.lookup(P) >= 60

    def test_twin_admitted_mid_prefill_gets_tail_hit(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(43)
        P = rng.integers(1, cfg.vocab_size, (61,)).astype(np.int32)
        outs = {}
        for twin_mid in (False, True):
            eng = ContinuousEngine(cfg, params, block_size=8,
                                   num_blocks=64, max_batch=4, max_len=96,
                                   prefill_chunk=16, max_admit_per_step=1)
            eng.submit(P[:21], 2)
            res = dict(eng.run())
            hb = eng.submit(P, 4)
            if twin_mid:
                eng.step()                 # b mid-prefill (one chunk in)
                hc = eng.submit(P, 4)      # twin of an in-flight prompt
            else:
                res.update(eng.run())
                hc = eng.submit(P, 4)
            res.update(eng.run())
            outs[twin_mid] = res[hb.req_id].tokens + res[hc.req_id].tokens
            # the twin's hit includes b's published partial tail (>= 37
            # when admitted mid-prefill; the full 60 after b finished)
            assert hc.n_prefix_hit >= 37, hc.n_prefix_hit
        assert outs[False] == outs[True]
