"""Distributed attention/pipeline tests (8 virtual devices, subprocess)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> str:
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestRingAttention:
    def test_matches_chunked_and_differentiable(self):
        out = run_sub("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.parallel.ring_attention import ring_attention
            from repro.models.attention import chunked_attention
            rng = np.random.default_rng(0)
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            B, Hq, Hkv, S, D = 2, 4, 2, 64, 16
            q = jnp.asarray(rng.normal(size=(B,Hq,S,D)), jnp.float32) / 4
            k = jnp.asarray(rng.normal(size=(B,Hkv,S,D)), jnp.float32)
            v = jnp.asarray(rng.normal(size=(B,Hkv,S,D)), jnp.float32)
            for causal in (True, False):
                got = jax.jit(lambda q,k,v: ring_attention(
                    q,k,v,mesh,causal=causal))(q,k,v)
                want = chunked_attention(q,k,v,causal=causal,intmax=True,
                                         chunk=16)
                assert float(jnp.abs(got-want).max()) < 2e-5
            g = jax.grad(lambda q: jnp.sum(ring_attention(
                q,k,v,mesh,causal=True)**2))(q)
            assert bool(jnp.all(jnp.isfinite(g)))
            print("OK")
        """)
        assert "OK" in out

    def test_distributed_softermax_renorm_is_exact(self):
        """The cross-chip combine uses integer-exponent rescales: the ring
        result equals the single-device closed form bit-for-bit-tolerance
        even with adversarial score magnitudes."""
        out = run_sub("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.parallel.ring_attention import ring_attention
            from repro.models.attention import chunked_attention
            mesh = jax.make_mesh((1, 8), ("data", "model"))
            rng = np.random.default_rng(1)
            q = jnp.asarray(rng.normal(size=(1,2,64,16)) * 8, jnp.float32)
            k = jnp.asarray(rng.normal(size=(1,2,64,16)) * 8, jnp.float32)
            v = jnp.asarray(rng.normal(size=(1,2,64,16)), jnp.float32)
            got = jax.jit(lambda q,k,v: ring_attention(
                q,k,v,mesh,causal=True))(q,k,v)
            want = chunked_attention(q,k,v,causal=True,intmax=True,chunk=8)
            assert float(jnp.abs(got-want).max()) < 5e-5
            print("OK")
        """)
        assert "OK" in out


class TestPipeline:
    def test_gpipe_matches_sequential(self):
        out = run_sub("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.parallel.pipeline import pipeline_apply
            from repro.models.registry import get_config, reduce_config
            from repro.models import lm as lm_mod
            from repro.models.schema import init_params
            mesh = jax.make_mesh((4, 2), ("pod", "data"))
            cfg = reduce_config(get_config("llama3.2-3b")).replace(
                n_layers=8, remat="none")
            params = init_params(jax.random.PRNGKey(0), lm_mod.lm_schema(cfg))
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.normal(size=(8, 16, cfg.d_model)) * 0.1,
                            jnp.float32)
            def stage_fn(layer_params, x):
                S = x.shape[1]
                pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                       (x.shape[0], S))
                def body(x, bp):
                    x, _ = lm_mod._block_apply(bp, x, cfg, pos, False)
                    return x, None
                return jax.lax.scan(body, x, layer_params)[0]
            want = stage_fn(params["blocks"], x)
            got = jax.jit(lambda p, x: pipeline_apply(
                p, x, mesh, stage_fn, microbatches=4))(params["blocks"], x)
            rel = float(jnp.abs(got - want).max()) / float(
                jnp.abs(want).max())
            assert rel < 5e-4, rel   # float reassociation across partitions
            g = jax.grad(lambda p: jnp.sum(pipeline_apply(
                p, x, mesh, stage_fn, microbatches=4) ** 2))(
                params["blocks"])
            assert all(bool(jnp.all(jnp.isfinite(l)))
                       for l in jax.tree_util.tree_leaves(g))
            print("OK rel", rel)
        """)
        assert "OK" in out

    def test_microbatch_count_invariance(self):
        """Different microbatch counts give the same result (schedule-only)."""
        out = run_sub("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.parallel.pipeline import pipeline_apply
            mesh = jax.make_mesh((4, 2), ("pod", "data"))
            # toy stage: affine per layer
            L, d = 8, 16
            rng = np.random.default_rng(0)
            w = jnp.asarray(rng.normal(size=(L, d, d)) * 0.1, jnp.float32)
            x = jnp.asarray(rng.normal(size=(8, 4, d)), jnp.float32)
            def stage_fn(ws, x):
                def body(x, wi):
                    return jnp.tanh(x @ wi), None
                return jax.lax.scan(body, x, ws)[0]
            outs = [jax.jit(lambda w, x, m=m: pipeline_apply(
                w, x, mesh, stage_fn, microbatches=m))(w, x)
                for m in (2, 4, 8)]
            for o in outs[1:]:
                np.testing.assert_allclose(np.asarray(outs[0]),
                                           np.asarray(o), atol=1e-6)
            print("OK")
        """)
        assert "OK" in out
