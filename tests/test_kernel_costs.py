"""Kernel cost observatory: the analytic launch-cost model pinned
byte-exact against the ref layer's measuring oracles, the grid planner's
argmin/memoization properties, and the engine's per-step integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode_paged.ref import (decode_gather_oracle,
                                                  split_layout)
from repro.kernels.flash_prefill_paged.ref import prefill_gather_oracle
from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import (AUTOTUNE_MODES, ContinuousEngine, CostParams,
                         GridPlanner, Telemetry, decode_launch_cost,
                         default_candidates, estimate_seconds,
                         prefill_launch_cost)

_rng = np.random.default_rng(11)

Hq, Hkv, D, BS = 8, 2, 64, 16
SHAPE = dict(n_q_heads=Hq, n_kv_heads=Hkv, head_dim=D, block_size=BS)


def _pools(n_blocks, dtype):
    if dtype == "int8":
        k = _rng.integers(-127, 128, (n_blocks, Hkv, BS, D)).astype(np.int8)
        v = _rng.integers(-127, 128, (n_blocks, Hkv, BS, D)).astype(np.int8)
        ks = _rng.random((n_blocks, Hkv, BS)).astype(np.float32)
        return (jnp.asarray(k), jnp.asarray(v),
                jnp.asarray(ks), jnp.asarray(ks))
    k = _rng.standard_normal((n_blocks, Hkv, BS, D))
    arr = jnp.asarray(k, dtype=jnp.dtype(dtype))
    return arr, arr, None, None


def _table(B, W, n_blocks, lengths):
    """Exact-cover tables: row i holds ceil(len/BS) real entries, rest 0."""
    bt = np.zeros((B, W), np.int32)
    for i, ln in enumerate(lengths):
        nb = min(-(-int(ln) // BS), W)
        bt[i, :nb] = _rng.integers(1, n_blocks, (nb,))
    return jnp.asarray(bt)


class TestDecodeModelMatchesOracle:
    @pytest.mark.parametrize("dtype", ["float32", "int8"])
    @pytest.mark.parametrize("tile,split", [(1, 1), (2, 1), (4, 1),
                                            (1, 2), (2, 2), (4, 3),
                                            (16, 1), (1, 4)])
    def test_gather_waste_steps_exact(self, dtype, tile, split):
        B, W, n_blocks = 5, 12, 64
        lengths = np.array([1, 17, 64, 190, 7], np.int64)
        k, v, ks, vs = _pools(n_blocks, dtype)
        bt = _table(B, W, n_blocks, lengths)
        oracle = decode_gather_oracle(k, v, bt, lengths,
                                      kv_tile_blocks=tile, split_k=split,
                                      k_scale=ks, v_scale=vs)
        model = decode_launch_cost(lengths, W, kv_tile_blocks=tile,
                                   split_k=split, kv_dtype=dtype, **SHAPE)
        assert model.gather_bytes == oracle["gather_bytes"]
        assert model.waste_bytes == oracle["waste_bytes"]
        assert model.useful_bytes == oracle["useful_bytes"]
        assert model.grid_steps == oracle["grid_steps"]
        _, _, _, Wp = split_layout(W, tile, split)
        assert Wp == oracle["padded_width"]

    def test_random_geometry_sweep(self):
        for _ in range(25):
            B = int(_rng.integers(1, 7))
            W = int(_rng.integers(1, 40))
            tile = int(_rng.integers(1, 9))
            split = int(_rng.integers(1, 5))
            lengths = _rng.integers(1, W * BS + 1, (B,))
            k, v, _, _ = _pools(48, "float32")
            bt = _table(B, W, 48, lengths)
            oracle = decode_gather_oracle(k, v, bt, lengths,
                                          kv_tile_blocks=tile,
                                          split_k=split)
            model = decode_launch_cost(lengths, W, kv_tile_blocks=tile,
                                       split_k=split, **SHAPE)
            assert model.gather_bytes == oracle["gather_bytes"]
            assert model.waste_bytes == oracle["waste_bytes"]
            assert model.grid_steps == oracle["grid_steps"]

    def test_waste_zero_iff_no_padding(self):
        # every row exactly fills the unpadded, un-bucketed table and the
        # grid needs no tile/split padding -> zero waste
        B, W = 3, 8
        lengths = np.full((B,), W * BS, np.int64)
        model = decode_launch_cost(lengths, W, kv_tile_blocks=2, split_k=2,
                                   **SHAPE)
        _, _, _, Wp = split_layout(W, 2, 2)
        assert Wp == W and model.waste_bytes == 0
        # any shortfall (a freed block, or a padded grid) -> strictly
        # positive; waste is block-granular, so drop a full block
        short = lengths.copy()
        short[0] -= BS
        assert decode_launch_cost(short, W, kv_tile_blocks=2, split_k=2,
                                  **SHAPE).waste_bytes > 0
        assert decode_launch_cost(lengths, W, kv_tile_blocks=3, split_k=1,
                                  **SHAPE).waste_bytes > 0

    def test_int8_scale_siblings_counted(self):
        lengths = np.array([40, 8], np.int64)
        f32 = decode_launch_cost(lengths, 4, kv_dtype="float32", **SHAPE)
        i8 = decode_launch_cost(lengths, 4, kv_dtype="int8", **SHAPE)
        # int8 blocks: quarter the values + the f32 scale rows
        _, _, _, Wp = split_layout(4, 1, 1)
        blocks = 2 * Hkv * Wp
        assert i8.gather_bytes == f32.gather_bytes // 4 + blocks * 2 * BS * 4

    def test_scaled_multiplies_extensive_fields_only(self):
        c = decode_launch_cost(np.array([33]), 4, **SHAPE)
        s = c.scaled(3)
        assert s.gather_bytes == 3 * c.gather_bytes
        assert s.flops == 3 * c.flops
        assert s.grid_steps == 3 * c.grid_steps
        assert s.tile_bytes == c.tile_bytes
        assert s.vmem_bytes == c.vmem_bytes
        d = c.to_dict()
        assert d["useful_bytes"] == c.gather_bytes - c.waste_bytes

    def test_unknown_dtype_rejected(self):
        with pytest.raises(ValueError, match="kv_dtype"):
            decode_launch_cost(np.array([8]), 2, kv_dtype="fp4", **SHAPE)


class TestPrefillModelMatchesOracle:
    @pytest.mark.parametrize("dtype", ["float32", "int8"])
    @pytest.mark.parametrize("tile", [1, 2, 4])
    @pytest.mark.parametrize("q_len,block_q", [(32, 128), (200, 128),
                                               (64, 32)])
    def test_gather_waste_steps_exact(self, dtype, tile, q_len, block_q):
        B, n_blocks = 3, 64
        pos0 = np.array([0, 48, 16], np.int64)
        cover = [-(-int(p + q_len) // BS) for p in pos0]
        W = max(cover) + 2                       # some rows padded
        k, v, ks, vs = _pools(n_blocks, dtype)
        bt = _table(B, W, n_blocks, [c * BS for c in cover])
        oracle = prefill_gather_oracle(k, v, bt, pos0, q_len,
                                       kv_tile_blocks=tile, block_q=block_q,
                                       cover_blocks=cover,
                                       k_scale=ks, v_scale=vs)
        model = prefill_launch_cost(q_len, pos0, cover, W,
                                    kv_tile_blocks=tile, block_q=block_q,
                                    kv_dtype=dtype, **SHAPE)
        assert model.gather_bytes == oracle["gather_bytes"]
        assert model.waste_bytes == oracle["waste_bytes"]
        assert model.useful_bytes == oracle["useful_bytes"]
        assert model.grid_steps == oracle["grid_steps"]

    def test_waste_zero_iff_exact_cover(self):
        pos0, q_len = [0], 4 * BS
        cover = [4]
        model = prefill_launch_cost(q_len, pos0, cover, 4, **SHAPE)
        assert model.waste_bytes == 0
        padded = prefill_launch_cost(q_len, pos0, cover, 6, **SHAPE)
        assert padded.waste_bytes > 0

    def test_misaligned_rows_rejected(self):
        with pytest.raises(ValueError, match="align"):
            prefill_launch_cost(32, [0, 1], [2], 4, **SHAPE)


class TestEstimateSeconds:
    def test_monotone_in_length(self):
        # compute-bound machine point: the gather DMA is unconditional over
        # the padded width, so only the @pl.when-gated FLOPs see the length
        p = CostParams(flops_per_s=5e10)
        costs = [decode_launch_cost(np.array([ln]), 16, **SHAPE)
                 for ln in (8, 64, 200)]
        secs = [estimate_seconds(c, p) for c in costs]
        assert secs == sorted(secs) and secs[0] < secs[-1]

    def test_split_k_helps_long_row_with_cores(self):
        # one long row: split-K halves the sequential walk when there are
        # cores to absorb the extra lanes
        lengths = np.array([64 * BS], np.int64)
        p = CostParams(cores=8)
        t1 = estimate_seconds(decode_launch_cost(lengths, 64, **SHAPE), p)
        t4 = estimate_seconds(decode_launch_cost(lengths, 64, split_k=4,
                                                 **SHAPE), p)
        assert t4 < t1


class TestGridPlanner:
    CANDS = [(1, 1), (2, 1), (4, 1), (2, 2)]

    def _planner(self, **kw):
        return GridPlanner(self.CANDS, kv_dtype="float32", **SHAPE, **kw)

    def test_argmin_never_loses_to_any_fixed_candidate(self):
        pl = self._planner()
        for _ in range(20):
            B = int(_rng.integers(1, 6))
            W = int(_rng.integers(1, 33))
            lengths = _rng.integers(1, W * BS + 1, (B,))
            dec = pl.plan_decode(lengths, W)
            for (t, s) in self.CANDS:
                c = decode_launch_cost(lengths, W, kv_tile_blocks=t,
                                       split_k=s, **SHAPE)
                assert dec.predicted_s <= estimate_seconds(
                    c, pl.cost_params) + 1e-15
            assert (dec.kv_tile_blocks, dec.split_k) in self.CANDS
            assert len(dec.considered) == len(self.CANDS)

    def test_memoizes_on_block_counts_not_raw_lengths(self):
        pl = self._planner()
        d1 = pl.plan_decode(np.array([17, 33]), 8)
        # same per-row block counts (ceil/BS), different raw lengths
        d2 = pl.plan_decode(np.array([20, 44]), 8)
        assert d2 is d1
        assert len(pl._cache) == 1
        d3 = pl.plan_decode(np.array([17, 49]), 8)   # crosses a block
        assert d3 is not d1

    def test_decisions_recorded_to_registry(self):
        from repro.serve import MetricRegistry
        reg = MetricRegistry()
        pl = self._planner(registry=reg)
        pl.plan_decode(np.array([40]), 4)
        pl.plan_decode(np.array([40]), 4)            # cache hit still counts
        assert reg.get("autotune_decisions_total").value == 2
        assert sum(v for k, v in pl.summary().items()) == 2
        pl.observe_measured(pl.plan_decode(np.array([40]), 4), 1e-3)
        assert reg.get("autotune_pred_over_measured").value > 0

    def test_default_candidates_closed_and_deduped(self):
        cands = default_candidates(4, 2)
        assert set(cands) == {(1, 1), (4, 1), (1, 2), (4, 2)}
        assert default_candidates(1, 1) == ((1, 1),)
        with pytest.raises(ValueError):
            GridPlanner([(0, 1)], kv_dtype="float32", **SHAPE)


class TestEngineIntegration:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = reduce_config(get_config("qwen3-4b"))
        params = model_fns(cfg).init(jax.random.PRNGKey(0))
        return cfg, params

    def _run(self, cfg, params, tel=None, **kw):
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=48,
                               max_batch=4, max_len=64, telemetry=tel,
                               **kw)
        rng = np.random.default_rng(5)
        hs = [eng.submit(rng.integers(1, 100, (n,)).astype(np.int32), 5)
              for n in (9, 21, 13)]
        res = eng.run()
        return [res[h.req_id].tokens for h in hs], eng

    def test_autotune_modes_same_tokens_and_decisions(self, setup):
        cfg, params = setup
        streams = {}
        for mode in AUTOTUNE_MODES:
            toks, eng = self._run(cfg, params, autotune=mode,
                                  kv_tile_blocks=2, decode_split_k=2)
            streams[mode] = toks
            if mode == "off":
                assert eng.planner is None
            elif mode == "per-step":
                assert sum(eng.planner.summary().values()) > 0
        assert streams["off"] == streams["static"] == streams["per-step"]

    def test_invalid_mode_rejected(self, setup):
        cfg, params = setup
        with pytest.raises(ValueError, match="autotune"):
            ContinuousEngine(cfg, params, block_size=8, num_blocks=48,
                             max_batch=4, max_len=64, autotune="always")

    def test_kernel_cost_metrics_published(self, setup):
        cfg, params = setup
        from repro.serve import ManualClock
        tel = Telemetry(clock=ManualClock(tick=1e-4))
        _, eng = self._run(cfg, params, tel=tel, autotune="per-step")
        reg = tel.registry
        dma = reg.get("kernel_dma_bytes_total").value
        waste = reg.get("kernel_waste_bytes_total").value
        assert dma > 0 and reg.get("kernel_flops_total").value > 0
        assert 0 <= waste < dma
        assert reg.get("kernel_launch_dma_bytes").count > 0
        assert reg.get("autotune_decisions_total").value > 0
        # decode timeline slices carry the per-phase cost stamp
        decode_evs = [e for e in tel.timeline.events
                      if e["name"] == "decode"]
        assert decode_evs
        for e in decode_evs:
            assert e["args"]["dma_bytes"] > 0
            assert e["args"]["flops"] > 0
        # counter totals == sum over timeline-stamped phases (all phases
        # that ran a paged kernel are decode slices in this one-shot
        # prefill engine)
        assert sum(e["args"]["dma_bytes"] for e in decode_evs) == dma

    def test_engine_decode_cost_matches_direct_model(self, setup):
        cfg, params = setup
        from repro.serve import ManualClock
        tel = Telemetry(clock=ManualClock(tick=1e-4))
        _, eng = self._run(cfg, params, tel=tel)
        ev = [e for e in tel.timeline.events if e["name"] == "decode"][0]
        # one decode launch re-modeled from the stamped geometry must obey
        # the accounting identity dma >= waste and layers-scaling
        assert ev["args"]["dma_bytes"] % cfg.n_layers == 0
        assert ev["args"]["waste_bytes"] <= ev["args"]["dma_bytes"]
