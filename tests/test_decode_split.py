"""GQA-grouped / multi-block-tiled / split-K paged decode: Softermax-merge
operator properties (hypothesis), kernel-vs-ref parity sweeps across tile
sizes and split factors (bf16 + int8), legacy-kernel equivalence, the
shared table-width bucketing policy, and engine greedy equality across
grid settings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.numerics import NEG_INF
from repro.core.softermax import softermax_finalize, softermax_merge
from repro.kernels.flash_decode_paged import (flash_decode_paged,
                                              flash_decode_paged_single,
                                              paged_decode_ref,
                                              paged_decode_split_ref)
from repro.models.attention import quantize_kv
from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import ContinuousEngine
from repro.serve.paged_step import table_width_bucket

_rng = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# softermax_merge: operator properties
# ---------------------------------------------------------------------------


def _state_of(scores: np.ndarray, intmax: bool, col_scale=None):
    """Closed-form partial state of one score segment (rows, cols) against
    unit values — the (m, d, acc) a kernel lane leaves behind. Empty
    segments (cols == 0) give the merge identity. ``col_scale`` mimics the
    int8 dequant fused into the score row."""
    if scores.shape[-1] == 0:
        rows = scores.shape[0]
        return (np.full((rows, 1), NEG_INF, np.float32),
                np.zeros((rows, 1), np.float32),
                np.zeros((rows, 1), np.float32))
    s = scores.astype(np.float32)
    if col_scale is not None:
        s = s * col_scale[None, :]
    m = np.max(s, axis=-1, keepdims=True)
    if intmax:
        m = np.ceil(m)
    p = np.exp2(s - m)
    d = np.sum(p, axis=-1, keepdims=True)
    acc = np.sum(p, axis=-1, keepdims=True)  # values == 1: acc mirrors d
    return m.astype(np.float32), d.astype(np.float32), acc.astype(np.float32)


def _merge_pair(a, b):
    m = jnp.stack([a[0], b[0]], 0)
    d = jnp.stack([a[1], b[1]], 0)
    acc = jnp.stack([a[2], b[2]], 0)
    out = softermax_merge(m, d, acc, axis=0)
    return tuple(np.asarray(x) for x in out)


def _rand_segments(rng, n_seg, max_rows=3, max_cols=9, allow_empty=True):
    rows = int(rng.integers(1, max_rows + 1))
    lo = 0 if allow_empty else 1
    return [rng.uniform(-30.0, 30.0,
                        (rows, int(rng.integers(lo, max_cols + 1)))
                        ).astype(np.float32) for _ in range(n_seg)]


def _check_merge_equals_whole(segs, intmax, col_scales=None):
    """Splitting a score row into segments, reducing each, and merging
    must reproduce the unsplit reduction — the exact property that makes
    split-K legal for Softermax."""
    cs = col_scales or [None] * len(segs)
    states = [_state_of(s, intmax, col_scale=c) for s, c in zip(segs, cs)]
    m = jnp.stack([s[0] for s in states], 0)
    d = jnp.stack([s[1] for s in states], 0)
    acc = jnp.stack([s[2] for s in states], 0)
    _, d2, acc2 = softermax_merge(m, d, acc, axis=0)
    whole = _state_of(
        np.concatenate(segs, axis=-1), intmax,
        col_scale=None if col_scales is None else np.concatenate(cs))
    np.testing.assert_allclose(np.asarray(d2), whole[1], rtol=1e-5,
                               atol=1e-30)
    np.testing.assert_allclose(np.asarray(acc2), whole[2], rtol=1e-5,
                               atol=1e-30)


def _check_commutative(segs, intmax):
    """Pairwise merge is exactly commutative (max and two-term sums are
    order-symmetric in IEEE arithmetic)."""
    a, b = (_state_of(s, intmax) for s in segs[:2])
    ab, ba = _merge_pair(a, b), _merge_pair(b, a)
    for x, y in zip(ab, ba):
        np.testing.assert_array_equal(x, y)


def _check_associative(segs, intmax):
    """(a ⊕ b) ⊕ c == a ⊕ (b ⊕ c) — exactly for the rescales (integer
    exponent adds under IntMax), up to fp addition order for the sums."""
    a, b, c = (_state_of(s, intmax) for s in segs[:3])
    left = _merge_pair(_merge_pair(a, b), c)
    right = _merge_pair(a, _merge_pair(b, c))
    for x, y in zip(left, right):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-30)


def _check_permutation_invariant(segs, perm, intmax):
    """n-ary merge must not care which split lane produced which
    partition."""
    states = [_state_of(s, intmax) for s in segs]

    def nary(order):
        m = jnp.stack([states[i][0] for i in order], 0)
        d = jnp.stack([states[i][1] for i in order], 0)
        acc = jnp.stack([states[i][2] for i in order], 0)
        return softermax_merge(m, d, acc, axis=0)

    base, shuf = nary(range(len(segs))), nary(perm)
    for x, y in zip(base[1:], shuf[1:]):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-30)


def _check_identity_exact(seg, intmax):
    """Merging with (NEG_INF, 0, 0) — an empty partition — changes
    nothing, bit for bit."""
    a = _state_of(seg, intmax)
    out = _merge_pair(a, _state_of(seg[:, :0], intmax))
    np.testing.assert_array_equal(out[1], a[1])
    np.testing.assert_array_equal(out[2], a[2])
    np.testing.assert_array_equal(
        np.asarray(softermax_finalize(jnp.asarray(out[2]),
                                      jnp.asarray(out[1]))),
        np.asarray(softermax_finalize(jnp.asarray(a[2]),
                                      jnp.asarray(a[1]))))


class TestSoftermaxMerge:
    """Seeded sweeps of the operator laws (no-dependency fallback for the
    hypothesis test below, same checkers)."""

    @pytest.mark.parametrize("intmax", [True, False])
    def test_merge_of_partials_equals_whole(self, intmax):
        rng = np.random.default_rng(2)
        for _ in range(25):
            _check_merge_equals_whole(_rand_segments(rng, 3), intmax)

    @pytest.mark.parametrize("intmax", [True, False])
    def test_commutative_and_associative(self, intmax):
        rng = np.random.default_rng(3)
        for _ in range(25):
            segs = _rand_segments(rng, 3)
            _check_commutative(segs, intmax)
            _check_associative(segs, intmax)

    @pytest.mark.parametrize("intmax", [True, False])
    def test_permutation_invariant(self, intmax):
        rng = np.random.default_rng(4)
        for _ in range(25):
            segs = _rand_segments(rng, 4)
            _check_permutation_invariant(
                segs, list(rng.permutation(len(segs))), intmax)

    @pytest.mark.parametrize("intmax", [True, False])
    def test_identity_state_is_exact(self, intmax):
        rng = np.random.default_rng(5)
        for _ in range(25):
            _check_identity_exact(_rand_segments(rng, 1,
                                                 allow_empty=False)[0],
                                  intmax)

    def test_int8_scaled_path(self):
        """States built from scale-dequantized score rows (the fused int8
        path: S *= k_scale post-dot) merge identically to the whole-row
        reduction — the merge never sees the scales, only states."""
        rng = np.random.default_rng(7)
        for _ in range(25):
            segs = _rand_segments(rng, 3)
            cols = [rng.uniform(0.01, 0.2, (s.shape[-1],)
                                ).astype(np.float32) for s in segs]
            _check_merge_equals_whole(segs, True, col_scales=cols)

    def test_hypothesis_properties(self):
        """Property-based search over the same operator laws (associative,
        commutative, permutation-invariant, identity, split == whole; both
        IntMax and plain-max paths)."""
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @st.composite
        def segments(draw, n_seg=3, max_rows=3, max_cols=9):
            rows = draw(st.integers(1, max_rows))
            segs = []
            for _ in range(n_seg):
                cols = draw(st.integers(0, max_cols))  # 0 = empty lane
                segs.append(np.asarray(draw(st.lists(
                    st.lists(st.floats(-30.0, 30.0, allow_nan=False,
                                       width=32),
                             min_size=cols, max_size=cols),
                    min_size=rows, max_size=rows)),
                    np.float32).reshape(rows, cols))
            return segs

        @settings(max_examples=40, deadline=None)
        @given(segments(n_seg=4), st.permutations(list(range(4))),
               st.booleans())
        def run(segs, perm, intmax):
            _check_merge_equals_whole(segs, intmax)
            _check_commutative(segs, intmax)
            _check_associative(segs, intmax)
            _check_permutation_invariant(segs, perm, intmax)
            if segs[0].shape[-1]:
                _check_identity_exact(segs[0], intmax)

        run()


# ---------------------------------------------------------------------------
# Kernel vs refs: parity sweeps
# ---------------------------------------------------------------------------


def _random_paged_kv(B, Hkv, D, BS, W, quantized=False):
    N = B * W + 1
    kp = jnp.asarray(_rng.normal(size=(N, Hkv, BS, D)), jnp.float32)
    vp = jnp.asarray(_rng.normal(size=(N, Hkv, BS, D)), jnp.float32)
    bt = jnp.asarray(_rng.permutation(np.arange(1, N))[:B * W].reshape(B, W),
                     jnp.int32)
    if not quantized:
        return kp, vp, bt, None, None
    kq, ksc = quantize_kv(kp)
    vq, vsc = quantize_kv(vp)
    return kq, vq, bt, ksc, vsc


class TestGroupedSplitDecodeKernel:
    @pytest.mark.parametrize("T", [1, 2, 4])
    @pytest.mark.parametrize("S", [1, 2, 3])
    def test_matches_ref_across_tiles_and_splits(self, T, S):
        """Odd lengths, mid-block tails, a zombie row, a one-token row —
        every (tile, split) layout computes the identical attention."""
        B, Hq, Hkv, D, BS, W = 4, 8, 2, 16, 8, 7
        kp, vp, bt, _, _ = _random_paged_kv(B, Hkv, D, BS, W)
        q = jnp.asarray(_rng.normal(size=(B, Hq, D)),
                        jnp.float32) / np.sqrt(D)
        lens = jnp.asarray([1, 29, 56, 0], jnp.int32)
        want = paged_decode_ref(q, kp, vp, bt, lens)
        got = flash_decode_paged(q, kp, vp, bt, lens, kv_tile_blocks=T,
                                 split_k=S, interpret=True)
        sref = paged_decode_split_ref(q, kp, vp, bt, lens,
                                      kv_tile_blocks=T, split_k=S)
        # row with length 0 is a zombie: kernels/split-ref emit 0 (merge
        # identity), the closed-form oracle emits a uniform average — the
        # engine masks either; compare the live rows against the oracle
        # and the zombie row against the kernel contract
        np.testing.assert_allclose(np.asarray(got)[:3],
                                   np.asarray(want)[:3], atol=1e-5)
        np.testing.assert_allclose(np.asarray(sref)[:3],
                                   np.asarray(want)[:3], atol=1e-5)
        assert np.all(np.asarray(got)[3] == 0)
        assert np.all(np.asarray(sref)[3] == 0)

    @pytest.mark.parametrize("T,S", [(1, 1), (2, 2), (4, 3)])
    def test_int8_matches_ref(self, T, S):
        B, Hq, Hkv, D, BS, W = 2, 4, 2, 16, 8, 6
        kp, vp, bt, ksc, vsc = _random_paged_kv(B, Hkv, D, BS, W,
                                                quantized=True)
        q = jnp.asarray(_rng.normal(size=(B, Hq, D)),
                        jnp.float32) / np.sqrt(D)
        lens = jnp.asarray([11, 41], jnp.int32)
        want = paged_decode_ref(q, kp, vp, bt, lens, k_scale=ksc,
                                v_scale=vsc)
        got = flash_decode_paged(q, kp, vp, bt, lens, k_scale=ksc,
                                 v_scale=vsc, kv_tile_blocks=T, split_k=S,
                                 interpret=True)
        sref = paged_decode_split_ref(q, kp, vp, bt, lens, k_scale=ksc,
                                      v_scale=vsc, kv_tile_blocks=T,
                                      split_k=S)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(sref), np.asarray(want),
                                   atol=1e-5)

    def test_grouped_equals_legacy_per_head_kernel(self):
        """The restructure is layout-only: the grouped/tiled/split kernel
        and the retired per-head single-block kernel agree."""
        B, Hq, Hkv, D, BS, W = 2, 8, 2, 16, 8, 5
        kp, vp, bt, _, _ = _random_paged_kv(B, Hkv, D, BS, W)
        q = jnp.asarray(_rng.normal(size=(B, Hq, D)),
                        jnp.float32) / np.sqrt(D)
        lens = jnp.asarray([17, 40], jnp.int32)
        legacy = flash_decode_paged_single(q, kp, vp, bt, lens,
                                           interpret=True)
        got = flash_decode_paged(q, kp, vp, bt, lens, kv_tile_blocks=2,
                                 split_k=2, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(legacy),
                                   atol=1e-5)

    def test_oversized_tile_and_split_clamp(self):
        """T and S larger than the table clamp instead of erroring."""
        B, Hq, Hkv, D, BS, W = 1, 2, 1, 16, 8, 3
        kp, vp, bt, _, _ = _random_paged_kv(B, Hkv, D, BS, W)
        q = jnp.asarray(_rng.normal(size=(B, Hq, D)),
                        jnp.float32) / np.sqrt(D)
        lens = jnp.asarray([19], jnp.int32)
        want = paged_decode_ref(q, kp, vp, bt, lens)
        got = flash_decode_paged(q, kp, vp, bt, lens, kv_tile_blocks=16,
                                 split_k=9, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    @pytest.mark.tpu
    def test_compiled_matches_interpret(self):
        """Compiled-Pallas parity for the grouped/tiled/split grid — only
        runnable on a real TPU backend; conftest skips it elsewhere."""
        B, Hq, Hkv, D, BS, W = 2, 8, 2, 128, 32, 8
        kp, vp, bt, _, _ = _random_paged_kv(B, Hkv, D, BS, W)
        q = jnp.asarray(_rng.normal(size=(B, Hq, D)),
                        jnp.float32) / np.sqrt(D)
        lens = jnp.asarray([70, 256], jnp.int32)
        for T, S in ((4, 1), (4, 2)):
            got = flash_decode_paged(q, kp, vp, bt, lens, kv_tile_blocks=T,
                                     split_k=S)
            want = flash_decode_paged(q, kp, vp, bt, lens,
                                      kv_tile_blocks=T, split_k=S,
                                      interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-5)


# ---------------------------------------------------------------------------
# table_width_bucket: the one shared policy
# ---------------------------------------------------------------------------


class TestTableWidthBucket:
    def test_pow2_policy(self):
        assert [table_width_bucket(n) for n in (1, 2, 3, 5, 8, 9)] == \
            [1, 2, 4, 8, 8, 16]

    def test_pow2_clamps_to_nb_max_without_truncating(self):
        assert table_width_bucket(5, nb_max=6) == 6
        assert table_width_bucket(6, nb_max=6) == 6
        assert table_width_bucket(3, nb_max=6) == 4

    def test_chunk_policy_quantizes_to_chunk_blocks(self):
        assert table_width_bucket(5, chunk_blocks=2) == 6
        assert table_width_bucket(4, chunk_blocks=2) == 4
        assert table_width_bucket(1, chunk_blocks=4) == 4

    def test_bucket_sets_stay_bounded(self):
        """The warmup enumeration: every width any in-range request can
        produce collapses to a small set under either policy."""
        nb_max = 23
        pow2 = {table_width_bucket(n, nb_max=nb_max)
                for n in range(1, nb_max + 1)}
        chunk = {table_width_bucket(n, chunk_blocks=4)
                 for n in range(1, nb_max + 1)}
        assert pow2 == {1, 2, 4, 8, 16, 23}
        assert chunk == {4, 8, 12, 16, 20, 24}


# ---------------------------------------------------------------------------
# Engine: greedy equality across grid settings
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen3-4b"))
    params = model_fns(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _serve(cfg, params, prompts, max_new=6, **kw):
    kw.setdefault("block_size", 8)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 96)
    eng = ContinuousEngine(cfg, params, **kw)
    hs = [eng.submit(p, max_new) for p in prompts]
    res = eng.run()
    return [res[h.req_id].tokens for h in hs], eng


class TestEngineGridSettings:
    def test_greedy_identical_across_tile_split_settings(self, setup):
        """Tile/split are layout knobs: one-shot, chunked, and cached
        engines produce identical greedy streams at any setting."""
        cfg, params = setup
        shared = _rng.integers(1, cfg.vocab_size, (21,)).astype(np.int32)
        prompts = [np.concatenate(
            [shared, _rng.integers(1, cfg.vocab_size, (n,))]).astype(
                np.int32) for n in (13, 30, 7)]
        base, _ = _serve(cfg, params, prompts)
        cold, _ = _serve(cfg, params, prompts, prefix_cache=False,
                         kv_tile_blocks=4, decode_split_k=2)
        tiled, e1 = _serve(cfg, params, prompts, kv_tile_blocks=4,
                           decode_split_k=2)
        chunked, _ = _serve(cfg, params, prompts, kv_tile_blocks=2,
                            decode_split_k=3, prefill_chunk=16)
        assert base == cold == tiled == chunked
        assert e1.metrics.cow_copies >= 1       # COW-fork path exercised
        assert e1.metrics.prefix_hit_tokens > 0

    def test_greedy_identical_int8_across_settings(self, setup):
        cfg, params = setup
        prompts = [_rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
                   for n in (5, 37)]
        base, _ = _serve(cfg, params, prompts, kv_dtype="int8")
        tiled, _ = _serve(cfg, params, prompts, kv_dtype="int8",
                          kv_tile_blocks=4, decode_split_k=2,
                          prefill_chunk=16)
        assert base == tiled

    def test_interpret_kernels_run_the_grid(self, setup):
        """With cfg.interpret_kernels the engine's decode/chunk steps run
        the actual Pallas grid (tiled + split) and still match the ref
        engine's streams."""
        import dataclasses
        cfg, params = setup
        cfg_i = dataclasses.replace(cfg, interpret_kernels=True)
        prompts = [_rng.integers(1, cfg.vocab_size, (20,)).astype(np.int32)]
        base, _ = _serve(cfg, params, prompts, max_new=4)
        interp, _ = _serve(cfg_i, params, prompts, max_new=4,
                           num_blocks=32, max_batch=2, max_len=48,
                           kv_tile_blocks=2, decode_split_k=2,
                           prefill_chunk=16)
        assert base == interp

    def test_warmup_covers_tiled_buckets(self, setup):
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=32,
                               max_batch=2, max_len=48, prefill_chunk=16,
                               kv_tile_blocks=2, decode_split_k=2)
        eng.warmup()
        assert eng.metrics.steps == 0
        h = eng.submit(
            _rng.integers(1, cfg.vocab_size, (20,)).astype(np.int32), 4)
        res = eng.run()
        assert len(res[h.req_id].tokens) == 4

    def test_rejects_bad_grid_settings(self, setup):
        cfg, params = setup
        with pytest.raises(ValueError):
            ContinuousEngine(cfg, params, kv_tile_blocks=0)
        with pytest.raises(ValueError):
            ContinuousEngine(cfg, params, decode_split_k=0)


@pytest.mark.slow
class TestBenchSmoke:
    def test_decode_paged_bench_smoke(self):
        """The benchmark's CI mode: kernel parity + five-path engine
        greedy equality on a tiny workload; speed reported, not gated."""
        import pathlib
        import sys
        root = pathlib.Path(__file__).resolve().parent.parent
        sys.path.insert(0, str(root / "benchmarks"))
        try:
            import decode_paged_bench
            ratio = decode_paged_bench.main(["--smoke"])
        finally:
            sys.path.pop(0)
        assert ratio > 0
