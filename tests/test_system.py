"""End-to-end behaviour tests: train loop fault tolerance, checkpointing
(including elastic restore), data pipeline determinism, serving engine,
energy model calibration, straggler monitor."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.base import TrainConfig
from repro.core import energy_model
from repro.data import DataState, SyntheticLMData
from repro.models.registry import get_config, model_fns, reduce_config
from repro.optim import adamw
from repro.serve import ServeEngine
from repro.train import StragglerMonitor, make_train_step, train


@pytest.fixture(scope="module")
def small_setup():
    cfg = reduce_config(get_config("llama3.2-3b"))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


@pytest.mark.slow
class TestTrainLoop:
    def test_loss_decreases(self, small_setup):
        cfg, fns, params = small_setup
        tc = TrainConfig(total_steps=30, warmup_steps=3, learning_rate=3e-3,
                         checkpoint_every=1000)
        data = SyntheticLMData(cfg.vocab_size, 64, 8, seed=3)
        step = jax.jit(make_train_step(fns.loss, tc))
        out = train(train_step=step, params=params, data=data, tc=tc,
                    log_every=1000)
        first = np.mean(out["history"][:5])
        last = np.mean(out["history"][-5:])
        assert last < first - 0.2, (first, last)

    def test_microbatched_matches_unbatched_grads(self, small_setup):
        cfg, fns, params = small_setup
        from repro.train.step import make_loss_and_grad
        data = SyntheticLMData(cfg.vocab_size, 32, 8, seed=4)
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        _, _, g1 = make_loss_and_grad(fns.loss, TrainConfig(microbatches=1))(
            params, batch)
        _, _, g4 = make_loss_and_grad(fns.loss, TrainConfig(microbatches=4))(
            params, batch)
        flat1 = jnp.concatenate([x.ravel().astype(jnp.float32)
                                 for x in jax.tree_util.tree_leaves(g1)])
        flat4 = jnp.concatenate([x.ravel().astype(jnp.float32)
                                 for x in jax.tree_util.tree_leaves(g4)])
        # same expectation up to per-microbatch loss normalization (token
        # counts equal here ⇒ should match closely)
        np.testing.assert_allclose(np.asarray(flat1), np.asarray(flat4),
                                   atol=1e-4)

    def test_nan_guard_raises(self, small_setup):
        cfg, fns, params = small_setup
        tc = TrainConfig(total_steps=3, learning_rate=1e-3)
        data = SyntheticLMData(cfg.vocab_size, 32, 8, seed=5)

        def bad_step(p, o, b):
            return p, o, {"loss": jnp.float32(np.nan)}

        with pytest.raises(FloatingPointError):
            train(train_step=bad_step, params=params, data=data, tc=tc)


@pytest.mark.slow
class TestCheckpointing:
    def test_roundtrip_and_retention(self, small_setup):
        cfg, fns, params = small_setup
        opt = adamw.init_state(params)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, async_save=False)
            for s in (10, 20, 30):
                mgr.save(s, {"params": params, "opt": opt,
                             "data": {"seed": 1, "step": s}})
            assert mgr.all_steps() == [20, 30]
            restored = mgr.restore(30, {
                "params": params, "opt": opt, "data": {"seed": 0, "step": 0}})
            assert restored["data"]["step"] == 30
            for a, b in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(restored["params"])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_resume_exact(self, small_setup):
        """Fault-tolerance: kill after step N, resume, bit-identical to an
        uninterrupted run (params + data stream)."""
        cfg, fns, params0 = small_setup
        tc_full = TrainConfig(total_steps=12, warmup_steps=2,
                              learning_rate=1e-3, checkpoint_every=6)
        step = jax.jit(make_train_step(fns.loss, tc_full))

        def run(ckpt_dir, total):
            tc = TrainConfig(total_steps=total, warmup_steps=2,
                             learning_rate=1e-3, checkpoint_every=6)
            data = SyntheticLMData(cfg.vocab_size, 32, 8, seed=9)
            return train(train_step=step, params=params0, data=data, tc=tc,
                         ckpt_dir=ckpt_dir, log_every=1000)

        with tempfile.TemporaryDirectory() as d1:
            uninterrupted = run(None, 12)
            # interrupted at 6, then resumed
            run(d1, 6)
            resumed = run(d1, 12)
        for a, b in zip(jax.tree_util.tree_leaves(uninterrupted["params"]),
                        jax.tree_util.tree_leaves(resumed["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)

    def test_elastic_restore_new_mesh(self, small_setup):
        """Checkpoints restore onto a different device layout (elastic)."""
        cfg, fns, params = small_setup
        from jax.sharding import NamedSharding, PartitionSpec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sh = jax.tree_util.tree_map(
            lambda a: NamedSharding(mesh, PartitionSpec()), params)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            mgr.save(1, {"params": params})
            restored = mgr.restore(1, {"params": params},
                                   shardings={"params": sh})
            leaf = jax.tree_util.tree_leaves(restored["params"])[0]
            assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}


class TestData:
    def test_deterministic_restart(self):
        d1 = SyntheticLMData(1000, 16, 4, seed=2)
        batches = [next(d1) for _ in range(5)]
        d2 = SyntheticLMData(1000, 16, 4, seed=2)
        d2.restore(DataState(seed=2, step=3))
        np.testing.assert_array_equal(next(d2)["tokens"],
                                      batches[3]["tokens"])

    def test_host_sharding_disjoint(self):
        a = SyntheticLMData(1000, 16, 8, seed=2, host_id=0, num_hosts=2)
        b = SyntheticLMData(1000, 16, 8, seed=2, host_id=1, num_hosts=2)
        assert not np.array_equal(next(a)["tokens"], next(b)["tokens"])

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLMData(1000, 16, 4, seed=2)
        batch = next(d)
        assert batch["tokens"].shape == batch["labels"].shape == (4, 16)


class TestServe:
    def test_generate_shapes_and_determinism(self, small_setup):
        cfg, fns, params = small_setup
        eng = ServeEngine(cfg, params, max_len=48)
        prompts = np.ones((2, 16), np.int32) * 7
        r1 = eng.generate(prompts, max_new=6)
        r2 = eng.generate(prompts, max_new=6)
        assert r1.tokens.shape == (2, 6)
        np.testing.assert_array_equal(r1.tokens, r2.tokens)  # greedy
        assert r1.tokens.max() < cfg.vocab_size

    def test_sampling_temperature(self, small_setup):
        cfg, fns, params = small_setup
        eng = ServeEngine(cfg, params, max_len=48)
        prompts = np.ones((2, 16), np.int32) * 7
        r = eng.generate(prompts, max_new=6, temperature=1.0, seed=3)
        assert r.tokens.shape == (2, 6)


class TestStragglerMonitor:
    def test_flags_injected_delay(self):
        mon = StragglerMonitor(k=3.0)
        for _ in range(30):
            assert not mon.observe(0.100 + np.random.default_rng(0).normal()
                                   * 1e-4)
        assert mon.observe(0.5)   # 5x step time → flagged
        assert mon.flagged == 1


class TestEnergyModelCalibration:
    def test_table4_matches_paper_bands(self):
        t4 = energy_model.table4()
        u = t4["unnormed_softmax_unit"]
        assert 0.15 <= u["area_ratio"] <= 0.35      # paper 0.25
        assert 0.05 <= u["energy_ratio"] <= 0.15    # paper 0.10
        n = t4["normalization_unit"]
        assert 0.45 <= n["area_ratio"] <= 0.80      # paper 0.65
        assert 0.30 <= n["energy_ratio"] <= 0.50    # paper 0.39
        p = t4["full_pe"]
        assert 0.80 <= p["area_ratio"] <= 1.00      # paper 0.90
        assert 0.35 <= p["energy_ratio"] <= 0.55    # paper 0.43

    def test_fig5_scaling(self):
        rows = energy_model.fig5_sweep(widths=(32,),
                                       seq_lens=(128, 512, 2048))
        # softermax stays strictly cheaper and the gap is stable with L
        for r in rows:
            assert r["softermax_uj"] < r["baseline_uj"]
        assert rows[-1]["baseline_uj"] > rows[0]["baseline_uj"] * 10
