"""Hypothesis property tests for the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.softermax as sm
from repro.core import quant
from repro.launch.roofline import collective_bytes, shape_bytes

_settings = settings(max_examples=40, deadline=None)


def _float_rows(draw, rows, cols, lo=-30.0, hi=30.0):
    data = draw(st.lists(
        st.lists(st.floats(lo, hi, allow_nan=False, width=32),
                 min_size=cols, max_size=cols),
        min_size=rows, max_size=rows))
    return jnp.array(np.array(data, np.float32))


@st.composite
def rows(draw, max_rows=4, max_cols=33):
    r = draw(st.integers(1, max_rows))
    c = draw(st.integers(1, max_cols))
    return _float_rows(draw, r, c)


class TestSoftermaxProperties:
    @_settings
    @given(rows())
    def test_simplex(self, x):
        y = sm.softermax(x)
        assert bool(jnp.all(y >= 0))
        np.testing.assert_allclose(jnp.sum(y, -1), 1.0, atol=1e-4)

    @_settings
    @given(rows())
    def test_shift_invariance(self, x):
        # softmax-family invariance: softermax(x + c) == softermax(x)
        y1 = sm.softermax(x)
        y2 = sm.softermax(x + 7.0)
        np.testing.assert_allclose(y1, y2, atol=1e-4)

    @_settings
    @given(rows())
    def test_intmax_equals_base2(self, x):
        np.testing.assert_allclose(
            sm.softermax(x), sm.softmax_base2(x), atol=1e-4)

    @_settings
    @given(rows())
    def test_online_scan_equals_closed_form(self, x):
        np.testing.assert_allclose(
            sm.softermax_online_scan(x, block=8), sm.softermax(x), atol=1e-4)

    @_settings
    @given(rows())
    def test_monotone_order_preserved(self, x):
        # higher score ⇒ (weakly) higher probability within a row
        y = np.asarray(sm.softermax(x))
        xs = np.asarray(x)
        for r in range(xs.shape[0]):
            order = np.argsort(xs[r], kind="stable")
            assert np.all(np.diff(y[r][order]) >= -1e-6)


class TestQuantProperties:
    @_settings
    @given(st.floats(-40, 40, allow_nan=False, width=32))
    def test_qformat_roundtrip_within_half_ulp(self, v):
        fmt = quant.QFormat(6, 2)
        q = float(fmt.quantize_exact(jnp.float32(v)))
        if fmt.min_value <= v <= fmt.max_value:
            assert abs(q - v) <= 0.5 / fmt.scale + 1e-6
        assert fmt.min_value <= q <= fmt.max_value

    @_settings
    @given(st.floats(-20, 0, allow_nan=False, width=32))
    def test_lpw_exp2_relative_error(self, t):
        got = float(quant.lpw_exp2(jnp.float32(t)))
        want = 2.0 ** t
        # 4-segment LPW + Q(1,15): ~1% relative or 1 ulp absolute
        assert abs(got - want) <= max(0.011 * want, 2 ** -15 + 1e-9)

    @_settings
    @given(st.floats(0.25, 900, allow_nan=False, width=32))
    def test_lpw_reciprocal_relative_error(self, d):
        got = float(quant.lpw_reciprocal(jnp.float32(d)))
        want = 1.0 / d
        # Q(1,7) mantissa: ~1.6% worst-case relative error
        assert abs(got - want) <= 0.02 * want + 1e-9

    @_settings
    @given(st.integers(1, 2 ** 30), st.sampled_from(["f32", "bf16", "s8"]))
    def test_shape_bytes(self, n, dt):
        per = {"f32": 4, "bf16": 2, "s8": 1}[dt]
        assert shape_bytes(dt, str(n)) == n * per


class TestCollectiveParser:
    def test_while_trip_count_multiplies(self):
        hlo = """
HloModule m

%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[64]{0} all-reduce(%x), replica_groups=[16,16]<=[256]
  ROOT %t = tuple()
}

ENTRY %main () -> f32[64] {
  %w = (s32[], f32[64]) while(%init), condition=%c, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %r = f32[64]{0} get-tuple-element(%w)
}
"""
        out = collective_bytes(hlo)
        # 64 f32 = 256B; all-reduce ring 2*(15/16)*256 = 480B; ×12 trips
        np.testing.assert_allclose(out["all-reduce"], 480 * 12)

    def test_plain_collectives_counted_once(self):
        hlo = """
HloModule m

ENTRY %main () -> f32[128] {
  %ag = f32[128]{0} all-gather(%x), replica_groups=[2,8]<=[16]
  ROOT %cp = f32[128]{0} collective-permute(%ag), source_target_pairs={{0,1}}
}
"""
        out = collective_bytes(hlo)
        np.testing.assert_allclose(out["all-gather"], 512 * 7 / 8)
        np.testing.assert_allclose(out["collective-permute"], 512)
