"""Fleet serving layer (PR 9): async front-end (serve/frontend.py),
prefix-affinity routing (serve/router.py), replica supervision with
journaled failover (serve/supervisor.py, serve/journal.py), and the
cross-replica telemetry contracts (TTFT once fleet-wide, E2E from the
original submit, collect() aggregation surviving a replica death)."""
import asyncio

import jax
import numpy as np
import pytest

from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import (ContinuousEngine, EngineGuard, EngineSheddingError,
                         FaultInjector, FaultPlan, FaultSpec, FleetSupervisor,
                         GuardConfig, GuardSignals, Journal, JournalCorrupt,
                         ManualClock, MetricRegistry, RequestTracker, Router,
                         Telemetry, canned_fleet_plan, leaked_blocks, replay)
from repro.serve.frontend import AsyncFrontend
from repro.serve.guard import SHEDDING
from repro.serve.supervisor import DEAD, SERVING

_rng = np.random.default_rng(41)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen3-4b"))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, **kw):
    kw.setdefault("block_size", 8)
    kw.setdefault("num_blocks", 24)
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 48)
    kw.setdefault("retry_backoff_s", 0.0)
    return ContinuousEngine(cfg, params, **kw)


def _prompt(cfg, n):
    return _rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)


def _reference_streams(cfg, params, prompts, max_new, **kw):
    """Greedy streams of an unfailed single-engine run (the byte-identity
    oracle: placement never changes greedy output)."""
    eng = _engine(cfg, params, **kw)
    handles = [eng.submit(p, max_new) for p in prompts]
    res = eng.run()
    return [list(res[h.req_id].tokens) for h in handles]


# ---------------------------------------------------------------------------
# Journal: record validation + replay invariants (host-only)
# ---------------------------------------------------------------------------


class TestJournal:
    def test_append_validates_kind_and_file_roundtrip(self, tmp_path):
        p = tmp_path / "wal.jsonl"
        j = Journal(path=str(p), clock=ManualClock(tick=0.5))
        with pytest.raises(ValueError, match="unknown journal record"):
            j.append("telegram", rid=0)
        j.append("submit", rid=0, prompt_len=4, max_new=2, prompt=[1, 2, 3, 4])
        j.append("placement", rid=0, replica=1, engine_rid=0, attempt=0,
                 reason="submit", resume_base=0)
        j.append("token", rid=0, replica=1, pos=0, toks=[7, 8])
        j.append("terminal", rid=0, reason="length", n_tokens=2)
        j.close()
        loaded = Journal.load(str(p))
        assert loaded.records == j.records       # WAL is flushed per append
        st = loaded.replay()
        assert st.requests[0].tokens == [7, 8]
        assert st.requests[0].finish_reason == "length"
        assert st.requests[0].placements[0]["reason"] == "submit"
        assert st.terminal.keys() == {0}

    def test_replay_rejects_impossible_histories(self):
        base = [dict(kind="submit", rid=0, prompt_len=4, max_new=4, t=0.0)]
        with pytest.raises(JournalCorrupt, match="pos 2"):
            replay(base + [dict(kind="token", rid=0, replica=0, pos=2,
                                toks=[1], t=0.1)])
        with pytest.raises(JournalCorrupt, match="terminal claims"):
            replay(base + [dict(kind="terminal", rid=0, reason="length",
                                n_tokens=3, t=0.1)])
        with pytest.raises(JournalCorrupt, match="after its terminal"):
            replay(base
                   + [dict(kind="terminal", rid=0, reason="length",
                           n_tokens=0, t=0.1),
                      dict(kind="token", rid=0, replica=0, pos=0, toks=[1],
                           t=0.2)])
        with pytest.raises(JournalCorrupt, match="submitted twice"):
            replay(base + base)
        with pytest.raises(JournalCorrupt, match="unknown request"):
            replay([dict(kind="token", rid=9, replica=0, pos=0, toks=[1],
                         t=0.0)])

    def test_failover_count_from_placements(self):
        st = replay([
            dict(kind="submit", rid=0, prompt_len=2, max_new=4, t=0.0),
            dict(kind="placement", rid=0, replica=0, engine_rid=0,
                 attempt=0, reason="submit", resume_base=0, t=0.0),
            dict(kind="placement", rid=0, replica=1, engine_rid=1,
                 attempt=1, reason="crash", resume_base=2, t=0.2),
        ])
        assert st.requests[0].n_failovers == 1


# ---------------------------------------------------------------------------
# AsyncStream + AsyncFrontend (asyncio surface)
# ---------------------------------------------------------------------------


class TestAsyncFrontend:
    def test_stream_and_typed_result(self, setup):
        cfg, params = setup
        prompt = _prompt(cfg, 8)
        ref = _reference_streams(cfg, params, [prompt], 4)[0]
        sup = FleetSupervisor([_engine(cfg, params) for _ in range(2)])
        fe = AsyncFrontend(sup)

        async def drive():
            stream = await fe.submit(prompt, 4)
            driver = asyncio.ensure_future(fe.run())
            got = [tok async for tok in stream]
            fe.close()
            await driver
            return got, stream.result()

        got, result = asyncio.run(drive())
        assert got == ref == result.tokens
        assert result.ok and result.finish_reason == "length"
        assert result.n_failovers == 0 and len(result.replicas) == 1

    def test_run_until_drained_sync_consumption(self, setup):
        cfg, params = setup
        prompts = [_prompt(cfg, 8) for _ in range(3)]
        ref = _reference_streams(cfg, params, prompts, 4)
        sup = FleetSupervisor([_engine(cfg, params) for _ in range(2)])
        fe = AsyncFrontend(sup)

        async def drive():
            streams = [await fe.submit(p, 4) for p in prompts]
            await fe.run_until_drained()
            return streams

        streams = asyncio.run(drive())
        assert [s.drain_nowait() for s in streams] == ref
        assert all(s.finished for s in streams)


# ---------------------------------------------------------------------------
# Router: affinity, demotion, skipping, round-robin
# ---------------------------------------------------------------------------


class TestRouter:
    def _fleet(self, cfg, params, n=3):
        return FleetSupervisor([_engine(cfg, params) for _ in range(n)])

    def test_affinity_prefers_the_replica_holding_the_prefix(self, setup):
        cfg, params = setup
        sup = self._fleet(cfg, params)
        prompt = _prompt(cfg, 16)
        # serve the prompt once through replica 1 only, so only its radix
        # tree holds the prefix
        sup.replicas[1].engine.submit(prompt, 2)
        sup.replicas[1].engine.run()
        follow_up = np.concatenate([prompt, _prompt(cfg, 4)])
        r = Router("affinity")
        chosen = r.place(follow_up, sup.replicas)
        assert chosen.idx == 1
        assert r.decisions[-1].affinity_tokens >= 8   # >= one block

    def test_cold_fleet_falls_back_to_load_then_budget(self, setup):
        cfg, params = setup
        sup = self._fleet(cfg, params)
        # load replica 0 with queued work: the cold prompt must avoid it
        sup.replicas[0].engine.submit(_prompt(cfg, 8), 4)
        r = Router("affinity")
        chosen = r.place(_prompt(cfg, 8), sup.replicas)
        assert chosen.idx != 0
        assert r.decisions[-1].affinity_tokens == 0

    def test_degraded_is_demoted_shedding_and_dead_are_skipped(self, setup):
        cfg, params = setup
        guards = [EngineGuard(), EngineGuard(), EngineGuard()]
        engines = [_engine(cfg, params, guard=g) for g in guards]
        sup = FleetSupervisor(engines)
        prompt = _prompt(cfg, 16)
        # replica 0 holds the prefix but is DEGRADED: healthy replicas win
        engines[0].submit(prompt, 2)
        engines[0].run()
        guards[0].observe(GuardSignals(pool_util=0.9))
        r = Router("affinity")
        assert r.place(prompt, sup.replicas).idx != 0
        assert not r.decisions[-1].demoted
        # all healthy candidates gone: the degraded one is still usable
        guards[1].observe(GuardSignals(pool_util=1.0))   # SHEDDING
        sup.replicas[2].state = DEAD
        chosen = r.place(prompt, sup.replicas)
        assert chosen.idx == 0 and r.decisions[-1].demoted
        # nothing accepting at all -> None
        guards[0].observe(GuardSignals(pool_util=1.0))
        assert r.place(prompt, sup.replicas) is None

    def test_round_robin_cycles_over_accepting_replicas(self, setup):
        cfg, params = setup
        sup = self._fleet(cfg, params)
        r = Router("round-robin")
        order = [r.place(_prompt(cfg, 4), sup.replicas).idx
                 for _ in range(6)]
        assert order == [0, 1, 2, 0, 1, 2]
        sup.replicas[1].state = DEAD
        order = [r.place(_prompt(cfg, 4), sup.replicas).idx
                 for _ in range(4)]
        assert order == [0, 2, 0, 2]
        with pytest.raises(ValueError, match="unknown routing policy"):
            Router("dartboard")


# ---------------------------------------------------------------------------
# Failover: crash, hang+resume, byte-identical streams, journal replay
# ---------------------------------------------------------------------------


class TestFailover:
    def test_crash_replaces_in_flight_requests_byte_identically(
            self, setup, tmp_path):
        cfg, params = setup
        prompts = [_prompt(cfg, 8) for _ in range(6)]
        ref = _reference_streams(cfg, params, prompts, 8)
        jr = Journal(path=str(tmp_path / "wal.jsonl"))
        sup = FleetSupervisor(
            [_engine(cfg, params) for _ in range(3)],
            journal=jr,
            faults=FaultInjector(canned_fleet_plan(crash_tick=2,
                                                   hang_tick=None)),
            check_invariants_each_tick=True)
        treqs = [sup.submit(p, 8) for p in prompts]
        sup.run_until_drained(max_ticks=500)
        assert [t.result.tokens for t in treqs] == ref
        assert all(t.result.ok for t in treqs)
        assert sup.replicas[0].state == DEAD
        assert sup.c_crashed.value == 1
        assert sup.g_alive.value == 2
        # the crash actually displaced work (the failover path ran)
        moved = [t for t in treqs if t.n_failovers]
        assert moved and all(0 in t.replicas and t.replicas[-1] != 0
                             for t in moved)
        assert sup.tracker.c_failovers.value == len(moved)
        # zero leaked blocks on every SURVIVING pool
        for r in sup.replicas:
            if r.state == SERVING:
                assert leaked_blocks(r.engine.pool,
                                     r.engine.prefix_cache) == 0
        # journal replay reconstructs the tracker's terminal state exactly
        st = Journal.load(str(tmp_path / "wal.jsonl")).replay()
        for t in treqs:
            assert st.requests[t.rid].tokens == t.result.tokens
            assert st.requests[t.rid].finish_reason == \
                t.result.finish_reason
            assert st.requests[t.rid].n_failovers == t.n_failovers
        assert [e["event"] for e in st.replica_events] == ["crash"]

    def test_hang_watchdog_fails_over_then_replica_rejoins(self, setup):
        cfg, params = setup
        prompts = [_prompt(cfg, 8) for _ in range(4)]
        ref = _reference_streams(cfg, params, prompts, 10)
        sup = FleetSupervisor(
            [_engine(cfg, params) for _ in range(2)],
            faults=FaultInjector(canned_fleet_plan(
                crash_tick=10_000,        # no crash in this test
                hang_tick=2, hang_ticks=8, hang_replica=1)),
            hang_grace_ticks=2, check_invariants_each_tick=True)
        treqs = [sup.submit(p, 10) for p in prompts]
        sup.run_until_drained(max_ticks=500)
        assert [t.result.tokens for t in treqs] == ref
        assert sup.c_hung.value == 1
        hung = sup.replicas[1]
        assert hung.state == SERVING and not hung.revoked
        # the revoked requests were cancelled on resume: no leaks, and the
        # replica is empty and placeable again
        assert leaked_blocks(hung.engine.pool, hung.engine.prefix_cache) == 0
        assert not hung.engine.sched.has_work()
        t_new = sup.submit(prompts[0], 2)
        sup.run_until_drained(max_ticks=100)
        assert t_new.result.ok

    def test_organic_engine_death_is_a_crash(self, setup):
        cfg, params = setup
        prompts = [_prompt(cfg, 8) for _ in range(4)]
        ref = _reference_streams(cfg, params, prompts, 6)
        engines = [_engine(cfg, params, step_fault_retries=0)
                   for _ in range(2)]
        # replica 0's pool raises an unabsorbed TransientFault mid-serve:
        # the supervisor must treat the unhandled engine exception as a
        # replica crash and fail its work over
        engines[0].attach_faults(FaultInjector(FaultPlan(seed=0, specs=[
            FaultSpec("step_fault", step=1, duration=50)])))
        sup = FleetSupervisor(engines)
        treqs = [sup.submit(p, 6) for p in prompts]
        sup.run_until_drained(max_ticks=500)
        assert [t.result.tokens for t in treqs] == ref
        assert sup.replicas[0].state == DEAD
        assert sup.c_crashed.value == 1


# ---------------------------------------------------------------------------
# Satellite 2: TTFT observed once fleet-wide, E2E from original submit
# ---------------------------------------------------------------------------


class TestMigrationStamps:
    def test_ttft_once_and_e2e_from_original_submit(self, setup):
        cfg, params = setup
        clock = ManualClock(tick=0.001)
        tel_a = Telemetry(clock=clock)
        tel_b = Telemetry(clock=clock)
        eng_a = _engine(cfg, params, telemetry=tel_a, clock=clock)
        eng_b = _engine(cfg, params, telemetry=tel_b, clock=clock)
        prompt = _prompt(cfg, 8)
        ref = _reference_streams(cfg, params, [prompt], 6)[0]
        h = eng_a.submit(prompt, 6)
        t_submit = h.t_submit
        for _ in range(6):                  # until first token(s) stream
            eng_a.step()
            eng_a.drain()
            if h.tokens:
                break
        emitted = list(h.tokens)
        assert emitted and tel_a.registry.get("serve_ttft_seconds").count == 1
        # replica A dies; one second later the survivor takes the request
        # with the migration stamps
        clock.advance(1.0)
        h2 = eng_b.submit(
            np.concatenate([prompt, np.asarray(emitted, np.int32)]),
            6 - len(emitted), t_submit=t_submit, ttft_observed=True)
        assert h2.t_submit == t_submit       # deadline/E2E base survives
        res = eng_b.run()
        assert emitted + list(res[h2.req_id].tokens) == ref
        # fleet aggregation: exactly ONE TTFT sample across both replicas,
        # and the single E2E sample spans the migration gap (measured from
        # the ORIGINAL submit, not the re-placement)
        agg = MetricRegistry().collect(tel_a.registry, tel_b.registry)
        assert agg.get("serve_ttft_seconds").count == 1
        assert agg.get("serve_e2e_seconds").count == 1
        assert agg.get("serve_e2e_seconds").sum >= 1.0

    def test_fleet_ttft_counts_survive_crash(self, setup):
        cfg, params = setup
        clock = ManualClock(tick=0.001)
        tels = [Telemetry(clock=clock) for _ in range(3)]
        engines = [_engine(cfg, params, telemetry=t, clock=clock)
                   for t in tels]
        sup = FleetSupervisor(
            engines, clock=clock,
            faults=FaultInjector(canned_fleet_plan(crash_tick=2,
                                                   hang_tick=None)))
        prompts = [_prompt(cfg, 8) for _ in range(6)]
        treqs = [sup.submit(p, 8) for p in prompts]
        sup.run_until_drained(max_ticks=500)
        assert any(t.n_failovers for t in treqs)
        # tracker-level (fleet truth): one TTFT + one E2E per request
        assert sup.tracker.h_ttft.count == len(prompts)
        assert sup.tracker.h_e2e.count == len(prompts)
        # replica-level via collect(): migrated requests were NOT observed
        # twice, and E2E samples exist only on the finishing replica
        agg = sup.collect_metrics()
        assert agg.get("serve_ttft_seconds").count == len(prompts)
        assert agg.get("serve_e2e_seconds").count == len(prompts)


# ---------------------------------------------------------------------------
# Satellite 3: collect()/Histogram.merge under live failover
# ---------------------------------------------------------------------------


class TestCollectUnderFailover:
    def test_fleet_aggregate_equals_per_replica_sum_with_a_dead_replica(
            self, setup):
        cfg, params = setup
        clock = ManualClock(tick=0.001)
        tels = [Telemetry(clock=clock) for _ in range(3)]
        engines = [_engine(cfg, params, telemetry=t, clock=clock)
                   for t in tels]
        sup = FleetSupervisor(
            engines, clock=clock,
            faults=FaultInjector(canned_fleet_plan(crash_tick=2,
                                                   hang_tick=None)))
        treqs = [sup.submit(_prompt(cfg, 8), 8) for _ in range(6)]
        sup.run_until_drained(max_ticks=500)
        assert sup.replicas[0].state == DEAD
        agg = sup.collect_metrics()
        # extensive metrics: the fleet aggregate is EXACTLY the sum over
        # per-replica registries — the dead replica's history included,
        # nothing lost, nothing double-counted
        for name in ("serve_requests_submitted_total",
                     "serve_requests_finished_total"):
            per = [t.registry.get(name).value
                   for t in tels if t.registry.get(name)]
            assert agg.get(name).value == sum(per) > 0, name
        for name in ("serve_ttft_seconds", "serve_e2e_seconds",
                     "serve_queue_wait_seconds"):
            per = [t.registry.get(name) for t in tels
                   if t.registry.get(name)]
            assert agg.get(name).count == sum(h.count for h in per), name
            assert agg.get(name).sum == pytest.approx(
                sum(h.sum for h in per)), name
        # engine-rid submissions: every successful placement (incl.
        # failovers) shows up on exactly one replica
        assert agg.get("serve_requests_submitted_total").value == \
            sum(len(t.replicas) for t in treqs)
        # completion count is fleet-wide exact despite the mid-window death
        assert agg.get("serve_requests_finished_total").value == len(treqs)
        # prefix restriction still works across the fleet
        only = sup.collect_metrics(prefix="fleet_")
        assert only.get("fleet_requests_completed_total").value == len(treqs)
        assert only.get("serve_ttft_seconds") is None


# ---------------------------------------------------------------------------
# Satellite 1: the shedding backoff hint
# ---------------------------------------------------------------------------


class TestSheddingBackoffHint:
    def test_guard_hint_tracks_the_clean_streak(self):
        g = EngineGuard(GuardConfig(recover_steps=3))
        g.observe(GuardSignals(pool_util=1.0))
        assert g.state == SHEDDING and g.retry_after_steps() == 3
        g.observe(GuardSignals())
        assert g.retry_after_steps() == 2
        g.observe(GuardSignals(pool_util=1.0))   # dirty: streak resets
        assert g.retry_after_steps() == 3

    def test_engine_raises_with_machine_readable_hint(self, setup):
        cfg, params = setup
        guard = EngineGuard(GuardConfig(pool_util_degraded=0.01,
                                        pool_util_shedding=0.02,
                                        recover_steps=4))
        eng = _engine(cfg, params, guard=guard, prefix_cache=False)
        eng.submit(_prompt(cfg, 8), 4)
        eng.step()
        assert guard.state == SHEDDING
        with pytest.raises(EngineSheddingError) as ei:
            eng.submit(_prompt(cfg, 8), 4)
        assert ei.value.retry_after_steps == 4
        assert "4 clean steps" in str(ei.value)

    def test_supervisor_backoff_rides_the_hint_then_rejects(self, setup):
        cfg, params = setup
        guard = EngineGuard(GuardConfig(recover_steps=5))
        guard.observe(GuardSignals(pool_util=1.0))    # SHEDDING, no work:
        eng = _engine(cfg, params, guard=guard)       # stays shedding
        sup = FleetSupervisor([eng], max_attempts=3)
        treq = sup.submit(_prompt(cfg, 8), 4)
        assert treq.state == "pending"
        assert treq.next_retry_tick > 0      # backoff armed
        assert sup.tracker.c_retries.value == 1
        sup.run_until_drained(max_ticks=200)
        assert treq.result.finish_reason == "rejected"
        assert not treq.result.ok
        assert sup.tracker.c_failed.value == 1

    def test_pending_deadline_enforced_by_the_supervisor(self, setup):
        cfg, params = setup
        clock = ManualClock(tick=0.001)
        guard = EngineGuard(GuardConfig(recover_steps=5))
        guard.observe(GuardSignals(pool_util=1.0))
        eng = _engine(cfg, params, guard=guard, clock=clock)
        sup = FleetSupervisor([eng], clock=clock, max_attempts=100)
        treq = sup.submit(_prompt(cfg, 8), 4, deadline_s=0.5)
        clock.advance(1.0)
        sup.tick()
        assert treq.result.finish_reason == "deadline"
