"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step on CPU, asserting output shapes + no NaNs.
(The FULL configs are exercised only via the dry-run — ShapeDtypeStructs.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.models.registry import (ARCH_IDS, GRID_ARCHS, get_config,
                                   model_fns, reduce_config)
from repro.optim import adamw
from repro.train import make_train_step

pytestmark = pytest.mark.slow    # full arch sweep: minutes of CPU compiles

B, S = 2, 32


def _batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_positions, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = reduce_config(get_config(arch))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    lg = fns.forward(params, batch)
    assert lg.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))


@pytest.mark.parametrize("arch", GRID_ARCHS)
def test_one_train_step(arch, rng):
    cfg = reduce_config(get_config(arch))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    tc = TrainConfig(total_steps=10, warmup_steps=2, learning_rate=1e-3)
    step = jax.jit(make_train_step(fns.loss, tc))
    batch = _batch(cfg, rng)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(np.isfinite(float(metrics["loss"])))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree_util.tree_map(lambda a, b: a - b, params, new_params), 0.0)
    assert delta > 0
    assert int(new_opt.step) == 1


@pytest.mark.parametrize("arch", ["qwen3-4b", "hymba-1.5b", "rwkv6-7b",
                                  "deepseek-v2-236b"])
def test_decode_matches_forward(arch, rng):
    """prefill + decode == teacher-forced forward (exact for non-MoE)."""
    from repro.models import lm as lm_mod
    cfg = reduce_config(get_config(arch))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(1))
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S + 2)), jnp.int32)
    full, _ = lm_mod.lm_forward(params, toks, cfg)
    lg, cache = fns.prefill(params, {"tokens": toks[:, :S]}, S + 2)
    moe = cfg.moe.n_experts > 0
    errs = [float(jnp.abs(lg - full[:, S - 1]).max())]
    for t in range(2):
        lg, cache = fns.decode_step(params, toks[:, S + t], cache)
        errs.append(float(jnp.abs(lg - full[:, S + t]).max()))
    if moe:
        # MoE capacity competition differs between prefill/decode and full
        # forward: agreement is approximate (see DESIGN.md)
        assert max(errs) < 1.0
    else:
        assert max(errs) < 1e-4


def test_full_configs_match_assignment():
    """Lock the exact assigned hyperparameters."""
    c = get_config("moonshot-v1-16b-a3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (48, 2048, 16, 16, 1408, 163840)
    assert (c.moe.n_experts, c.moe.top_k) == (64, 6)
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == (
        60, 5120, 128, 102400)
    assert (c.mla.kv_lora, c.moe.n_experts, c.moe.top_k,
            c.moe.n_shared) == (512, 160, 6, 2)
    c = get_config("qwen3-4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.qk_norm) == (36, 2560, 32, 8, 9728, 151936, True)
    c = get_config("granite-3-8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 4096, 32, 8, 12800, 49155)
    c = get_config("nemotron-4-15b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.activation) == (32, 6144, 48, 8, 24576, 256000,
                                            "relu2")
    c = get_config("llama3.2-3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (28, 3072, 24, 8, 8192, 128256)
    c = get_config("hymba-1.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.ssm.state) == (32, 1600, 25, 5, 5504, 32001, 16)
    c = get_config("whisper-base")
    assert (c.n_layers, c.n_enc_layers, c.d_model, c.n_heads, c.d_ff,
            c.vocab_size) == (6, 6, 512, 8, 2048, 51865)
    c = get_config("rwkv6-7b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (
        32, 4096, 14336, 65536)
    c = get_config("pixtral-12b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 5120, 32, 8, 14336, 131072)


def test_vocab_padding_divisible():
    for arch in GRID_ARCHS:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
