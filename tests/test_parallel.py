"""Distribution tests: sharding rules engine (pure), and multi-device
collectives/DDP/sharded-train in subprocesses with 8 virtual CPU devices
(the main test process must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.parallel.sharding import (DEFAULT_RULES, LONG_CONTEXT_RULES,
                                     logical_to_physical)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> str:
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestShardingRules:
    def setup_method(self):
        # AbstractMesh avoids touching real devices (via the version shim:
        # its constructor signature changed across jax releases)
        from repro.parallel.compat import abstract_mesh
        self.mesh = abstract_mesh((16, 16), ("data", "model"))
        self.mp = abstract_mesh((2, 16, 16), ("pod", "data", "model"))

    def test_divisible_dims_shard(self):
        spec = logical_to_physical(("embed", "mlp"), (4096, 12800),
                                   DEFAULT_RULES, self.mesh)
        assert spec == jax.sharding.PartitionSpec("data", "model")

    def test_non_divisible_degrades_to_replication(self):
        # 8 kv heads on a 16-way model axis → replicate
        spec = logical_to_physical(("kv_heads",), (8,), DEFAULT_RULES,
                                   self.mesh)
        assert spec == jax.sharding.PartitionSpec(None)

    def test_mesh_axis_used_once(self):
        spec = logical_to_physical(("heads", "mlp"), (32, 128),
                                   DEFAULT_RULES, self.mesh)
        # both map to "model"; only the first dim gets it
        assert spec == jax.sharding.PartitionSpec("model", None)

    def test_batch_spans_pod_and_data(self):
        spec = logical_to_physical(("batch", None), (256, 4096),
                                   DEFAULT_RULES, self.mp)
        assert spec == jax.sharding.PartitionSpec(("pod", "data"), None)

    def test_batch_one_long_context_shards_seq(self):
        spec = logical_to_physical(("batch", "seq", None), (1, 524288, 64),
                                   LONG_CONTEXT_RULES, self.mp)
        assert spec == jax.sharding.PartitionSpec(
            None, ("pod", "data"), None)

    def test_partial_tuple_prefix(self):
        # batch=16 divisible by data(16) but not pod*data(32) on multi-pod:
        # order is ("pod","data") → pod(2) divides 16, pod*data=32 doesn't →
        # keeps ("pod",) only
        spec = logical_to_physical(("batch",), (16,), DEFAULT_RULES, self.mp)
        # ("pod",) and "pod" are the same placement; older jax
        # PartitionSpec doesn't normalize the 1-tuple, so accept either
        assert spec in (jax.sharding.PartitionSpec(("pod",)),
                        jax.sharding.PartitionSpec("pod"))


class TestMultiDevice:
    def test_compressed_psum_matches_exact_within_quant_error(self):
        out = run_sub("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.parallel.collectives import compressed_psum
            from repro.parallel.compat import shard_map
            mesh = jax.make_mesh((8,), ("data",))
            x = jnp.arange(8 * 32, dtype=jnp.float32).reshape(8, 32) / 77.0
            def f(xs):
                mean, resid = compressed_psum(xs, "data")
                return mean, resid
            y, r = jax.jit(shard_map(f, mesh=mesh,
                in_specs=jax.sharding.PartitionSpec("data"),
                out_specs=(jax.sharding.PartitionSpec(),
                           jax.sharding.PartitionSpec("data"))))(x)
            exact = jnp.mean(x.reshape(8, 1, 32), 0)
            err = float(jnp.abs(y[0] - exact).max())
            amax = float(jnp.abs(x).max())
            assert err <= amax / 127 + 1e-6, (err, amax / 127)
            print("ERR", err)
        """)
        assert "ERR" in out

    def test_ddp_train_step_with_compression(self):
        out = run_sub("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.models.registry import get_config, reduce_config, model_fns
            from repro.configs.base import TrainConfig
            from repro.optim import adamw
            from repro.train.step import make_ddp_train_step
            cfg = reduce_config(get_config("llama3.2-3b"))
            fns = model_fns(cfg)
            params = fns.init(jax.random.PRNGKey(0))
            opt = adamw.init_state(params)
            errors = jax.tree_util.tree_map(jnp.zeros_like, params)
            mesh = jax.make_mesh((8,), ("data",))
            tc = TrainConfig(grad_compression=True, learning_rate=1e-3)
            step = jax.jit(make_ddp_train_step(fns.loss, tc, mesh))
            batch = {"tokens": jnp.ones((8, 32), jnp.int32),
                     "labels": jnp.ones((8, 32), jnp.int32)}
            p2, o2, e2, m = step(params, opt, errors, batch)
            assert np.isfinite(float(m["loss"]))
            print("LOSS", float(m["loss"]))
        """)
        assert "LOSS" in out

    def test_sharded_train_step_matches_single_device(self):
        """pjit on a 4x2 mesh computes the same loss as 1 device."""
        out = run_sub("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.models.registry import get_config, reduce_config, model_fns
            from repro.configs.base import TrainConfig
            from repro.optim import adamw
            from repro.train import make_train_step
            from repro.parallel.sharding import (DEFAULT_RULES,
                logical_to_physical, sharding_context)
            cfg = reduce_config(get_config("qwen3-4b")).replace(
                vocab_pad_to=16)
            fns = model_fns(cfg)
            params = fns.init(jax.random.PRNGKey(0))
            opt = adamw.init_state(params)
            tc = TrainConfig(learning_rate=1e-3)
            batch = {"tokens": jnp.ones((8, 32), jnp.int32),
                     "labels": jnp.ones((8, 32), jnp.int32)}
            # single device
            _, _, m1 = jax.jit(make_train_step(fns.loss, tc))(params, opt, batch)
            # sharded
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            with sharding_context(mesh, DEFAULT_RULES):
                sh = jax.tree_util.tree_map(
                    lambda spec, a: NamedSharding(mesh, logical_to_physical(
                        spec, a.shape, DEFAULT_RULES, mesh)),
                    fns.specs, params,
                    is_leaf=lambda x: isinstance(x, tuple) and all(
                        isinstance(e, (str, type(None))) for e in x))
                ps = jax.device_put(params, sh)
                _, _, m2 = jax.jit(make_train_step(fns.loss, tc))(ps, opt, batch)
            d = abs(float(m1["loss"]) - float(m2["loss"]))
            assert d < 1e-3, d
            print("DELTA", d)
        """)
        assert "DELTA" in out

    def test_dryrun_single_cell_small_mesh(self):
        """The dry-run path itself works end-to-end on a small mesh."""
        out = run_sub("""
            import jax
            from repro.launch.dryrun import lower_cell
            from repro.models.registry import get_config, reduce_config
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            import repro.launch.dryrun as dr
            import repro.launch.mesh as lm
            lm_orig = lm.make_production_mesh
            dr.make_production_mesh = lambda multi_pod=False: mesh
            cfg = reduce_config(get_config("qwen3-4b"))
            compiled, report = dr.lower_cell(
                "qwen3-4b", "train_4k", cfg_override=cfg.replace(
                    vocab_pad_to=64))
            assert report["roofline"]["flops_per_chip"] > 0
            print("OK", report["roofline"]["dominant"])
        """)
        assert "OK" in out
