"""Paged chunked prefill: kernel vs oracle parity, equivalence with the
dense one-shot suffix path, engine-level greedy equality across chunk
boundaries, decode/prefill interleaving, and multi-turn generated-token
reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill_paged import (flash_prefill_paged,
                                               flash_prefill_paged_op,
                                               paged_prefill_ref,
                                               paged_prefill_split_ref)
from repro.models.registry import get_config, model_fns, reduce_config
from repro.serve import ContinuousEngine
from repro.serve.kv_pool import PagedKVCache
from repro.serve.paged_step import (paged_prefill, paged_prefill_chunked,
                                    paged_prefill_suffix, scatter_prefill,
                                    scatter_prefill_offset)

_rng = np.random.default_rng(23)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen3-4b"))
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, params


def _random_paged_kv(B, Hkv, D, BS, W, *, shuffle=True):
    """Pool + per-sequence disjoint tables over blocks 1.. (0 = garbage)."""
    N = B * W + 1
    kp = jnp.asarray(_rng.normal(size=(N, Hkv, BS, D)), jnp.float32)
    vp = jnp.asarray(_rng.normal(size=(N, Hkv, BS, D)), jnp.float32)
    ids = np.arange(1, N)
    if shuffle:
        ids = _rng.permutation(ids)
    bt = jnp.asarray(ids[:B * W].reshape(B, W), jnp.int32)
    return kp, vp, bt


class TestFlashPrefillPagedKernel:
    @pytest.mark.parametrize("B,Hq,Hkv,D,BS,Sq,pos0s,bq", [
        (2, 4, 2, 16, 8, 7, (0, 5), 8),       # odd suffix, mid-block start
        (2, 8, 2, 32, 16, 33, (13, 40), 16),  # odd suffix, multi-tile q
        (1, 2, 2, 64, 8, 16, (9,), 4),        # start mid-block, tiny tiles
        (3, 4, 4, 16, 8, 24, (0, 17, 3), 128),  # block_q > Sq (clamped)
    ])
    def test_matches_ref_on_ragged_geometry(self, B, Hq, Hkv, D, BS, Sq,
                                            pos0s, bq):
        W = -(-(max(pos0s) + Sq) // BS)
        kp, vp, bt = _random_paged_kv(B, Hkv, D, BS, W)
        q = jnp.asarray(_rng.normal(size=(B, Hq, Sq, D)),
                        jnp.float32) / np.sqrt(D)
        pos0 = jnp.asarray(pos0s, jnp.int32)
        got = flash_prefill_paged(q, kp, vp, bt, pos0, interpret=True,
                                  block_q=bq)
        want = paged_prefill_ref(q, kp, vp, bt, pos0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    @pytest.mark.parametrize("T", [1, 2, 4])
    def test_multi_block_tiles_match_ref(self, T):
        """kv_tile_blocks is layout-only: T pool blocks per kv grid step
        (table padded to a tile multiple with garbage block 0, pad tiles
        skipped above the diagonal) computes the identical attention on
        ragged geometry — odd suffix, mid-block start, non-tile-multiple
        table width."""
        B, Hq, Hkv, D, BS, Sq, bq = 2, 8, 2, 16, 8, 19, 8
        pos0s = (11, 26)
        W = -(-(max(pos0s) + Sq) // BS)
        assert W % T or T == 1 or W // T > 1   # keep the ragged case real
        kp, vp, bt = _random_paged_kv(B, Hkv, D, BS, W)
        q = jnp.asarray(_rng.normal(size=(B, Hq, Sq, D)),
                        jnp.float32) / np.sqrt(D)
        pos0 = jnp.asarray(pos0s, jnp.int32)
        got = flash_prefill_paged(q, kp, vp, bt, pos0, interpret=True,
                                  block_q=bq, kv_tile_blocks=T)
        want = paged_prefill_ref(q, kp, vp, bt, pos0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_ref_matches_dense_suffix_attention(self):
        """The single-table positional-causal oracle computes the same
        attention as PR-2's gather-and-concat ``_suffix_attention`` when
        the suffix KV is pool-resident."""
        from repro.serve.paged_step import _suffix_attention
        B, Hq, Hkv, D, BS, Sq, pos0 = 1, 4, 2, 16, 8, 19, 21
        W = -(-(pos0 + Sq) // BS)
        kp, vp, bt = _random_paged_kv(B, Hkv, D, BS, W)
        q = jnp.asarray(_rng.normal(size=(B, Hq, Sq, D)),
                        jnp.float32) / np.sqrt(D)
        got = paged_prefill_ref(q, kp, vp, bt,
                                jnp.asarray([pos0], jnp.int32))
        # dense path: gather prefix rows [0, pos0) and suffix rows
        # [pos0, pos0+Sq) out of the same pool, then concat + mask
        from repro.kernels.flash_decode_paged.ref import gather_kv
        kv_all_k = gather_kv(kp, bt)
        kv_all_v = gather_kv(vp, bt)
        W_pre = -(-pos0 // BS)          # prefix table incl. partial tail
        k_pre = gather_kv(kp, bt[:, :W_pre])
        v_pre = gather_kv(vp, bt[:, :W_pre])
        k_suf = kv_all_k[:, :, pos0:pos0 + Sq]
        v_suf = kv_all_v[:, :, pos0:pos0 + Sq]
        pre_valid = jnp.arange(W_pre * BS)[None, :] < pos0
        q_pos = pos0 + jnp.arange(Sq)[None, :]
        want = _suffix_attention(q, k_pre, v_pre, k_suf, v_suf, pre_valid,
                                 q_pos, intmax=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    @pytest.mark.parametrize("Sq,pos0,pad_to_cq", [
        (16, 24, False),    # exact cover, mid-block-free
        (16, 21, False),    # exact cover, mid-block pos0
        (16, 37, True),     # cover quantized to chunk blocks + pad
        (8, 3, False),      # W <= tail_blocks: whole table masked
    ])
    def test_split_ref_matches_oracle(self, Sq, pos0, pad_to_cq):
        """The serve-path split oracle (mask-free prefix bulk + masked
        static tail) is the same attention under its table contract —
        exact cover, or cover rounded to chunk-block multiples with
        garbage-block padding."""
        B, Hq, Hkv, D, BS = 1, 4, 2, 16, 8
        cq = -(-Sq // BS)
        cover = -(-(pos0 + Sq) // BS)
        W = (-(-cover // cq) * cq) if pad_to_cq else cover
        kp, vp, bt_full = _random_paged_kv(B, Hkv, D, BS, cover)
        bt = np.zeros((B, W), np.int32)          # pad entries -> block 0
        bt[:, :cover] = np.asarray(bt_full)
        bt = jnp.asarray(bt)
        q = jnp.asarray(_rng.normal(size=(B, Hq, Sq, D)),
                        jnp.float32) / np.sqrt(D)
        p0 = jnp.asarray([pos0], jnp.int32)
        want = paged_prefill_ref(q, kp, vp, bt, p0)
        got = paged_prefill_split_ref(q, kp, vp, bt, p0,
                                      tail_blocks=2 * cq + 1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_cpu_dispatch_falls_back_to_ref(self):
        """Interpret-mode fallback assertion: off-TPU, the op must run (no
        compiled-Pallas requirement) and agree with the pure-JAX oracle in
        both its fallback modes."""
        B, Hq, Hkv, D, BS, Sq, pos0 = 2, 4, 2, 16, 8, 11, 6
        W = -(-(pos0 + Sq) // BS)
        kp, vp, bt = _random_paged_kv(B, Hkv, D, BS, W)
        q = jnp.asarray(_rng.normal(size=(B, Hq, Sq, D)),
                        jnp.float32) / np.sqrt(D)
        pos0 = jnp.asarray([pos0, 3], jnp.int32)
        want = paged_prefill_ref(q, kp, vp, bt, pos0)
        default = flash_prefill_paged_op(q, kp, vp, bt, pos0)
        interp = flash_prefill_paged_op(q, kp, vp, bt, pos0, interpret=True)
        if jax.default_backend() != "tpu":
            # default dispatch IS the oracle off-TPU — bitwise identical
            np.testing.assert_array_equal(np.asarray(default),
                                          np.asarray(want))
        np.testing.assert_allclose(np.asarray(interp), np.asarray(want),
                                   atol=1e-5)

    @pytest.mark.tpu
    def test_compiled_matches_interpret(self):
        """Compiled-Pallas parity — only meaningful (and only runnable) on
        a real TPU backend; conftest skips it cleanly elsewhere."""
        B, Hq, Hkv, D, BS, Sq, pos0 = 1, 4, 2, 128, 16, 32, 24
        W = -(-(pos0 + Sq) // BS)
        kp, vp, bt = _random_paged_kv(B, Hkv, D, BS, W)
        q = jnp.asarray(_rng.normal(size=(B, Hq, Sq, D)),
                        jnp.float32) / np.sqrt(D)
        pos0 = jnp.asarray([pos0], jnp.int32)
        got = flash_prefill_paged(q, kp, vp, bt, pos0)
        want = flash_prefill_paged(q, kp, vp, bt, pos0, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


class TestChunkedPrefillStep:
    """Model-level: chunked == one-shot over identical pool state."""

    def _resident_prefix(self, cfg, params, prompt, m0, pool, table, bs):
        toks = jnp.asarray(prompt[None, :m0], jnp.int32)
        _, ks, vs = paged_prefill(params, toks,
                                  jnp.asarray([m0 - 1], jnp.int32), cfg)
        pool.k, pool.v = scatter_prefill(
            pool.k, pool.v, ks, vs, jnp.asarray(table[:m0 // bs], jnp.int32))

    @pytest.mark.parametrize("m0,chunk", [(16, 16), (16, 24), (32, 8)])
    def test_chunked_equals_one_shot_suffix(self, setup, m0, chunk):
        """Walking the suffix in chunks (incl. chunk sizes that straddle
        block boundaries) must reproduce the one-shot dense suffix
        prefill: same final logits, same pool contents."""
        cfg, params = setup
        bs = 8
        S = 72                           # suffix of 56 = 7 blocks
        prompt = _rng.integers(1, cfg.vocab_size, (S,)).astype(np.int32)
        pools = {}
        for mode in ("dense", "chunked"):
            pool = PagedKVCache(cfg, num_blocks=S // bs, block_size=bs)
            table = np.asarray(pool.alloc(0, S // bs), np.int32)
            self._resident_prefix(cfg, params, prompt, m0, pool, table, bs)
            sl = S - m0
            pos = m0 + np.arange(sl)
            blk = jnp.asarray(table[pos // bs], jnp.int32)
            off = jnp.asarray(pos % bs, jnp.int32)
            if mode == "dense":
                lg, ks, vs = paged_prefill_suffix(
                    params, jnp.asarray(prompt[None, m0:], jnp.int32),
                    jnp.asarray(m0, jnp.int32),
                    jnp.asarray([sl - 1], jnp.int32), pool.k, pool.v,
                    jnp.asarray(table[None, :m0 // bs], jnp.int32),
                    jnp.asarray([m0], jnp.int32), cfg)
                pool.k, pool.v = scatter_prefill_offset(
                    pool.k, pool.v, ks, vs, blk, off)
            else:
                m = m0
                while m < S:
                    c = min(chunk, S - m)
                    cover = -(-(m + c) // bs)
                    lg, pool.k, pool.v = paged_prefill_chunked(
                        params, jnp.asarray(prompt[None, m:m + c],
                                            jnp.int32),
                        jnp.asarray(m, jnp.int32),
                        jnp.asarray([c - 1], jnp.int32), pool.k, pool.v,
                        jnp.asarray(table[None, :cover], jnp.int32),
                        blk[m - m0:m - m0 + c], off[m - m0:m - m0 + c],
                        cfg)
                    m += c
            pools[mode] = (np.asarray(lg), np.asarray(pool.k),
                           np.asarray(pool.v))
        lg_d, k_d, v_d = pools["dense"]
        lg_c, k_c, v_c = pools["chunked"]
        np.testing.assert_allclose(lg_c, lg_d, atol=2e-4)
        assert np.argmax(lg_c) == np.argmax(lg_d)
        np.testing.assert_allclose(k_c, k_d, atol=1e-5)
        np.testing.assert_allclose(v_c, v_d, atol=1e-5)


class TestChunkedEngine:
    @pytest.mark.parametrize("chunk", [8, 24])
    def test_greedy_identical_to_one_shot(self, setup, chunk):
        """Odd prompt lengths (ragged final chunks, mid-block ends) decode
        identically chunked vs one-shot."""
        cfg, params = setup
        lens = (5, 20, 37, 64)
        prompts = [_rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
                   for n in lens]
        outs = {}
        for c in (0, chunk):
            eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                                   max_batch=4, max_len=96, prefill_chunk=c)
            hs = [eng.submit(p, 6) for p in prompts]
            res = eng.run()
            outs[c] = [res[h.req_id].tokens for h in hs]
            for toks in outs[c]:
                assert len(toks) == 6
        assert outs[0] == outs[chunk]

    def test_prefix_cache_mid_block_offsets(self, setup):
        """Shared prefix of non-block-multiple length: chunked prefill
        starts mid-block after the COW tail splice and must agree with the
        one-shot path."""
        cfg, params = setup
        shared = _rng.integers(1, cfg.vocab_size, (21,)).astype(np.int32)
        prompts = [np.concatenate(
            [shared, _rng.integers(1, cfg.vocab_size, (n,))]).astype(
                np.int32) for n in (13, 30, 7)]
        outs = {}
        for c in (0, 16):
            eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                                   max_batch=4, max_len=96, prefill_chunk=c)
            hs = [eng.submit(p, 5) for p in prompts]
            res = eng.run()
            outs[c] = [res[h.req_id].tokens for h in hs]
        assert outs[0] == outs[16]

    def test_long_prompt_does_not_stall_decode(self, setup):
        """Interleaving: a short request already decoding keeps producing
        tokens on the very steps a long prompt spends prefilling."""
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                               max_batch=4, max_len=128, prefill_chunk=8,
                               max_admit_per_step=1)
        short = eng.submit(
            _rng.integers(1, cfg.vocab_size, (8,)).astype(np.int32), 16)
        eng.step()                       # short joins the decode batch
        assert short.state == "decoding"
        long = eng.submit(
            _rng.integers(1, cfg.vocab_size, (80,)).astype(np.int32), 8)
        decoded_during_prefill = 0
        for _ in range(40):              # bounded: 1 admit + 10 chunks
            eng.step()
            if long.state == "prefill":
                decoded_during_prefill += 1
                assert short.n_generated > 0
            if long.state not in ("queued", "prefill"):
                break
        assert long.state == "decoding"
        n_before_join = short.n_generated
        # 80 tokens at chunk 8 = 10 chunks; decode advanced alongside
        assert decoded_during_prefill >= 9
        assert n_before_join >= 9
        eng.run()

    def test_multi_turn_generated_tokens_reused(self, setup):
        """Finish publishes drained decode tokens into the radix tree: a
        follow-up turn extending [prompt ‖ reply] must hit the cache for
        the whole conversation so far, and still decode exactly like a
        cold engine."""
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                               max_batch=4, max_len=96, prefill_chunk=16)
        pA = _rng.integers(1, cfg.vocab_size, (19,)).astype(np.int32)
        h1 = eng.submit(pA, 12)
        r1 = eng.run()
        reply = r1[h1.req_id].tokens
        follow = np.concatenate(
            [pA, np.asarray(reply, np.int32),
             _rng.integers(1, cfg.vocab_size, (7,))]).astype(np.int32)
        hit0 = eng.metrics.prefix_hit_tokens
        h2 = eng.submit(follow, 4)
        r2 = eng.run()
        hit = eng.metrics.prefix_hit_tokens - hit0
        # prompt (19) + cached generated KV (11 = max_new - 1) = 30
        # resident tokens; ≥ 3 full blocks of those must be reused
        assert hit >= 24, hit
        cold = ContinuousEngine(cfg, params, block_size=8, num_blocks=64,
                                max_batch=4, max_len=96, prefix_cache=False)
        h3 = cold.submit(follow, 4)
        r3 = cold.run()
        assert r2[h2.req_id].tokens == r3[h3.req_id].tokens

    def test_warmup_covers_chunked_path(self, setup):
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, block_size=8, num_blocks=32,
                               max_batch=2, max_len=48, prefill_chunk=16)
        eng.warmup()
        assert eng.metrics.steps == 0    # metrics reset after warmup
        h = eng.submit(
            _rng.integers(1, cfg.vocab_size, (20,)).astype(np.int32), 4)
        res = eng.run()
        assert len(res[h.req_id].tokens) == 4


@pytest.mark.slow
class TestBenchSmoke:
    def test_prefill_paged_bench_smoke(self):
        """The benchmark's CI mode: asserts chunked == dense greedy
        outputs (op-level argmax and engine-level tokens) on a tiny
        workload; speed is reported, not gated."""
        import pathlib
        import sys
        root = pathlib.Path(__file__).resolve().parent.parent
        sys.path.insert(0, str(root / "benchmarks"))
        try:
            import prefill_paged_bench
            ratio = prefill_paged_bench.main(["--smoke"])
        finally:
            sys.path.pop(0)
        assert ratio > 0
