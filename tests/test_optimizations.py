"""Correctness of the beyond-paper perf optimizations (§Perf): every opt
must be semantics-preserving — same numbers (or documented approximation)
as the paper-faithful baseline path."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config, model_fns, reduce_config

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> str:
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": SRC}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestDusCacheUpdate:
    @pytest.mark.parametrize("arch", ["qwen3-4b", "hymba-1.5b",
                                      "deepseek-v2-236b"])
    def test_decode_identical_with_dus(self, arch, rng):
        cfg0 = reduce_config(get_config(arch))
        cfg1 = cfg0.replace(opt_dus_cache=True)
        fns0, fns1 = model_fns(cfg0), model_fns(cfg1)
        params = fns0.init(jax.random.PRNGKey(0))
        B, S = 2, 16
        toks = jnp.asarray(rng.integers(1, cfg0.vocab_size, (B, S + 3)),
                           jnp.int32)
        lg0, c0 = fns0.prefill(params, {"tokens": toks[:, :S]}, S + 3)
        lg1, c1 = fns1.prefill(params, {"tokens": toks[:, :S]}, S + 3)
        for t in range(3):
            lg0, c0 = fns0.decode_step(params, toks[:, S + t], c0)
            lg1, c1 = fns1.decode_step(params, toks[:, S + t], c1)
            np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                                       atol=1e-5)


class TestBf16Params:
    def test_loss_close_to_f32(self, rng):
        cfg0 = reduce_config(get_config("llama3.2-3b")).replace(
            compute_dtype="bfloat16")
        cfg1 = cfg0.replace(opt_bf16_params=True)
        fns0, fns1 = model_fns(cfg0), model_fns(cfg1)
        params = fns0.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.asarray(rng.integers(1, 512, (2, 32)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 512, (2, 32)),
                                       jnp.int32)}
        l0, _ = fns0.loss(params, batch)
        l1, _ = fns1.loss(params, batch)
        # identical math (compute was already bf16); cast site differs only
        assert abs(float(l0) - float(l1)) < 1e-2

    def test_grads_flow_through_cast(self, rng):
        cfg = reduce_config(get_config("llama3.2-3b")).replace(
            compute_dtype="bfloat16", opt_bf16_params=True)
        fns = model_fns(cfg)
        params = fns.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                 "labels": jnp.ones((2, 16), jnp.int32)}
        g = jax.grad(lambda p: fns.loss(p, batch)[0])(params)
        leaves = jax.tree_util.tree_leaves(g)
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)
        # grads arrive in the PARAM dtype (f32 master)
        p_leaves = jax.tree_util.tree_leaves(params)
        assert all(l.dtype == p.dtype for l, p in zip(leaves, p_leaves))


class TestAbsorbedMLA:
    def test_equivalent_to_expanded(self, rng):
        from repro.models import mla as mla_mod
        from repro.models.schema import init_params
        cfg = reduce_config(get_config("deepseek-v2-236b"))
        params = init_params(jax.random.PRNGKey(0), mla_mod.mla_schema(cfg))
        x = jnp.asarray(rng.normal(size=(2, 24, cfg.d_model)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(24, dtype=jnp.int32), (2, 24))
        y0 = mla_mod.mla_apply(params, x, cfg, positions=pos)
        y1 = mla_mod.mla_apply(params, x,
                               cfg.replace(opt_mla_absorbed=True),
                               positions=pos)
        rel = float(jnp.abs(y0 - y1).max()) / float(jnp.abs(y0).max())
        assert rel < 1e-4, rel


class TestMoEShardMap:
    def test_matches_global_when_no_drops(self):
        out = run_sub("""
            import jax, jax.numpy as jnp, numpy as np, dataclasses
            from repro.models.registry import get_config, reduce_config
            from repro.models import moe as moe_mod
            from repro.parallel.sharding import sharding_context, DEFAULT_RULES
            from repro.models.schema import init_params
            cfg = reduce_config(get_config("moonshot-v1-16b-a3b"))
            cfg = cfg.replace(moe=dataclasses.replace(
                cfg.moe, capacity_factor=50.0))
            params = init_params(jax.random.PRNGKey(0),
                                 moe_mod.moe_schema(cfg))
            x = jnp.asarray(np.random.default_rng(0).normal(
                size=(4, 16, cfg.d_model)), jnp.float32)
            y_g, _ = moe_mod._moe_apply_global(params, x, cfg)
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            with sharding_context(mesh, DEFAULT_RULES):
                y_s, _ = jax.jit(lambda p, xx: moe_mod.moe_apply_shard_map(
                    p, xx, cfg, mesh))(params, x)
            rel = float(jnp.abs(y_g - y_s).max()) / float(jnp.abs(y_g).max())
            assert rel < 1e-4, rel
            print("REL", rel)
        """)
        assert "REL" in out

    def test_seq_parallel_rules_lower_train(self):
        """SP rules + all opts lower and compile a small sharded train step."""
        out = run_sub("""
            import jax
            import repro.launch.dryrun as dr
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            dr.make_production_mesh = lambda multi_pod=False: mesh
            from repro.models.registry import get_config, reduce_config
            cfg = reduce_config(get_config("moonshot-v1-16b-a3b")).replace(
                vocab_pad_to=64).with_opts(True)
            compiled, report = dr.lower_cell(
                "moonshot-v1-16b-a3b", "train_4k", cfg_override=cfg)
            print("DOM", report["roofline"]["dominant"])
        """)
        assert "DOM" in out


class TestCacheSeqShard:
    def test_decode_lowering_shards_cache(self):
        out = run_sub("""
            import jax
            import repro.launch.dryrun as dr
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            dr.make_production_mesh = lambda multi_pod=False: mesh
            from repro.models.registry import get_config, reduce_config
            cfg = reduce_config(get_config("qwen3-4b")).replace(
                vocab_pad_to=64).with_opts(True)
            compiled, report = dr.lower_cell(
                "qwen3-4b", "decode_32k", cfg_override=cfg)
            args_gb = report["memory_analysis"]["argument_size_in_bytes"]
            # baseline would replicate the cache over model (4x); sharded
            # cache argument bytes must be well below that
            cfg0 = reduce_config(get_config("qwen3-4b")).replace(
                vocab_pad_to=64)
            compiled0, report0 = dr.lower_cell(
                "qwen3-4b", "decode_32k", cfg_override=cfg0)
            args0 = report0["memory_analysis"]["argument_size_in_bytes"]
            print("RATIO", args0 / args_gb)
            assert args0 / args_gb > 2.0, (args0, args_gb)
        """)
        assert "RATIO" in out


class TestInt8KVCache:
    def test_decode_close_to_fp_cache(self, rng):
        cfg0 = reduce_config(get_config("qwen3-4b"))
        cfg1 = cfg0.replace(opt_int8_kv=True, opt_dus_cache=True)
        fns0, fns1 = model_fns(cfg0), model_fns(cfg1)
        params = fns0.init(jax.random.PRNGKey(1))
        B, S = 2, 24
        toks = jnp.asarray(rng.integers(1, cfg0.vocab_size, (B, S + 4)),
                           jnp.int32)
        lg0, c0 = fns0.prefill(params, {"tokens": toks[:, :S]}, S + 4)
        lg1, c1 = fns1.prefill(params, {"tokens": toks[:, :S]}, S + 4)
        assert c1["k"].dtype == jnp.int8
        scale = float(jnp.abs(lg0).max())
        for t in range(4):
            lg0, c0 = fns0.decode_step(params, toks[:, S + t], c0)
            lg1, c1 = fns1.decode_step(params, toks[:, S + t], c1)
            rel = float(jnp.abs(lg1 - lg0).max()) / scale
            assert rel < 0.05, rel

    def test_quantize_roundtrip(self, rng):
        from repro.models.attention import dequantize_kv, quantize_kv
        t = jnp.asarray(rng.normal(size=(2, 4, 64)) * 3, jnp.float32)
        q, s = quantize_kv(t)
        back = dequantize_kv(q, s, jnp.float32)
        rel = float(jnp.abs(back - t).max()) / float(jnp.abs(t).max())
        assert rel < 0.02, rel


class TestOnehotEmbed:
    def test_decode_identical(self, rng):
        cfg0 = reduce_config(get_config("llama3.2-3b"))
        cfg1 = cfg0.replace(opt_onehot_embed=True)
        fns0, fns1 = model_fns(cfg0), model_fns(cfg1)
        params = fns0.init(jax.random.PRNGKey(0))
        B, S = 2, 16
        toks = jnp.asarray(rng.integers(1, cfg0.vocab_size, (B, S + 2)),
                           jnp.int32)
        _, c0 = fns0.prefill(params, {"tokens": toks[:, :S]}, S + 2)
        _, c1 = fns1.prefill(params, {"tokens": toks[:, :S]}, S + 2)
        for t in range(2):
            lg0, c0 = fns0.decode_step(params, toks[:, S + t], c0)
            lg1, c1 = fns1.decode_step(params, toks[:, S + t], c1)
            np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                                       atol=1e-4)
