"""deepseek-v2-236b — MLA (kv_lora=512) + 2 shared / 160 routed top-6 MoE.
[arXiv:2405.04434]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,                 # per-expert hidden
    vocab_size=102400,
    activation="silu",
    rope_theta=10000.0,
    mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64,
                  v_head=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536,
                  n_shared=2, d_shared=1536,
                  first_dense=1, d_ff_dense=12288,
                  capacity_factor=1.25),
)
