"""hymba-1.5b — hybrid: parallel attention + mamba heads, ssm_state=16.
[arXiv:2411.13676] Sliding-window attention (1024) everywhere; meta tokens
stubbed (DESIGN.md §Arch-applicability)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,          # padded to 32256 for TP
    activation="silu",
    window=1024,
    rope_theta=10000.0,
    ssm=SSMConfig(state=16, d_inner=3200, conv_width=4),
)
