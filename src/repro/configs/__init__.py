"""Architecture configs (one module per assigned arch) + config dataclasses."""
from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                MLAConfig, ModelConfig, MoEConfig,
                                PREFILL_32K, ShapeConfig, SSMConfig,
                                TRAIN_4K, TrainConfig)
