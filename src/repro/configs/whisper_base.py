"""whisper-base — enc-dec, conv/mel frontend stubbed (frame embeddings in).
[arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,                # decoder layers
    n_enc_layers=6,
    enc_positions=1500,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,          # padded to 51968 for TP
    activation="gelu",
    rope_theta=0.0,            # additive positions (sinusoidal/learned)
    tie_embeddings=True,
)
