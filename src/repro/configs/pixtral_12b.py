"""pixtral-12b — mistral-nemo decoder backbone; pixtral-ViT frontend stubbed
(input_specs provides patch embeddings). [hf:mistralai/Pixtral-12B-2409]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    activation="silu",
    rope_theta=1000000.0,
)
