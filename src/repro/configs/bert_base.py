"""bert-base — the paper's own evaluation network (encoder-only); used by the
Table-III accuracy benchmark, not part of the 40-cell grid."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="bert-base",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=30522,
    activation="gelu",
    causal=False,              # bidirectional encoder
    rope_theta=10000.0,        # RoPE in place of learned positions
    tie_embeddings=True,
)
