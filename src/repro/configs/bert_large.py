"""bert-large — the paper's larger evaluation network (Table III)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="bert-large",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=30522,
    activation="gelu",
    causal=False,
    rope_theta=10000.0,
    tie_embeddings=True,
)
