"""Model / shape / run configuration dataclasses.

Every assigned architecture is an instance of ``ModelConfig``; the four
input-shape cells are ``ShapeConfig``s. ``reduced()`` derives the smoke-test
config for CPU (same family, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 2
    d_expert: int = 0
    n_shared: int = 0
    d_shared: int = 0            # hidden dim of the shared-expert MLP
    capacity_factor: float = 1.25
    router_softmax: str = "softermax"   # beyond-paper: router uses base-2 too
    aux_loss_weight: float = 0.01
    first_dense: int = 0                # leading layers with dense FFN (DS-V2)
    d_ff_dense: int = 0                 # their hidden dim


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 0              # 0 = no q compression
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 16
    d_inner: int = 0             # 0 = 2*d_model
    conv_width: int = 4
    # rwkv
    head_size: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | rwkv | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 = d_model // n_heads
    vocab_pad_to: int = 256      # Megatron-style padding so vocab shards
    activation: str = "silu"     # silu | gelu | relu2
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # attention
    window: int = 0              # 0 = full attention; >0 = sliding window
    softmax_impl: str = "softermax"   # softmax | base2 | base2_folded |
                                      # softermax | softermax_fixed
    attention_impl: str = "chunked"   # chunked | flash | naive
    attention_chunk: int = 512
    causal: bool = True          # False for encoders (BERT)
    # submodules
    moe: MoEConfig = MoEConfig()
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec
    n_enc_layers: int = 0        # >0 => encoder-decoder (whisper)
    enc_positions: int = 1500    # encoder frame positions (whisper stub)
    # dtypes
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # remat: "none" | "full" (checkpoint layer body)
    remat: str = "full"
    # flags for tests / interpret-mode kernels
    interpret_kernels: bool = False
    # ----- beyond-paper perf optimizations (EXPERIMENTS.md §Perf). All
    # off = the paper-faithful baseline sharding recorded in §Roofline. ----
    opt_bf16_params: bool = False   # cast ≥2-D params to bf16 pre-gather:
                                    # halves FSDP weight-gather + grad bytes
    opt_cache_seq_shard: bool = False  # decode KV cache: shard seq over the
                                    # model axis (distributed online softmax
                                    # — softermax renorm across chips)
    opt_dus_cache: bool = False     # decode cache update via
                                    # dynamic-update-slice (uniform position)
                                    # instead of a full-cache one-hot select
    opt_moe_shard_map: bool = False # EP dispatch via shard_map all-to-all
                                    # instead of global scatter (kills the
                                    # full-buffer all-reduce)
    opt_seq_parallel: bool = False  # train/prefill: activations seq-sharded
                                    # over "model"; weights gathered per layer
                                    # (no boundary all-reduces)
    opt_mla_absorbed: bool = False  # MLA train/prefill in latent space (one
                                    # shared 576-d KV "head") — K/V are never
                                    # expanded, so cross-chip attention moves
                                    # the 576-d latent instead of 128 heads
                                    # × 320 dims (85× less KV wire/memory)
    opt_int8_kv: bool = False       # decode KV cache stored int8 with
                                    # per-row scales (halves cache bytes —
                                    # the serving-side sibling of the paper's
                                    # int8 softmax interfaces). GQA caches
                                    # only (MLA latent / hybrid excluded).
    opt_onehot_embed: bool = False  # decode: embed via one-hot matmul so the
                                    # vocab-sharded table is consumed in
                                    # place (a tiny psum) instead of being
                                    # replicated for the row gather
    opt_serve_resident: bool = False  # decode: weights replicated over
                                    # "data" (TP-resident) instead of FSDP —
                                    # no per-step weight re-gathers
    opt_ring_attention: bool = False  # SP prefill/train attention as a KV
                                    # ring (ppermute) — distributed online
                                    # softermax; equal wire to the KV
                                    # all-gather but O(S_loc) peak memory
                                    # and compute/transfer overlap

    def with_opts(self, on: bool = True) -> "ModelConfig":
        return self.replace(opt_bf16_params=on, opt_cache_seq_shard=on,
                            opt_dus_cache=on, opt_moe_shard_map=on,
                            opt_seq_parallel=on, opt_mla_absorbed=on,
                            opt_onehot_embed=on, opt_serve_resident=on,
                            opt_ring_attention=on,
                            opt_int8_kv=(on and self.family in
                                         ("dense", "moe") and
                                         self.mla is None))

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def compute_dtype_(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def param_dtype_(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell."""

    name: str
    kind: str                # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned shape cells for the LM family.
TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                       LONG_500K)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    microbatches: int = 1        # gradient accumulation
    grad_compression: bool = False  # int8 error-feedback allreduce (shard_map)
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    seed: int = 0
