"""moonshot-v1-16b-a3b — Moonlight-style MoE, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,                 # per-expert hidden
    vocab_size=163840,
    activation="silu",
    rope_theta=50000.0,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408,
                  capacity_factor=1.25),
)
