"""rwkv6-7b — Finch: attention-free, data-dependent decay. [arXiv:2404.05892]
Softermax-inapplicable (no softmax in the architecture) — see DESIGN.md."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="rwkv",
    n_layers=32,
    d_model=4096,
    n_heads=64,                # d_model / head_size
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    activation="relu2",        # channel-mix uses squared relu
    rope_theta=0.0,
    ssm=SSMConfig(head_size=64, decay_lora=64, mix_lora=32),
)
