from repro.optim.adamw import (AdamWState, apply_updates, clip_by_global_norm,
                               init_state, lr_schedule)

__all__ = ["AdamWState", "apply_updates", "clip_by_global_norm", "init_state",
           "lr_schedule"]
