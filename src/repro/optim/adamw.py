"""AdamW with decoupled weight decay, global-norm clipping, LR schedules.

Functional (no optax dependency): state is a pytree {m, v, step} mirroring
the parameters. Optimizer state inherits the parameter sharding (FSDP over
the "data"/"embed" rules), which is what makes the 236B configs fit — the
12 bytes/param of Adam state are sharded over the full mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


@dataclasses.dataclass(frozen=True)
class AdamWState:
    m: Any
    v: Any
    step: jax.Array

    def tree_flatten(self):
        return (self.m, self.v, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    AdamWState,
    lambda s: s.tree_flatten(),
    AdamWState.tree_unflatten,
)


def init_state(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamWState(m=zeros,
                      v=jax.tree_util.tree_map(jnp.zeros_like, params),
                      step=jnp.zeros((), jnp.int32))


def lr_schedule(tc: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to 10%."""
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - tc.warmup_steps) /
                    jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def apply_updates(params, grads, state: AdamWState, tc: TrainConfig
                  ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    grads, gn = clip_by_global_norm(grads, tc.grad_clip)
    step = state.step + 1
    lr = lr_schedule(tc, step)
    b1, b2 = tc.b1, tc.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        u = mh / (jnp.sqrt(vh) + 1e-8)
        if p.ndim >= 2:  # decay matrices only (norms/embed-1d exempt)
            u = u + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, step), {"lr": lr, "grad_norm": gn}
