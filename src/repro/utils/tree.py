"""Pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_num_params(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) if hasattr(l, "shape") else 1 for l in leaves))


def tree_size_bytes(tree) -> int:
    """Total bytes of a pytree of arrays (or ShapeDtypeStructs)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for l in leaves:
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total


def tree_cast(tree, dtype):
    """Cast every floating-point leaf of a pytree to ``dtype``."""

    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_allfinite(tree) -> jax.Array:
    """Scalar bool: every float leaf of the tree is finite."""
    leaves = [
        jnp.all(jnp.isfinite(l))
        for l in jax.tree_util.tree_leaves(tree)
        if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.floating)
    ]
    if not leaves:
        return jnp.asarray(True)
    return jnp.all(jnp.stack(leaves))
