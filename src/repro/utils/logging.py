"""Minimal structured logger (stdout, flush-friendly for long runs)."""
from __future__ import annotations

import logging
import sys

_FMT = "%(asctime)s %(levelname).1s %(name)s :: %(message)s"


def get_logger(name: str = "repro") -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stdout)
        handler.setFormatter(logging.Formatter(_FMT, datefmt="%H:%M:%S"))
        logger.addHandler(handler)
        logger.setLevel(logging.INFO)
        logger.propagate = False
    return logger
