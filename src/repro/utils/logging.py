"""Minimal structured logger (stdout, flush-friendly for long runs).

Two output modes per logger:

* text (default) — ``HH:MM:SS L name :: message``
* JSON  (``json=True``) — one object per line
  (``{"ts", "level", "logger", "msg"}``), the mode log-scraping serving
  deployments want; switching an existing logger's mode swaps its
  formatter in place.

The handler resolves ``sys.stdout`` at *emit* time rather than capturing
the stream at logger creation. A handler bound to the import-time stdout
keeps writing to the original file descriptor after something replaces
``sys.stdout`` — under pytest's capture that meant the first test to
import a module both leaked log lines past capsys and, when a second
differently-configured handler was attached to compensate, printed every
record twice. One marker-tagged handler per logger, current stream,
formatted exactly once.
"""
from __future__ import annotations

import json
import logging
import sys

_FMT = "%(asctime)s %(levelname).1s %(name)s :: %(message)s"
_MARKER = "_repro_handler"


class _CurrentStdoutHandler(logging.StreamHandler):
    """StreamHandler that follows ``sys.stdout`` reassignments."""

    def __init__(self):
        super().__init__(sys.stdout)

    @property
    def stream(self):
        return sys.stdout

    @stream.setter
    def stream(self, value):     # base __init__/setStream write this — the
        pass                     # live property wins, so ignore

    def emit(self, record):
        try:
            super().emit(record)
        except ValueError:       # emit raced a closing captured stream
            pass


class _JsonFormatter(logging.Formatter):
    def format(self, record: logging.LogRecord) -> str:
        out = {
            "ts": self.formatTime(record, "%Y-%m-%dT%H:%M:%S"),
            "level": record.levelname,
            "logger": record.name,
            "msg": record.getMessage(),
        }
        if record.exc_info:
            out["exc"] = self.formatException(record.exc_info)
        return json.dumps(out, ensure_ascii=False)


def get_logger(name: str = "repro", json: bool = False) -> logging.Logger:
    logger = logging.getLogger(name)
    ours = [h for h in logger.handlers if getattr(h, _MARKER, False)]
    if not ours:
        handler = _CurrentStdoutHandler()
        setattr(handler, _MARKER, True)
        logger.addHandler(handler)
        logger.setLevel(logging.INFO)
        logger.propagate = False
        ours = [handler]
    fmt = _JsonFormatter() if json \
        else logging.Formatter(_FMT, datefmt="%H:%M:%S")
    for h in ours:
        h.setFormatter(fmt)
    return logger
