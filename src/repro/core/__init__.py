"""Core: the paper's contribution — Softermax algorithm family, fixed-point
numerics, and the analytical hardware cost model.

Import the submodules directly; function names intentionally are NOT
re-exported at package level (``softermax`` is both a module and its main
function): ``from repro.core.softermax import softermax``.
"""

from repro.core import energy_model, numerics, quant  # noqa: F401
from repro.core import softermax as _softermax_module  # noqa: F401
