"""Softermax algorithm variants (pure jnp reference semantics).

This module is the paper's Figure-3 progression, implemented as composable JAX
functions:

  1. ``softmax_e``        — standard numerically-stable softmax (2 passes, base e)
  2. ``softmax_base2``    — base replacement: 2^x instead of e^x         (§III.A)
  3. ``*_online``         — online normalizer: fused max+denominator pass (§III.C)
  4. ``softermax``        — base-2 + *integer* max + online normalization,
                            the full hardware-friendly algorithm         (§III.C)
  5. ``softermax_fixed``  — bit-faithful fixed-point evaluation with the paper's
                            Table-I Q-formats and LPW units              (§III.B)

All functions operate over the last axis. Masked positions should carry
``numerics.NEG_INF`` (finite) rather than -inf so online recurrences stay
nan-free; fully-masked rows produce all-zero outputs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.numerics import LOG2_E, NEG_INF, exp2, int_ceil, pow2_int

# ---------------------------------------------------------------------------
# 1. Baseline: standard numerically-stable softmax (two explicit passes).
# ---------------------------------------------------------------------------


def softmax_e(x: jax.Array, axis: int = -1) -> jax.Array:
    """Standard max-subtracted softmax, base e. The paper's baseline."""
    m = jnp.max(x, axis=axis, keepdims=True)
    ex = jnp.exp(x - m)
    d = jnp.sum(ex, axis=axis, keepdims=True)
    return _safe_div(ex, d)


# ---------------------------------------------------------------------------
# 2. Base replacement (§III.A).
# ---------------------------------------------------------------------------


def softmax_base2(x: jax.Array, axis: int = -1, fold_log2e: bool = False) -> jax.Array:
    """Base-2 softmax: 2^(x-m) / sum 2^(x-m).

    With ``fold_log2e=True`` the input is pre-scaled by log2(e), making the
    result *identical* to ``softmax_e`` (up to rounding); this is the drop-in
    mode used when no softermax-aware finetuning is available. The scale is a
    single multiply that callers fold into the attention 1/sqrt(d) factor, so
    it is free at the tensor level.
    """
    if fold_log2e:
        x = x * jnp.asarray(LOG2_E, dtype=x.dtype)
    m = jnp.max(x, axis=axis, keepdims=True)
    ex = exp2(x - m)
    d = jnp.sum(ex, axis=axis, keepdims=True)
    return _safe_div(ex, d)


# ---------------------------------------------------------------------------
# 3. Online normalization (§III.C) — reference scan implementations.
#    These define the semantics the Pallas kernels must reproduce.
# ---------------------------------------------------------------------------


def softmax_online(x: jax.Array, base2: bool = False) -> jax.Array:
    """Milakov-Gimelshein online softmax over the last axis via lax.scan.

    Single conceptual pass: running max ``m`` and running denominator ``d``;
    on a new max the old denominator is rescaled by base**(m_old - m_new).
    """
    b = 2.0 if base2 else jnp.e
    _exp = exp2 if base2 else jnp.exp

    x2 = x.reshape((-1, x.shape[-1]))

    def step(carry, xv):
        m, d = carry
        m_new = jnp.maximum(m, xv)
        d = d * _exp_base(m - m_new, base2) + _exp_base(xv - m_new, base2)
        return (m_new, d), None

    init = (jnp.full(x2.shape[:1], NEG_INF, x2.dtype), jnp.zeros(x2.shape[:1], x2.dtype))
    (m, d), _ = jax.lax.scan(step, init, jnp.moveaxis(x2, -1, 0))
    y = _exp(x2 - m[:, None])
    y = _safe_div(y, d[:, None])
    del b
    return y.reshape(x.shape)


def softermax(x: jax.Array, axis: int = -1) -> jax.Array:
    """The full Softermax: base-2, integer max, online normalization.

    Closed form (exact-arithmetic equivalent of the online recurrence):
    ``m = max_i ceil(x_i)``; ``y_i = 2^(x_i - m) / sum_j 2^(x_j - m)``.
    Using the *integer* ceiling of the max only changes the shared scaling of
    numerator and denominator, so in exact arithmetic softermax(x) ==
    softmax_base2(x); the co-design payoff is that every renormalization
    factor 2^(m_old - m_new) has an integer exponent ⇒ a shift in hardware,
    an exact exponent-add on TPU.
    """
    if axis != -1:
        x = jnp.moveaxis(x, axis, -1)
    m = jnp.max(int_ceil(x), axis=-1, keepdims=True)
    # Fully-masked rows: keep the exponent finite.
    m = jnp.maximum(m, NEG_INF)
    ex = exp2(x - m)
    d = jnp.sum(ex, axis=-1, keepdims=True)
    y = _safe_div(ex, d)
    if axis != -1:
        y = jnp.moveaxis(y, -1, axis)
    return y


def softermax_online_scan(x: jax.Array, block: int = 128) -> jax.Array:
    """Block-online softermax over the last axis (reference for the kernels).

    Processes ``block``-wide slices the way the Unnormed Softmax Unit does:
    per-slice IntMax + local power-of-two sums, then a running-sum
    renormalization by an exact power of two (the "shift"), then a final
    normalization pass (the Normalization Unit).
    """
    *lead, V = x.shape
    pad = (-V) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)], constant_values=NEG_INF)
    Vp = x.shape[-1]
    xb = x.reshape((-1, Vp // block, block))

    def step(carry, xv):  # xv: (rows, block)
        m, d = carry
        local_m = jnp.max(int_ceil(xv), axis=-1)  # IntMax over the slice
        m_new = jnp.maximum(m, local_m)
        local_d = jnp.sum(exp2(xv - m_new[:, None]), axis=-1)
        d = d * pow2_int(m - m_new, xv.dtype) + local_d  # shift + add
        return (m_new, d), None

    rows = xb.shape[0]
    init = (jnp.full((rows,), NEG_INF, x.dtype), jnp.zeros((rows,), x.dtype))
    (m, d), _ = jax.lax.scan(step, init, jnp.moveaxis(xb, 1, 0))
    y = exp2(xb.reshape(rows, Vp) - m[:, None])
    y = _safe_div(y, d[:, None])
    y = y.reshape(*lead, Vp)
    if pad:
        y = y[..., :V]
    return y


# ---------------------------------------------------------------------------
# 3b. Split-K merge: combining partial online states (§III.C corollary).
# ---------------------------------------------------------------------------


def softermax_merge(m: jax.Array, d: jax.Array, acc: jax.Array,
                    axis: int = 0):
    """Combine partial Softermax states ``(m, d, acc)`` along ``axis``.

    A partial state is what one pass of the Unnormed Softmax Unit leaves
    behind after streaming *some subset* of the key columns: the running
    (Int)Max ``m``, the unnormalized denominator ``d = Σ 2^(s - m)`` and the
    unnormalized accumulator ``acc = Σ 2^(s - m)·v``. Because every
    renormalization is a pure exponent shift, two such states merge exactly:

        m*   = max(m₁, m₂)
        d*   = d₁·2^(m₁-m*) + d₂·2^(m₂-m*)
        acc* = acc₁·2^(m₁-m*) + acc₂·2^(m₂-m*)

    This operator is associative and commutative (exactly so for the
    rescales under IntMax — integer exponent adds — and up to fp addition
    order for the sums), which is what makes flash-decode-style split-K
    legal for Softermax: KV partitions can be walked by parallel grid lanes
    in any order and combined afterwards. Empty partitions carry the
    identity state ``(NEG_INF, 0, 0)`` and drop out of the merge.

    ``m`` and ``d`` must have a trailing singleton where ``acc`` has the
    feature dim, so the rescale broadcasts. Returns the merged
    ``(m, d, acc)`` with ``axis`` removed; the caller normalizes via
    ``softermax_finalize`` (or feeds the state into a further merge).
    """
    m_star = jnp.max(m, axis=axis, keepdims=True)
    # d == 0 marks the identity state; with NEG_INF finite the exp2 is
    # already 0 (or a harmless 2^0 when *everything* is empty), but the
    # select keeps the merge identity-exact rather than merely approximate
    scale = jnp.where(d > 0, exp2(m - m_star), 0.0)
    d_out = jnp.sum(d * scale, axis=axis)
    acc_out = jnp.sum(acc * scale, axis=axis)
    return jnp.squeeze(m_star, axis=axis), d_out, acc_out


def softermax_finalize(acc: jax.Array, d: jax.Array) -> jax.Array:
    """Normalization Unit for a (merged) partial state: ``acc / d`` with
    fully-masked rows (d == 0) mapped to 0 — the same contract as every
    kernel epilogue."""
    return _safe_div(acc, d)


# ---------------------------------------------------------------------------
# 4. Fixed-point softermax (§III.B, Table I bitwidths).
# ---------------------------------------------------------------------------


def softermax_fixed(
    x: jax.Array,
    bitwidths: Optional[quant.SoftermaxBitwidths] = None,
    block: int = 16,
) -> jax.Array:
    """Bit-faithful fixed-point Softermax with the paper's Table-I formats.

    Pipeline per row, processed ``block`` elements at a time (the hardware
    VectorSize): quantize input to Q(6,2) → IntMax → LPW power-of-two to
    Q(1,15) → accumulate PowSum in Q(10,6) with shift renormalization →
    LPW reciprocal Q(1,7) → output multiply quantized to Q(1,7).

    Differentiable via straight-through estimators (quant.ste_round), so it
    can be used directly in softermax-aware finetuning.
    """
    bw = bitwidths or quant.DEFAULT_BITWIDTHS
    *lead, V = x.shape
    xq = bw.inp.quantize(x)  # Q(6,2) input
    pad = (-V) % block
    if pad:
        xq = jnp.pad(xq, [(0, 0)] * len(lead) + [(0, pad)], constant_values=bw.inp.min_value)
    Vp = xq.shape[-1]
    xb = xq.reshape((-1, Vp // block, block))
    rows = xb.shape[0]

    def step(carry, xv):  # xv: (rows, block)
        m, d = carry
        local_m = jnp.max(jnp.ceil(xv), axis=-1)  # IntMax (Q(6,2) ceil is exact)
        m_new = jnp.maximum(m, local_m)
        # LPW 2^(x - m): exponent in (-inf, 0]; unnormed values Q(1,15)
        un = quant.lpw_exp2(xv - m_new[:, None], out_fmt=bw.unnormed)
        local_d = jnp.sum(un, axis=-1)
        d = bw.powsum.quantize(d * pow2_int(m - m_new, xv.dtype) + local_d)
        return (m_new, d), un

    init = (
        jnp.full((rows,), float(quant.DEFAULT_BITWIDTHS.inp.min_value), xb.dtype),
        jnp.zeros((rows,), xb.dtype),
    )
    (m, d), un = jax.lax.scan(step, init, jnp.moveaxis(xb, 1, 0))
    un = jnp.moveaxis(un, 0, 1).reshape(rows, Vp)  # unnormed numerators (per-block max ref)
    # Normalization Unit: renormalize numerators to the global max (shift),
    # then multiply by the LPW reciprocal of the denominator.
    # NOTE un was computed against the *running* max at its block; recompute the
    # shift per block: numerator_i * 2^(m_block_i - m_final). We recover the
    # running max per block from the scan by recomputing it (cheap, exact).
    run_m = _running_block_intmax(xb, init_m=init[0])  # (rows, nblocks)
    shift = pow2_int(run_m - m[:, None], xb.dtype)  # ≤ 1, integer exponent
    un = un.reshape(rows, Vp // block, block) * shift[..., None]
    un = un.reshape(rows, Vp)
    recip = quant.lpw_reciprocal(d, out_fmt=bw.recip)  # Q(1,7) reciprocal
    y = bw.outp.quantize(un * recip[:, None])
    y = jnp.where(d[:, None] > 0, y, jnp.zeros_like(y))
    y = y.reshape(*lead, Vp)
    if pad:
        y = y[..., :V]
    return y


def _running_block_intmax(xb: jax.Array, init_m: jax.Array) -> jax.Array:
    """Running IntMax *after* each block, matching the scan in softermax_fixed."""

    def step(m, xv):
        m_new = jnp.maximum(m, jnp.max(jnp.ceil(xv), axis=-1))
        return m_new, m_new

    _, ms = jax.lax.scan(step, init_m, jnp.moveaxis(xb, 1, 0))
    return jnp.moveaxis(ms, 0, 1)  # (rows, nblocks)


# ---------------------------------------------------------------------------
# Attention-facing entry point.
# ---------------------------------------------------------------------------


def attention_softmax(
    scores: jax.Array,
    impl: str = "softermax",
    axis: int = -1,
) -> jax.Array:
    """Dispatch table used by every model in the zoo.

    impl ∈ {"softmax" (e-base baseline), "base2", "base2_folded",
            "softermax" (paper), "softermax_fixed" (bit-faithful QAT)}.
    """
    if impl == "softmax":
        return softmax_e(scores, axis=axis)
    if impl == "base2":
        return softmax_base2(scores, axis=axis)
    if impl == "base2_folded":
        return softmax_base2(scores, axis=axis, fold_log2e=True)
    if impl == "softermax":
        return softermax(scores, axis=axis)
    if impl == "softermax_fixed":
        if axis not in (-1, scores.ndim - 1):
            scores = jnp.moveaxis(scores, axis, -1)
            out = softermax_fixed(scores.reshape(-1, scores.shape[-1])).reshape(scores.shape)
            return jnp.moveaxis(out, -1, axis)
        shape = scores.shape
        return softermax_fixed(scores.reshape(-1, shape[-1])).reshape(shape)
    raise ValueError(f"unknown softmax impl: {impl!r}")


def _exp_base(x: jax.Array, base2: bool) -> jax.Array:
    return exp2(x) if base2 else jnp.exp(x)


def _safe_div(num: jax.Array, den: jax.Array) -> jax.Array:
    """num/den with fully-masked rows (den == 0) mapped to 0, not nan."""
    return jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)
