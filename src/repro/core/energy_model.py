"""Analytical 7nm area/energy model for the Softermax hardware units (§IV, §VI.B).

There is no silicon in this repo, so the paper's Table IV / Fig. 5 are
reproduced through an explicit op-count × per-op-cost model:

* Per-op energy/area constants start from Horowitz (ISSCC'14, 45nm) scaled to
  a 7nm-class node, with the DesignWare fp16 transcendental units costed as
  timing-closed synthesis results (an fp16 exp/divider closed at ~1 GHz is
  several times the energy of a raw textbook datapath — this matches the
  paper's observation that general-purpose exp units carry large LUT and
  control overheads).
* Op counts are derived from the algorithm structures in
  ``core/softermax.py``. The pass-count asymmetry matters: the baseline makes
  an explicit max pass then an exp+accumulate pass; Softermax fuses them
  (online normalization), so per-element pipeline/control energy (``REG_E``)
  is paid twice by the baseline and once by Softermax.
* Unit-level comparisons (Table IV rows 1-2) cover the datapaths only;
  PE-level (row 3, Fig. 5) adds MACs, scratchpad traffic and buffer area,
  with a MAGNet-style reduction slice of ``d_per_pe`` MACs per score per PE.

Calibration status vs the paper (asserted in tests/benchmarks):
  unnormed unit  — area 0.25 (paper 0.25), energy ~0.08 (paper 0.10)
  normalization  — area ~0.58 (paper 0.65), energy ~0.38 (paper 0.39)
  full PE        — area ~0.90 (paper 0.90), energy ~0.47 (paper 0.43)
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# ---------------------------------------------------------------------------
# Per-op costs. Energy in pJ, area in um^2, 7nm-class estimates (see module
# docstring for provenance).
# ---------------------------------------------------------------------------

ENERGY_PJ: Dict[str, float] = {
    # narrow fixed point (softermax datapath)
    "int8_cmp": 0.008,       # IntMax ceil+compare
    "int8_mul": 0.056,
    "int16_add": 0.014,      # Q(10,6) accumulate
    "shift16": 0.005,
    "lut4_read": 0.004,      # 4-entry c-LUT / reciprocal LUT
    # DesignWare-style fp16, timing-closed
    "fp16_add": 0.18,
    "fp16_cmp": 0.15,
    "fp16_mul": 0.50,
    "fp16_div": 2.60,
    "fp16_exp": 2.20,        # range-reduce mul + LUT64 + interp + control
    # per-element, per-pass pipeline registers + control (both designs)
    "reg_pass": 0.20,
    # memory
    "sram_rd_byte": 0.25,
    "sram_wr_byte": 0.30,
    # int8 MAC (multiply + 24b accumulate)
    "int8_mac": 0.078,
}

AREA_UM2: Dict[str, float] = {
    "int8_cmp": 4.0,
    "int8_mul": 35.0,
    "int16_add": 10.0,
    "shift16": 6.0,
    "lut4": 8.0,
    "fp16_add": 65.0,
    "fp16_cmp": 30.0,
    "fp16_mul": 160.0,
    "fp16_div": 420.0,
    "fp16_exp": 360.0,
    "reg_lane": 136.0,       # pipeline regs + control per lane (both designs)
    "int8_mac": 48.0,
    "sram_per_kb": 650.0,
}


@dataclasses.dataclass(frozen=True)
class UnitCosts:
    energy_uj: float
    area_um2: float
    breakdown: Dict[str, float]


# ---------------------------------------------------------------------------
# Unit-level models (Table IV, rows 1-2). Datapath only — no scratchpads.
# ---------------------------------------------------------------------------


def baseline_unnormed_unit(rows: int, V: int, width: int = 32) -> UnitCosts:
    """DesignWare-style fp16 max+exp+accumulate over a (rows, V) matrix.

    Two explicit passes: (1) max scan, (2) subtract-max + exp + accumulate.
    """
    n = rows * V
    e = ENERGY_PJ
    energy = {
        "max_cmp": n * e["fp16_cmp"],
        "sub_max": n * e["fp16_add"],
        "exp": n * e["fp16_exp"],
        "acc": n * e["fp16_add"],
        "pipeline": n * 2 * e["reg_pass"],  # two passes
    }
    area = (
        width * (AREA_UM2["fp16_cmp"] + AREA_UM2["fp16_exp"] + AREA_UM2["fp16_add"]
                 + AREA_UM2["reg_lane"])
        + (width - 1) * AREA_UM2["fp16_add"]  # adder tree
    )
    return UnitCosts(sum(energy.values()) * 1e-6, area, energy)


def softermax_unnormed_unit(rows: int, V: int, width: int = 32) -> UnitCosts:
    """Softermax Unnormed Softmax Unit: IntMax + PowerOfTwo(LPW) + Reduction.

    Single fused pass (online normalization); per-slice shift renormalization.
    """
    n = rows * V
    slices = rows * max(V // width, 1)
    e = ENERGY_PJ
    energy = {
        "ceil_cmp": n * e["int8_cmp"],
        "lut_pow2": n * e["lut4_read"],
        "shift_pow2": n * e["shift16"],
        "acc": n * e["int16_add"],
        "renorm_shift": slices * (e["shift16"] + e["int16_add"]),
        "pipeline": n * 1 * e["reg_pass"],  # one fused pass
    }
    area = (
        width * (AREA_UM2["int8_cmp"] + AREA_UM2["lut4"] + AREA_UM2["shift16"]
                 + AREA_UM2["reg_lane"])
        + (width - 1) * AREA_UM2["int16_add"]
        + AREA_UM2["shift16"] + AREA_UM2["int16_add"]  # running-sum renorm path
    )
    return UnitCosts(sum(energy.values()) * 1e-6, area, energy)


def baseline_norm_unit(rows: int, V: int, width: int = 32) -> UnitCosts:
    """Baseline normalization: per-row fp16 reciprocal (DW divider) + per-
    element fp16 multiply."""
    n = rows * V
    e = ENERGY_PJ
    energy = {
        "row_recip": rows * e["fp16_div"],
        "mul": n * e["fp16_mul"],
        "pipeline": n * e["reg_pass"],
    }
    area = (
        width * (AREA_UM2["fp16_mul"] + AREA_UM2["reg_lane"])
        + AREA_UM2["fp16_div"]
    )
    return UnitCosts(sum(energy.values()) * 1e-6, area, energy)


def softermax_norm_unit(rows: int, V: int, width: int = 32) -> UnitCosts:
    """Softermax Normalization Unit: shift renorm + LPW reciprocal + int8 mul."""
    n = rows * V
    e = ENERGY_PJ
    energy = {
        "renorm_shift": n * e["shift16"],
        "recip_lpw": rows * (e["lut4_read"] + e["int8_mul"] + e["int16_add"]),
        "mul": n * e["int8_mul"],
        "pipeline": n * e["reg_pass"],
    }
    area = (
        width * (AREA_UM2["shift16"] + AREA_UM2["int8_mul"] + AREA_UM2["reg_lane"])
        + AREA_UM2["lut4"] + AREA_UM2["int8_mul"] + AREA_UM2["int16_add"]
    )
    return UnitCosts(sum(energy.values()) * 1e-6, area, energy)


# ---------------------------------------------------------------------------
# PE-level model (Table IV row 3, Fig. 5).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PEConfig:
    """MAGNet-style PE (paper Table II). ``d_per_pe`` is the slice of the
    attention reduction dimension each PE owns (the d=64 dot product is
    spread across PEs; partial sums meet in the accumulation collector)."""

    vector_size: int = 32
    n_lanes: int = 32
    d_per_pe: int = 8
    input_buffer_kb: int = 32
    weight_buffer_kb: int = 128
    accum_collector_kb: int = 12


def _softmax_sram_traffic(rows: int, V: int, softmax: str) -> float:
    """Scratchpad traffic (pJ) for the softmax portion at PE level.

    Baseline reads the fp16 scores twice (max pass + exp pass); Softermax
    reads int8 scores once. Both write/read unnormed numerators and write the
    output (fp16 for baseline, Q(1,7)=1B for softermax).
    """
    n = rows * V
    e = ENERGY_PJ
    if softmax == "baseline":
        return n * (2 * 2 * e["sram_rd_byte"]      # 2 passes x fp16
                    + 2 * e["sram_wr_byte"]        # numerators out
                    + 2 * e["sram_rd_byte"]        # numerators back in
                    + 2 * e["sram_wr_byte"])       # fp16 result
    return n * (1 * e["sram_rd_byte"]              # one int8 pass
                + 2 * e["sram_wr_byte"]            # Q(1,15) numerators
                + 2 * e["sram_rd_byte"]            # numerators back in
                + 1 * e["sram_wr_byte"])           # Q(1,7) result


def pe_costs(seq_len: int, softmax: str, cfg: PEConfig = PEConfig()) -> UnitCosts:
    """Energy/area of SELF+Softmax on one PE (the paper's Fig.-5 workload).

    Score matrix rows×V = seq_len×seq_len; each PE contributes ``d_per_pe``
    MACs per score (weight-stationary, operands from local buffers with 2x
    reuse), then runs softmax over its rows.
    """
    rows, V = seq_len, seq_len
    e = ENERGY_PJ
    n_scores = rows * V
    mac_energy = n_scores * cfg.d_per_pe * e["int8_mac"]
    mm_traffic = n_scores * cfg.d_per_pe * e["sram_rd_byte"] * 0.5  # 2x reuse
    if softmax == "baseline":
        u = baseline_unnormed_unit(rows, V, cfg.vector_size)
        nrm = baseline_norm_unit(rows, V, cfg.vector_size)
    elif softmax == "softermax":
        u = softermax_unnormed_unit(rows, V, cfg.vector_size)
        nrm = softermax_norm_unit(rows, V, cfg.vector_size)
    else:
        raise ValueError(softmax)
    energy = {
        "mac": mac_energy,
        "mm_traffic": mm_traffic,
        "softmax_compute": (u.energy_uj + nrm.energy_uj) * 1e6,
        "softmax_traffic": _softmax_sram_traffic(rows, V, softmax),
    }
    sram_kb = cfg.input_buffer_kb + cfg.weight_buffer_kb + cfg.accum_collector_kb
    area = (
        cfg.vector_size * cfg.n_lanes * AREA_UM2["int8_mac"]
        + sram_kb * AREA_UM2["sram_per_kb"]
        + u.area_um2
        + nrm.area_um2
    )
    return UnitCosts(sum(energy.values()) * 1e-6, area, energy)


def table4(seq_len: int = 384, width: int = 32) -> Dict[str, Dict[str, float]]:
    """Reproduce Table IV: softermax/baseline ratios at seq_len (SQuAD=384)."""
    rows = V = seq_len
    b_u = baseline_unnormed_unit(rows, V, width)
    s_u = softermax_unnormed_unit(rows, V, width)
    b_n = baseline_norm_unit(rows, V, width)
    s_n = softermax_norm_unit(rows, V, width)
    b_pe = pe_costs(seq_len, "baseline", PEConfig(vector_size=width, n_lanes=width))
    s_pe = pe_costs(seq_len, "softermax", PEConfig(vector_size=width, n_lanes=width))
    return {
        "unnormed_softmax_unit": {
            "area_ratio": s_u.area_um2 / b_u.area_um2,
            "energy_ratio": s_u.energy_uj / b_u.energy_uj,
            "paper_area": 0.25,
            "paper_energy": 0.10,
        },
        "normalization_unit": {
            "area_ratio": s_n.area_um2 / b_n.area_um2,
            "energy_ratio": s_n.energy_uj / b_n.energy_uj,
            "paper_area": 0.65,
            "paper_energy": 0.39,
        },
        "full_pe": {
            "area_ratio": s_pe.area_um2 / b_pe.area_um2,
            "energy_ratio": s_pe.energy_uj / b_pe.energy_uj,
            "paper_area": 0.90,
            "paper_energy": 0.43,
        },
    }


def fig5_sweep(widths=(16, 32), seq_lens=(128, 256, 384, 512, 1024, 2048, 4096)):
    """Fig. 5: PE energy vs sequence length for 16- and 32-wide configs."""
    out = []
    for w in widths:
        cfg = PEConfig(vector_size=w, n_lanes=w,
                       input_buffer_kb=16 if w == 16 else 32,
                       weight_buffer_kb=32 if w == 16 else 128,
                       accum_collector_kb=6 if w == 16 else 12)
        for L in seq_lens:
            b = pe_costs(L, "baseline", cfg)
            s = pe_costs(L, "softermax", cfg)
            out.append({
                "width": w,
                "seq_len": L,
                "baseline_uj": b.energy_uj,
                "softermax_uj": s.energy_uj,
                "ratio": s.energy_uj / b.energy_uj,
            })
    return out
