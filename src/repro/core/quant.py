"""Fixed-point arithmetic, LPW function units, and QAT utilities (§III.B).

Implements the paper's Table-I Q-formats bit-faithfully at the interfaces:

    Inp Q(6,2) | LocalMax Q(6,2) | Unnormed Q(1,15) | PowSum Q(10,6)
    | Recip Q(1,7) | Outp Q(1,7)

Notation: Q(i, f) has ``i`` integer bits (including sign when signed) and
``f`` fractional bits. Values are simulated in floating point but snapped to
the exact representable grid (round-to-nearest, saturating), which is
bit-equivalent for these narrow formats.

The linear-piecewise (LPW) units mirror the paper's hardware:

* ``lpw_exp2``      — 4-segment LPW of 2^frac on [0,1), shifted by the integer
                      part. With Q(6,2) inputs frac(x·4) is always 0, so the
                      slope LUT is unused and the unit degenerates to a
                      4-entry c-LUT — exactly the observation in §IV.A.
* ``lpw_reciprocal``— normalize to [1,2) by a leading-one shift, 4-segment LPW
                      of 1/m, shift back.

Everything is differentiable through clipped straight-through estimators so
softermax-aware finetuning (§III, "Softermax-aware finetuning") works out of
the box.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Q-format fixed point with clipped STE.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Q(int_bits, frac_bits) fixed-point format."""

    int_bits: int
    frac_bits: int
    signed: bool = True

    @property
    def total_bits(self) -> int:
        return self.int_bits + self.frac_bits

    @property
    def scale(self) -> float:
        return float(2.0 ** self.frac_bits)

    @property
    def max_value(self) -> float:
        if self.signed:
            return float(2.0 ** (self.int_bits - 1) - 1.0 / self.scale)
        return float(2.0 ** self.int_bits - 1.0 / self.scale)

    @property
    def min_value(self) -> float:
        return float(-(2.0 ** (self.int_bits - 1))) if self.signed else 0.0

    def quantize(self, x: jax.Array) -> jax.Array:
        """Round-to-nearest saturating quantization with clipped-STE gradient."""
        xc = jnp.clip(x, self.min_value, self.max_value)
        q = jnp.round(xc * self.scale) / self.scale
        # Straight-through: forward = q, gradient = d(clip)/dx (0 when saturated).
        return xc + jax.lax.stop_gradient(q - xc)

    def quantize_exact(self, x: jax.Array) -> jax.Array:
        """Quantization without STE (for non-differentiable reference paths)."""
        xc = jnp.clip(x, self.min_value, self.max_value)
        return jnp.round(xc * self.scale) / self.scale


@dataclasses.dataclass(frozen=True)
class SoftermaxBitwidths:
    """Paper Table I."""

    inp: QFormat = QFormat(6, 2, signed=True)
    localmax: QFormat = QFormat(6, 2, signed=True)
    unnormed: QFormat = QFormat(1, 15, signed=False)
    powsum: QFormat = QFormat(10, 6, signed=False)
    recip: QFormat = QFormat(1, 7, signed=False)
    outp: QFormat = QFormat(1, 7, signed=False)


DEFAULT_BITWIDTHS = SoftermaxBitwidths()

# ---------------------------------------------------------------------------
# LPW power-of-two unit (§IV.A, "Power of Two Unit").
# ---------------------------------------------------------------------------

_N_SEGMENTS = 4
# Endpoint-interpolation LUTs for 2^t on [0,1): c[k] = 2^(k/4), m[k] = slope.
_EXP2_C = np.array([2.0 ** (k / _N_SEGMENTS) for k in range(_N_SEGMENTS)])
_EXP2_M = np.array(
    [2.0 ** ((k + 1) / _N_SEGMENTS) - 2.0 ** (k / _N_SEGMENTS) for k in range(_N_SEGMENTS)]
)
# LUT entries are themselves stored in Q(1,15) in hardware.
_LUT_FMT = QFormat(1, 15, signed=False)
_EXP2_C_Q = np.round(_EXP2_C * _LUT_FMT.scale) / _LUT_FMT.scale
_EXP2_M_Q = np.round(_EXP2_M * _LUT_FMT.scale) / _LUT_FMT.scale


def _lut_select(seg: jax.Array, table, dtype) -> jax.Array:
    """4-entry LUT realized as a where-chain (TPU/Pallas-friendly: no gather)."""
    out = jnp.full(seg.shape, float(table[0]), dtype)
    for k in range(1, len(table)):
        out = jnp.where(seg == k, jnp.asarray(float(table[k]), dtype), out)
    return out


def lpw_exp2(t: jax.Array, out_fmt: QFormat = DEFAULT_BITWIDTHS.unnormed) -> jax.Array:
    """4-segment LPW approximation of 2^t for t <= 0, quantized to ``out_fmt``.

    Decomposes t = ip + fr with fr ∈ [0,1); computes the LPW of 2^fr; shifts
    right by -ip (a multiplication by an exact power of two).
    """
    t = jnp.asarray(t)
    ip = jnp.floor(t)
    fr = t - ip  # in [0, 1)
    x_scaled = fr * _N_SEGMENTS
    seg = jnp.clip(x_scaled.astype(jnp.int32), 0, _N_SEGMENTS - 1)
    u = x_scaled - seg.astype(t.dtype)  # frac(x_scaled); 0 for Q(6,2) inputs
    c = _lut_select(seg, _EXP2_C_Q, t.dtype)
    m = _lut_select(seg, _EXP2_M_Q, t.dtype)
    lpw = m * u + c
    # Shift by the integer part. ip <= 0; clamp the shift so 2^ip never
    # underflows to a denormal blowup in the simulation.
    ip = jnp.maximum(ip, -40.0)
    val = lpw * jnp.exp2(ip)
    return out_fmt.quantize(val)


# ---------------------------------------------------------------------------
# LPW reciprocal unit (§IV.B, "Normalization Unit").
# ---------------------------------------------------------------------------

_RECIP_C = np.array([1.0 / (1.0 + k / _N_SEGMENTS) for k in range(_N_SEGMENTS)])
_RECIP_M = np.array(
    [
        1.0 / (1.0 + (k + 1) / _N_SEGMENTS) - 1.0 / (1.0 + k / _N_SEGMENTS)
        for k in range(_N_SEGMENTS)
    ]
)


def lpw_reciprocal(d: jax.Array, out_fmt: QFormat = DEFAULT_BITWIDTHS.recip) -> jax.Array:
    """LPW 1/d for d > 0: normalize to [1,2) via leading-one shift, LPW, shift.

    The *mantissa* reciprocal is quantized to ``out_fmt`` (the Q(1,7) `Recip.`
    interface of Table I); the power-of-two un-shift is exact, mirroring the
    hardware where the shift happens after the narrow LPW unit.
    """
    d = jnp.asarray(d)
    safe = jnp.maximum(d, 2.0 ** -20)
    e = jnp.floor(jnp.log2(safe))  # leading-one position
    mant = safe * jnp.exp2(-e)  # in [1, 2)
    x_scaled = (mant - 1.0) * _N_SEGMENTS
    seg = jnp.clip(x_scaled.astype(jnp.int32), 0, _N_SEGMENTS - 1)
    u = x_scaled - seg.astype(d.dtype)
    c = _lut_select(seg, _RECIP_C, d.dtype)
    m = _lut_select(seg, _RECIP_M, d.dtype)
    recip_mant = out_fmt.quantize(m * u + c)  # in (0.5, 1]
    val = recip_mant * jnp.exp2(-e)
    return jnp.where(d > 0, val, 0.0)


def qformat_clip_count(x: jax.Array, fmt: QFormat,
                       where: Optional[jax.Array] = None) -> jax.Array:
    """Number of entries a saturating cast to ``fmt`` would clip — the
    telemetry overflow counters (serve numerics monitors) are built on
    this. ``where`` masks entries that don't participate (e.g. causally
    invalid score positions holding NEG_INF sentinels)."""
    hit = (x > fmt.max_value) | (x < fmt.min_value)
    if where is not None:
        hit = jnp.logical_and(hit, where)
    return jnp.sum(hit)


# ---------------------------------------------------------------------------
# Int8 QAT with percentile calibration (§V, "99.999% percentile calibrator").
# ---------------------------------------------------------------------------


def percentile_scale(x: jax.Array, percentile: float = 99.999) -> jax.Array:
    """Symmetric int8 scale from the |x| percentile (paper's calibrator)."""
    amax = jnp.percentile(jnp.abs(x).reshape(-1), percentile)
    return jnp.maximum(amax, 1e-8) / 127.0


def fake_quant_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Symmetric int8 fake-quant with clipped STE (weights & activations)."""
    xc = jnp.clip(x, -127.0 * scale, 127.0 * scale)
    q = jnp.round(xc / scale) * scale
    return xc + jax.lax.stop_gradient(q - xc)


class Int8Calibrator:
    """Running percentile calibrator: call ``observe`` during calibration
    batches, then ``scale`` is fixed for QAT/finetuning."""

    def __init__(self, percentile: float = 99.999):
        self.percentile = percentile
        self._amaxes: list[float] = []

    def observe(self, x: jax.Array) -> None:
        amax = float(jnp.percentile(jnp.abs(x).reshape(-1), self.percentile))
        self._amaxes.append(amax)

    @property
    def scale(self) -> float:
        if not self._amaxes:
            raise ValueError("calibrator has no observations")
        return max(float(np.median(self._amaxes)), 1e-8) / 127.0
