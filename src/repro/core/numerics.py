"""Numeric helpers shared by the softermax implementations.

Base-2 exponentials are the paper's central numeric substitution: TPU/ASIC
hardware computes ``e^x`` as ``2^(x*log2(e))`` anyway, so moving the network
itself to base 2 deletes the per-element conversion multiply.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# exact in double precision; cast at use sites.
LOG2_E = float(np.log2(np.e))
LN_2 = float(np.log(2.0))

# A very negative (but finite, representable in bf16) score used for masking.
# -inf is avoided inside online recurrences: (-inf) - (-inf) = nan.
NEG_INF = -1e9


def exp2(x: jax.Array) -> jax.Array:
    """2**x elementwise (jnp.exp2; lowers to the VPU exp2 on TPU)."""
    return jnp.exp2(x)


def pow2_int(k: jax.Array, dtype=jnp.float32) -> jax.Array:
    """2**k for *integer* k — the Softermax renormalization factor.

    Because k is an integer, this is an exact power of two: the hardware
    realization is a shifter and the float realization is an exponent add.
    ``exp2`` of an exactly-integer float is exact in IEEE arithmetic, which is
    why the integer-max co-design makes the online renormalization lossless.
    """
    return jnp.exp2(k.astype(dtype))


def int_ceil(x: jax.Array) -> jax.Array:
    """Ceiling used by the IntMax unit (kept in floating point carrying an
    exactly-integral value)."""
    return jnp.ceil(x)
