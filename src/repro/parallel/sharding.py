"""Logical-axis sharding rules engine.

Every parameter and major activation in the model zoo carries *logical* axis
names ("batch", "heads", "mlp", ...). A ``ShardingRules`` table maps logical
axes to mesh axes; ``logical_to_physical`` builds a PartitionSpec, degrading
gracefully when a dimension is not divisible by the assigned mesh axes (e.g.
8 KV heads on a 16-way model axis ⇒ replicate) or when a mesh axis is already
consumed by an earlier dimension.

This is how one model definition serves every mesh in the fleet: the rules
table is the only thing that changes between single-host tests (trivial mesh),
the 16×16 single-pod production mesh, and the (2,16,16) multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
import math
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of axes, or None=replicate)."""

    rules: Dict[str, MeshAxes]

    def get(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        axes = self.rules.get(logical, None)
        if axes is None:
            return ()
        if isinstance(axes, str):
            return (axes,)
        return tuple(axes)


# Default rules for the production meshes. "pod" appears only in multi-pod
# meshes; logical_to_physical silently drops mesh axes absent from the mesh.
DEFAULT_RULES = ShardingRules({
    # activations
    "batch": ("pod", "data"),
    "seq": None,                  # sequence-parallel mode overrides to "data"
    "kv_seq": "model",            # decode-cache seq (opt_cache_seq_shard)
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_experts": "model",
    # params — TP over "model", FSDP over "data"
    "vocab": "model",
    "embed": "data",              # FSDP shard of the d_model param dim
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",           # expert parallelism
    "expert_mlp": None,
    "q_lora": None,
    "kv_lora": None,
    "state": None,
    "conv": None,
    "layers": None,               # scan-stacked; never sharded
})

# Sequence-parallel override for batch=1 long-context cells: the data axis
# shards the KV-cache/sequence dimension instead of batch.
LONG_CONTEXT_RULES = ShardingRules({
    **DEFAULT_RULES.rules,
    "batch": None,
    "seq": ("pod", "data"),
    "kv_seq": ("pod", "data", "model"),  # batch=1: shard cache everywhere
})

# opt_serve_resident (§Perf): decode-time rules — parameters are NOT
# FSDP-sharded over "data" (each decode step would re-gather every weight);
# they stay TP-sharded over "model" and replicated across "data". Per-chip
# residency for the assigned archs is well under HBM (e.g. qwen3-4b bf16:
# 0.5 GB/chip), and decode wire drops to the softmax/stats combines.
SERVE_RULES = ShardingRules({
    **DEFAULT_RULES.rules,
    "embed": None,
})

# opt_seq_parallel (§Perf): activations carry sequence shards over the model
# axis instead of head/mlp shards. Weights keep their storage sharding; XLA
# all-gathers them per layer (FSDP/ZeRO-3 over "model" too). This swaps the
# per-layer boundary ALL-REDUCE of activations (O(S·d) — dominant at long
# seq) for per-layer weight ALL-GATHERS (O(params/L) — much smaller for the
# assigned shapes), and deletes the MoE residual-stream reshard entirely.
SEQ_PARALLEL_RULES = ShardingRules({
    **DEFAULT_RULES.rules,
    "seq": "model",
    "act_heads": None,   # heads stay whole; seq carries the model axis
    "act_mlp": None,
})


def logical_to_physical(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    rules: ShardingRules,
    mesh: Mesh,
) -> PartitionSpec:
    """Build a PartitionSpec for ``shape`` from logical axis names.

    Divisibility-aware: a mesh axis is applied to a dimension only when the
    dim size is divisible by it (progressively — for a tuple assignment like
    ("pod","data"), a prefix that divides is kept). Each mesh axis is used at
    most once across the spec.
    """
    assert len(logical) == len(shape), (logical, shape)
    used: set = set()
    spec = []
    for name, dim in zip(logical, shape):
        axes = [a for a in rules.get(name)
                if a in mesh.shape and a not in used]
        kept = []
        prod = 1
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
        for a in kept:
            used.add(a)
        spec.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return PartitionSpec(*spec)


# ---------------------------------------------------------------------------
# Ambient sharding context: set once by the launcher (dryrun/train/serve),
# no-op in plain unit tests so model code runs unmodified on one device.
# ---------------------------------------------------------------------------


class _Context:
    mesh: Optional[Mesh] = None
    rules: ShardingRules = DEFAULT_RULES


_ctx = _Context()


@contextmanager
def sharding_context(mesh: Optional[Mesh], rules: ShardingRules = DEFAULT_RULES):
    old_mesh, old_rules = _ctx.mesh, _ctx.rules
    _ctx.mesh, _ctx.rules = mesh, rules
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = old_mesh, old_rules


def current_mesh() -> Optional[Mesh]:
    return _ctx.mesh


def current_rules() -> ShardingRules:
    return _ctx.rules


def shard_act(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Annotate an activation with a sharding constraint (no-op without mesh)."""
    mesh = _ctx.mesh
    if mesh is None:
        return x
    spec = logical_to_physical(logical, x.shape, _ctx.rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_sharding(logical: Sequence[Optional[str]], shape: Sequence[int]):
    """NamedSharding for a parameter (None if no ambient mesh)."""
    mesh = _ctx.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_physical(logical, shape, _ctx.rules, mesh))
