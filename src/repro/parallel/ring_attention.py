"""Ring attention with the Softermax online recurrence (distributed softmax).

Sequence-parallel attention without materializing full K/V per chip: each of
the n model-axis ranks owns a sequence shard; K/V shards circulate the ring
(``lax.ppermute``) while every rank folds each visiting block into its
running (IntMax m, denominator d, accumulator) state — the *same* online
normalization the paper builds in hardware, here spanning chips: every
cross-block rescale is an exact power of two because the running max is kept
integral.

Wire bytes equal the all-gather it replaces; the wins are (a) peak memory —
only one visiting KV block is resident instead of the full sequence — and
(b) overlap: each permute transfers while the previous block computes.

Used by ``attention_apply`` when ``cfg.opt_ring_attention`` and the ambient
rules are sequence-parallel (seq sharded over "model").
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.numerics import NEG_INF
from repro.parallel.compat import shard_map


def _ring_inner(q, k, v, *, axis_name: str, n_ranks: int, causal: bool,
                intmax: bool):
    """Per-shard body. q: (B,Hq,S_loc,D); k/v: (B,Hkv,S_loc,D[v])."""
    B, Hq, S_loc, D = q.shape
    Hkv = k.shape[1]
    Dv = v.shape[-1]
    group = Hq // Hkv
    r = jax.lax.axis_index(axis_name)
    qg = q.reshape(B, Hkv, group, S_loc, D)
    q_pos = r * S_loc + jnp.arange(S_loc)

    m = jnp.full((B, Hkv, group, S_loc, 1), NEG_INF, jnp.float32)
    d = jnp.zeros((B, Hkv, group, S_loc, 1), jnp.float32)
    acc = jnp.zeros((B, Hkv, group, S_loc, Dv), jnp.float32)

    perm = [(i, (i + 1) % n_ranks) for i in range(n_ranks)]

    def fold(carry, kv_blk, kv_rank):
        m, d, acc = carry
        k_b, v_b = kv_blk
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_b,
                       preferred_element_type=jnp.float32)
        k_pos = kv_rank * S_loc + jnp.arange(S_loc)
        if causal:
            valid = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(valid, s, NEG_INF)
        sl = jnp.ceil(s) if intmax else s
        m_new = jnp.maximum(m, jnp.max(sl, axis=-1, keepdims=True))
        alpha = jnp.exp2(m - m_new)          # integer exponent under IntMax
        p = jnp.exp2(s - m_new)
        acc = acc * alpha + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(v_b.dtype), v_b,
            preferred_element_type=jnp.float32)
        d = d * alpha + jnp.sum(p, axis=-1, keepdims=True)
        return (m_new, d, acc)

    def step_fn(carry, step):
        state, kv = carry
        kv_rank = jnp.mod(r - step, n_ranks)
        state = fold(state, kv, kv_rank)
        kv = jax.lax.ppermute(kv, axis_name, perm)
        return (state, kv), None

    # lax.scan bounds live memory to ONE visiting KV block (the unrolled
    # form kept n blocks alive); the trailing extra permute is 1/n wire.
    ((m, d, acc), _), _ = jax.lax.scan(
        step_fn, ((m, d, acc), (k, v)), jnp.arange(n_ranks))
    o = jnp.where(d > 0, acc / jnp.where(d > 0, d, 1.0), 0.0)
    return o.reshape(B, Hq, S_loc, Dv).astype(q.dtype)


def ring_attention(
    q: jax.Array,  # (B, Hq, S, D) — seq logically global, sharded by caller
    k: jax.Array,
    v: jax.Array,
    mesh,
    *,
    axis_name: str = "model",
    causal: bool = True,
    intmax: bool = True,
    batch_axes: Tuple[str, ...] = ("pod", "data"),
) -> jax.Array:
    """shard_map entry: shards seq over ``axis_name``, runs the ring."""
    n = mesh.shape[axis_name]
    baxes = tuple(a for a in batch_axes if a in mesh.shape)
    spec = P(baxes if q.shape[0] % max(
        1, _prod(mesh.shape[a] for a in baxes)) == 0 else None,
        None, axis_name, None)
    inner = functools.partial(_ring_inner, axis_name=axis_name, n_ranks=n,
                              causal=causal, intmax=intmax)
    return shard_map(
        inner, mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=spec,
        check_vma=False,
    )(q, k, v)


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out
