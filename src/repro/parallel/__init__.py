"""Distribution: sharding rules engine + collectives (compressed allreduce)."""
