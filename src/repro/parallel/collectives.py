"""Distributed-optimization collectives: int8 compressed all-reduce.

``compressed_psum`` quantizes a tensor to int8 with a per-tensor scale,
all-reduces the int8 payload (as int32 accumulation to avoid overflow at
≤ 2^23 participants), and dequantizes — an 8x reduction in gradient
all-reduce bytes. Residual quantization error is returned for error-feedback
accumulation (the standard trick that keeps compressed SGD convergent:
the error is added back into the next step's gradient before quantization).

Used by the optional DDP train path (``train/step.py`` with
``tc.grad_compression=True``), built on ``shard_map`` over the data axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compressed_psum(x: jax.Array, axis_name: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """int8 error-feedback all-reduce mean over ``axis_name``.

    Returns (mean_of_quantized, local_residual)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    # shared scale so dequantization is consistent across participants
    amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127)
    residual = xf - q * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    mean = (total.astype(jnp.float32) * scale) / n.astype(jnp.float32)
    return mean.astype(x.dtype), residual.astype(x.dtype)


def compressed_psum_tree(grads, axis_name: str, errors=None):
    """Tree-wise compressed all-reduce with error feedback."""
    if errors is None:
        errors = jax.tree_util.tree_map(jnp.zeros_like, grads)
    fed = jax.tree_util.tree_map(lambda g, e: g + e, grads, errors)
    out = jax.tree_util.tree_map(
        lambda g: compressed_psum(g, axis_name), fed)
    means = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    residuals = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
    return means, residuals
