"""jax moved ``jax.experimental.shard_map.shard_map`` to ``jax.shard_map``
(and renamed ``check_rep`` to ``check_vma``); resolve whichever this jax
has so the shard_map call sites work across versions. Same treatment for
``AbstractMesh``, whose constructor went from ``(((name, size), ...))``
pairs to ``(axis_sizes, axis_names)``."""
from __future__ import annotations

import inspect
from typing import Tuple

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def abstract_mesh(axis_sizes: Tuple[int, ...],
                  axis_names: Tuple[str, ...]):
    """Device-free mesh for sharding-rule evaluation, any jax version."""
    from jax.sharding import AbstractMesh
    first = [p for p in
             inspect.signature(AbstractMesh.__init__).parameters
             if p != "self"][0]
    if first == "shape_tuple":          # <= 0.4.x: ((name, size), ...)
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    return AbstractMesh(axis_sizes, axis_names)


__all__ = ["shard_map", "abstract_mesh"]
