"""GPipe-style pipeline parallelism over a mesh axis (the "pod" axis).

The layer-stacked parameters (L, ...) are sharded over the pipeline axis on
their leading dim — each rank owns L/n contiguous layers (its *stage*). The
global batch splits into microbatches that flow through the ring: on every
tick each rank (a) takes its current activation (a fresh microbatch on rank
0, the neighbor's output otherwise), (b) runs its stage (a local lax.scan
over its layer slice), and (c) ``ppermute``s the result rightward. After
``M + n - 1`` ticks all microbatches have exited the last stage; the bubble
fraction is the standard (n-1)/(M+n-1).

This composes with the data/model-axis sharding of everything inside the
stage body: the stage_fn sees ordinary (microbatch, seq, d) activations and
per-layer params, so TP/FSDP rules apply unchanged within a stage.

``pipeline_forward`` wires it for the dense-LM block stack (embedding and
logits are computed outside the pipelined region).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from repro.parallel.compat import shard_map
from jax.sharding import PartitionSpec as P


def _pipeline_inner(stage_params, x_micro, *, stage_fn: Callable,
                    axis_name: str, n_stages: int):
    """Per-rank body. stage_params: this rank's (L/n, ...) layer slice.
    x_micro: (M, B_m, S, d) — full microbatch set (only rank 0 reads it)."""
    r = jax.lax.axis_index(axis_name)
    M = x_micro.shape[0]
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
    buf = jnp.zeros_like(x_micro)            # outputs (filled on last rank)
    cur = jnp.zeros_like(x_micro[0])         # activation in flight

    def tick(carry, t):
        cur, buf = carry
        # rank 0 ingests microbatch t (when in range)
        mb_in = jnp.clip(t, 0, M - 1)
        fresh = jax.lax.dynamic_index_in_dim(x_micro, mb_in, keepdims=False)
        cur = jnp.where(r == 0, fresh, cur)
        out = stage_fn(stage_params, cur)
        # last rank banks microbatch (t - (n-1)) when in range
        mb_out = t - (n_stages - 1)
        bank = (r == n_stages - 1) & (mb_out >= 0)
        buf = jax.lax.cond(
            bank,
            lambda b: jax.lax.dynamic_update_index_in_dim(
                b, out, jnp.clip(mb_out, 0, M - 1), axis=0),
            lambda b: b,
            buf)
        cur = jax.lax.ppermute(out, axis_name, perm)
        return (cur, buf), None

    (cur, buf), _ = jax.lax.scan(tick, (cur, buf),
                                 jnp.arange(M + n_stages - 1))
    # results live on the last rank only — broadcast via masked psum
    mask = (r == n_stages - 1).astype(buf.dtype)
    return jax.lax.psum(buf * mask, axis_name)


def pipeline_apply(
    stacked_params,            # (L, ...) pytree, L % n_stages == 0
    x: jax.Array,              # (B, S, d) global batch
    mesh,
    stage_fn: Callable,        # (layer_params_slice, x) -> x (scans layers)
    *,
    axis_name: str = "pod",
    microbatches: int = 4,
) -> jax.Array:
    """Run the stacked layers as an n-stage GPipe pipeline over ``axis_name``.

    Parameters enter shard_map sharded on their leading (layer) dim; the
    activations enter replicated across the pipeline axis (they are sharded
    over data/model inside stage_fn by the usual rules)."""
    n = mesh.shape[axis_name]
    B = x.shape[0]
    assert B % microbatches == 0, (B, microbatches)
    x_micro = x.reshape((microbatches, B // microbatches) + x.shape[1:])

    param_specs = jax.tree_util.tree_map(
        lambda a: P(axis_name, *([None] * (a.ndim - 1))), stacked_params)

    inner = functools.partial(_pipeline_inner, stage_fn=stage_fn,
                              axis_name=axis_name, n_stages=n)
    out = shard_map(
        inner, mesh=mesh,
        in_specs=(param_specs, P()),
        out_specs=P(),
        check_vma=False,
    )(stacked_params, x_micro)
    return out.reshape(x.shape)
