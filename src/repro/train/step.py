"""Train step factory: grad accumulation, remat, optional compressed DDP.

``make_train_step`` builds the pjit-able step:

    (params, opt_state, batch) → (params, opt_state, metrics)

* microbatching: the global batch splits into ``tc.microbatches`` slices;
  gradients accumulate in fp32 through a ``lax.scan`` — backward collectives
  of microbatch i overlap compute of microbatch i+1 under XLA's scheduler.
* loss = model loss (CE + z-loss + MoE aux) from the registry.
* optional int8 gradient compression (``tc.grad_compression``): the step is
  wrapped in ``shard_map`` over the data axis; per-shard gradients are
  all-reduced with error feedback (``parallel.collectives``) and the error
  buffer rides in the optimizer state extras.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.optim import adamw
from repro.parallel.compat import shard_map


def make_loss_and_grad(loss_fn, tc: TrainConfig):
    def loss_wrap(params, batch):
        loss, metrics = loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_wrap, has_aux=True)

    def accumulate(params, batch):
        """Gradients over the whole batch, microbatched."""
        n = tc.microbatches
        if n <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        B = jax.tree_util.tree_leaves(batch)[0].shape[0]
        assert B % n == 0, (B, n)
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((n, B // n) + x.shape[1:]), batch)

        def body(carry, mb):
            loss_acc, grads_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            grads_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
            return (loss_acc + loss, grads_acc), metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), metrics = jax.lax.scan(
            body, (jnp.zeros(()), zeros), micro)
        grads = jax.tree_util.tree_map(lambda g: g / n, grads)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss_sum / n, metrics, grads

    return accumulate


def make_train_step(loss_fn: Callable, tc: TrainConfig):
    accumulate = make_loss_and_grad(loss_fn, tc)

    def train_step(params, opt_state: adamw.AdamWState, batch
                   ) -> Tuple[Any, adamw.AdamWState, Dict[str, jax.Array]]:
        loss, metrics, grads = accumulate(params, batch)
        params, opt_state, info = adamw.apply_updates(
            params, grads, opt_state, tc)
        out = {"loss": loss, **metrics, **info}
        return params, opt_state, out

    return train_step


def make_ddp_train_step(loss_fn: Callable, tc: TrainConfig, mesh,
                        data_axis: str = "data"):
    """shard_map DDP step with int8 error-feedback gradient compression.

    Parameters are replicated across ``data_axis``; each shard computes
    gradients on its slice of the batch; gradients cross the wire as int8.
    State carries the error-feedback buffers.
    """
    from jax.sharding import PartitionSpec as P

    from repro.parallel.collectives import compressed_psum_tree

    accumulate = make_loss_and_grad(loss_fn, tc)

    def _step(params, opt_state, errors, batch):
        loss, metrics, grads = accumulate(params, batch)
        loss = jax.lax.pmean(loss, data_axis)
        if tc.grad_compression:
            grads, errors = compressed_psum_tree(grads, data_axis, errors)
        else:
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.pmean(g, data_axis), grads)
        params, opt_state, info = adamw.apply_updates(
            params, grads, opt_state, tc)
        return params, opt_state, errors, {"loss": loss, **info}

    pspec_params = P()           # replicated
    pspec_batch = P(data_axis)   # batch-sharded

    return shard_map(
        _step, mesh=mesh,
        in_specs=(pspec_params, pspec_params, pspec_params, pspec_batch),
        out_specs=(pspec_params, pspec_params, pspec_params, pspec_params),
        check_vma=False,
    )
