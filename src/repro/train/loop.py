"""Fault-tolerant training loop.

Responsibilities beyond calling the step:

* **checkpoint/restart** — auto-resume from the latest checkpoint (params,
  optimizer, data-iterator position, step counter); periodic async saves.
* **straggler monitor** — per-step wall time EWMA + variance; steps slower
  than ``mean + k·σ`` are flagged. On a real fleet this signal feeds the
  preemption/replacement controller; here it is logged and exposed for tests
  (with injectable delays).
* **NaN guard** — a non-finite loss aborts with the last good checkpoint on
  disk (restart-safe).
* metrics logging.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import TrainConfig
from repro.data import SyntheticLMData
from repro.optim import adamw
from repro.utils.logging import get_logger

log = get_logger("train")


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time monitor; flags outliers ≥ mean + k·σ."""

    k: float = 4.0
    alpha: float = 0.1
    mean: float = 0.0
    var: float = 0.0
    warmup: int = 5
    _n: int = 0
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self.mean = dt if self._n == 1 else (
                self.mean + (dt - self.mean) / self._n)
            return False
        sigma = max(self.var, 1e-12) ** 0.5
        is_straggler = dt > self.mean + self.k * sigma + 1e-9
        if is_straggler:
            self.flagged += 1
        delta = dt - self.mean
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        return is_straggler


def train(
    *,
    train_step: Callable,
    params,
    data: SyntheticLMData,
    tc: TrainConfig,
    ckpt_dir: Optional[str] = None,
    opt_state: Optional[adamw.AdamWState] = None,
    hooks: Optional[Dict[str, Callable]] = None,
    log_every: int = 10,
) -> Dict[str, Any]:
    """Run to tc.total_steps with checkpoint/restart. Returns final state."""
    hooks = hooks or {}
    opt_state = opt_state if opt_state is not None else adamw.init_state(params)
    start_step = 0
    mgr = CheckpointManager(ckpt_dir, keep=tc.keep_checkpoints) \
        if ckpt_dir else None

    if mgr is not None and mgr.latest_step() is not None:
        step0 = mgr.latest_step()
        restored = mgr.restore(step0, {
            "params": params, "opt": opt_state,
            "data": data.state.to_dict(),
        })
        params, opt_state = restored["params"], restored["opt"]
        from repro.data import DataState
        data.restore(DataState.from_dict(restored["data"]))
        start_step = restored["meta"]["step"]
        log.info("resumed from checkpoint step=%d", start_step)

    monitor = StragglerMonitor()
    history = []
    for step in range(start_step, tc.total_steps):
        batch = next(data)
        if "pre_step" in hooks:
            hooks["pre_step"](step)
        t0 = time.time()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if monitor.observe(dt):
            log.warning("straggler: step %d took %.3fs (mean %.3fs)",
                        step, dt, monitor.mean)
        if not np.isfinite(loss):
            if mgr is not None:
                mgr.wait()
            raise FloatingPointError(
                f"non-finite loss at step {step}; last checkpoint preserved")
        history.append(loss)
        if step % log_every == 0:
            log.info("step %d loss %.4f lr %.2e gnorm %.3f (%.3fs)",
                     step, loss, float(metrics.get("lr", 0)),
                     float(metrics.get("grad_norm", 0)), dt)
        if mgr is not None and (step + 1) % tc.checkpoint_every == 0:
            mgr.save(step + 1, {
                "params": params, "opt": opt_state,
                "data": data.state.to_dict(),
            })
    if mgr is not None:
        mgr.save(tc.total_steps, {
            "params": params, "opt": opt_state,
            "data": data.state.to_dict(),
        })
        mgr.wait()
    return {"params": params, "opt_state": opt_state, "history": history,
            "straggler_flags": monitor.flagged}
