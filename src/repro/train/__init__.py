from repro.train.loop import StragglerMonitor, train
from repro.train.step import make_ddp_train_step, make_train_step

__all__ = ["StragglerMonitor", "train", "make_ddp_train_step",
           "make_train_step"]
