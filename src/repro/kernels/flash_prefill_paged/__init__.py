from repro.kernels.flash_prefill_paged.flash_prefill_paged import (
    flash_prefill_paged)
from repro.kernels.flash_prefill_paged.ops import flash_prefill_paged_op
from repro.kernels.flash_prefill_paged.ref import (paged_prefill_ref,
                                                   paged_prefill_split_ref,
                                                   prefill_gather_oracle)

__all__ = ["flash_prefill_paged", "flash_prefill_paged_op",
           "paged_prefill_ref", "paged_prefill_split_ref",
           "prefill_gather_oracle"]
