"""Pure-jnp oracle for the paged chunked-prefill kernel.

``paged_prefill_ref`` materializes each sequence's logical KV through its
block table (the same ``gather_kv`` as the decode oracle) and runs the
closed-form softermax with the positional causal mask — logical column
``j`` is visible to query row ``pos0 + i`` iff ``j <= pos0 + i``. That one
mask is the whole story: prefix columns (all < pos0) are always visible,
the chunk's own columns form the causal triangle, and table rows past the
last query position (pad tail of the final block) are never visible.

``paged_prefill_split_ref`` is the CPU execution path of the serving
engine's chunked prefill: identical math, but the bulk of the prefix
columns — provably below every query position when the table is an exact
(or chunk-quantized) cover — skip the mask compare/select entirely; only a
static-size tail region is masked. XLA turns the gathers into one take per
chunk, and per-chunk the score matrix is only (Sq, pos0 + Sq) — the
serve-layer chunking, not these oracles, is what kills the quadratic
one-shot blow-up.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.numerics import NEG_INF
from repro.kernels.flash_decode_paged.ref import (gather_kv, gather_kv_dequant,
                                                  gather_scales, split_layout)


def prefill_gather_oracle(
    k_pool: jax.Array,        # (N, Hkv, BS, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, W) int32
    q_pos0,                   # (B,) absolute position of each row's q[0]
    q_len: int,               # Sq as passed to the kernel (incl. padding)
    *,
    kv_tile_blocks: int = 1,
    block_q: int = 128,
    cover_blocks=None,        # (B,) REAL table entries per row; default
    #                           assumes the table is the exact cover of
    #                           pos0 + q_len positions
    k_scale: jax.Array = None,   # (N, Hkv, BS) f32 when the pools are int8
    v_scale: jax.Array = None,
):
    """MEASURE one prefill launch's gather traffic: pad the table as the
    kernel wrapper does, run the ref layer's actual gathers for ONE kv
    walk, and multiply by the number of query tiles ``nq`` — the kernel's
    grid ``(B*Hkv, nq, nk)`` re-streams the whole walk once per query
    tile. Counterpart of ``flash_decode_paged.ref.decode_gather_oracle``;
    ``serve/kernel_costs.prefill_launch_cost`` must match it exactly."""
    B, W = block_tables.shape
    _, Hkv, BS, _ = k_pool.shape
    T, _, nk, Wp = split_layout(W, kv_tile_blocks, 1)
    BQ = min(block_q, q_len)
    nq = -(-q_len // BQ)
    bt = jnp.pad(block_tables.astype(jnp.int32), ((0, 0), (0, Wp - W)))

    gk = gather_kv(k_pool, bt)
    gv = gather_kv(v_pool, bt)
    walk = int(gk.nbytes) + int(gv.nbytes)
    per_block = gk.dtype.itemsize * BS * k_pool.shape[-1] * 2
    if k_scale is not None:
        gks = gather_scales(k_scale, bt)
        gvs = gather_scales(v_scale, bt)
        walk += int(gks.nbytes) + int(gvs.nbytes)
        per_block += gks.dtype.itemsize * BS * 2
    if cover_blocks is None:
        cover_blocks = [-(-(int(p) + q_len) // BS) for p in list(q_pos0)]
    useful_blocks = sum(min(int(c), Wp) for c in list(cover_blocks))
    gather = walk * nq
    useful = useful_blocks * Hkv * per_block * nq
    return {"gather_bytes": gather, "useful_bytes": useful,
            "waste_bytes": gather - useful,
            "grid_steps": B * Hkv * nq * nk, "padded_width": Wp}


def paged_prefill_ref(
    q: jax.Array,             # (B, Hq, Sq, D) pre-scaled
    k_pool: jax.Array,        # (N, Hkv, BS, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, W) int32, logical order
    q_pos0: jax.Array,        # (B,) int32 absolute position of q[:, :, 0]
    *,
    k_scale: jax.Array = None,   # (N, Hkv, BS) f32 when the pools are int8
    v_scale: jax.Array = None,
    intmax: bool = True,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, BS, _ = k_pool.shape
    group = Hq // Hkv
    k = gather_kv_dequant(k_pool, k_scale, block_tables)  # (B,Hkv,W*BS,D)
    v = gather_kv_dequant(v_pool, v_scale, block_tables)
    K = k.shape[2]
    qg = q.reshape(B, Hkv, group, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    qi = q_pos0.astype(jnp.int32)[:, None] + jnp.arange(Sq)[None, :]
    kj = jnp.arange(K, dtype=jnp.int32)
    valid = kj[None, None, :] <= qi[:, :, None]            # (B, Sq, K)
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    # ceil is monotone: ceil(max(s)) == max(ceil(s)), so IntMax needs only
    # a (…, 1) ceil after the reduce instead of a full-size pass — and the
    # denominator divides the (…, D) *output*, not the (…, K) weights,
    # exactly the kernel's normalize-at-the-end dataflow
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.ceil(m) if intmax else m
    p = jnp.exp2(s - m)
    d = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    o = o * jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def paged_prefill_split_ref(
    q: jax.Array,             # (B, Hq, Sq, D) pre-scaled
    k_pool: jax.Array,        # (N, Hkv, BS, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, W) int32, logical order
    q_pos0: jax.Array,        # (B,) int32 absolute position of q[:, :, 0]
    *,
    tail_blocks: int,
    k_scale: jax.Array = None,   # (N, Hkv, BS) f32 when the pools are int8
    v_scale: jax.Array = None,
    intmax: bool = True,
) -> jax.Array:
    """CPU serving fast path: same attention as ``paged_prefill_ref``, but
    the leading ``W - tail_blocks`` table blocks are treated as *provably
    causally valid* — no mask comparison, no select, no NEG_INF fill over
    the bulk of the prefix — and only the static-size tail region pays the
    positional causal mask. The chunked prefill's scores are ~95% prefix
    columns, so dropping two elementwise passes there is a large win on
    elementwise-bound CPU attention.

    CONTRACT (the caller must guarantee, it is not checked): every column
    of the first ``W - tail_blocks`` blocks sits at a logical position
    ``<= min(q_pos0)``. With ``tail_blocks = 2*ceil(Sq/BS) + 1`` this holds
    whenever ``W <= ceil((pos0+Sq)/BS) + ceil(Sq/BS) - 1`` — i.e. the
    table is the exact cover of ``pos0 + Sq`` positions, or that cover
    rounded up to a multiple of the chunk's block count (the engine's
    chunk-table bucketing); padded tail entries (garbage block 0) land in
    the masked region. For arbitrary (e.g. pow2-padded) tables use
    ``paged_prefill_ref``.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, BS, _ = k_pool.shape
    group = Hq // Hkv
    W = block_tables.shape[1]
    t = min(tail_blocks, W)
    qg = q.reshape(B, Hkv, group, Sq, D).astype(jnp.float32)
    qi = q_pos0.astype(jnp.int32)[:, None] + jnp.arange(Sq)[None, :]

    k2 = gather_kv_dequant(k_pool, k_scale, block_tables[:, W - t:])
    v2 = gather_kv_dequant(v_pool, v_scale, block_tables[:, W - t:])
    s2 = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k2.astype(jnp.float32))
    kj = (W - t) * BS + jnp.arange(t * BS, dtype=jnp.int32)
    valid = kj[None, None, :] <= qi[:, :, None]            # (B, Sq, t*BS)
    s2 = jnp.where(valid[:, None, None, :, :], s2, NEG_INF)
    m = jnp.max(s2, axis=-1, keepdims=True)
    if W > t:
        k1 = gather_kv_dequant(k_pool, k_scale, block_tables[:, :W - t])
        v1 = gather_kv_dequant(v_pool, v_scale, block_tables[:, :W - t])
        s1 = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k1.astype(jnp.float32))
        m = jnp.maximum(m, jnp.max(s1, axis=-1, keepdims=True))
    m = jnp.ceil(m) if intmax else m
    p2 = jnp.exp2(s2 - m)
    d = jnp.sum(p2, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p2, v2.astype(jnp.float32))
    if W > t:
        p1 = jnp.exp2(s1 - m)
        d = d + jnp.sum(p1, axis=-1, keepdims=True)
        o = o + jnp.einsum("bhgqk,bhkd->bhgqd", p1, v1.astype(jnp.float32))
    o = o * jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)
