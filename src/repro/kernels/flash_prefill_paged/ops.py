"""Backend dispatch for paged chunked-prefill attention.

Single dispatcher for every caller (the serving engine's chunk step routes
here too):

* TPU backend          — the compiled Pallas kernel (scalar-prefetch gather).
* ``interpret=True``   — the same kernel under the Pallas interpreter (CPU
  CI exercises the exact kernel dataflow this way).
* anywhere else        — pure JAX: the gather oracle ``paged_prefill_ref``,
  or the faster ``paged_prefill_split_ref`` when the caller passes
  ``split_tail_blocks`` (promising that the table width honors the split
  contract — exact cover or chunk-quantized; see ref.py). Identical math
  either way, so CPU serving stays fast (the interpreter is orders of
  magnitude slower than XLA on the same shapes).

``kv_tile_blocks`` is a kernel *layout* knob (pool blocks gathered per kv
grid step), not a math knob — the pure-JAX fallbacks compute the identical
attention and ignore it.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_prefill_paged.flash_prefill_paged import (
    flash_prefill_paged)
from repro.kernels.flash_prefill_paged.ref import (paged_prefill_ref,
                                                   paged_prefill_split_ref,
                                                   prefill_gather_oracle)


def flash_prefill_paged_op(q, k_pool, v_pool, block_tables, q_pos0, *,
                           k_scale=None, v_scale=None,
                           intmax: bool = True,
                           kv_tile_blocks: int = 1,
                           interpret: bool = False,
                           split_tail_blocks: Optional[int] = None
                           ) -> jax.Array:
    if interpret:
        return flash_prefill_paged(q, k_pool, v_pool, block_tables, q_pos0,
                                   k_scale=k_scale, v_scale=v_scale,
                                   intmax=intmax,
                                   kv_tile_blocks=kv_tile_blocks,
                                   interpret=True)
    if jax.default_backend() == "tpu":
        return flash_prefill_paged(q, k_pool, v_pool, block_tables, q_pos0,
                                   k_scale=k_scale, v_scale=v_scale,
                                   intmax=intmax,
                                   kv_tile_blocks=kv_tile_blocks)
    if split_tail_blocks is not None:
        return paged_prefill_split_ref(q, k_pool, v_pool, block_tables,
                                       q_pos0,
                                       tail_blocks=split_tail_blocks,
                                       k_scale=k_scale, v_scale=v_scale,
                                       intmax=intmax)
    return paged_prefill_ref(q, k_pool, v_pool, block_tables, q_pos0,
                             k_scale=k_scale, v_scale=v_scale,
                             intmax=intmax)


__all__ = ["flash_prefill_paged_op", "flash_prefill_paged",
           "paged_prefill_ref", "paged_prefill_split_ref",
           "prefill_gather_oracle"]
