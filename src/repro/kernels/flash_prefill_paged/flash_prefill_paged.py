"""Pallas TPU kernel: paged chunked-prefill attention with Softermax.

The prefill-side sibling of ``kernels/flash_decode_paged``: a *tile* of
suffix queries (one chunk of a long prompt, at absolute positions
``pos0 .. pos0+Sq-1``) attends directly against block-table-resident KV —
the cached prefix plus the chunk's own freshly scattered rows — with the
paper's Unnormed-Softmax-Unit recurrence carrying the running (IntMax,
denominator, accumulator) triple across KV tiles. Because every Softermax
rescale is an exact power-of-two exponent add, the physical blocks can be
streamed in table order with no pre-pass and no gather: the online state
*is* the carry, which is what makes chunked prefill free for this layout —
across chunk boundaries nothing needs to be handed over, the earlier
chunks' contribution lives in the pool and the recurrence is order-free.

Layout (same conventions as the decode kernel):

* KV is the pool ``(N, Hkv, BS, D)``; ``block_tables`` holds each
  sequence's physical block ids in *logical* order, so the key at logical
  position ``p`` lives at ``pool[table[p // BS], :, p % BS]``.
* The table is a scalar-prefetch operand: the KV BlockSpec index maps do
  the gather.
* **GQA grouping** — grid axis 0 is ``B*Hkv``: one lane owns a whole GQA
  group with a ``(group, BQ, D)`` query tile (flattened to
  ``(group*BQ, D)`` for the dots), so the block-table gather runs once per
  KV head instead of once per query head and the QK/AV dots are
  ``group``× taller MXU matmuls.
* **Multi-block KV tiles** — each kv grid step gathers ``kv_tile_blocks``
  (T) pool blocks (T block-granular DMAs overlapped within the step) and
  processes them as one ``(T*BS, D)`` VMEM tile; the wrapper pads the
  table to a tile multiple with garbage block 0. Grid
  ``(B*Hkv, nq, ceil(W/T))``; the kv axis is sequential and scratch
  carries (m, d, acc) across it.
* Causality is positional: column ``jj*T*BS + r`` is valid for query row
  ``pos0 + i*BQ + s`` iff ``col <= row`` — this one mask covers the
  all-valid prefix columns, the in-chunk triangle, and the not-yet-written
  tail rows of the last block alike. KV tiles entirely above the diagonal
  of a query tile are skipped (prefix tiles are the workload and are never
  skippable); the padded table tail always sits above the diagonal, so pad
  tiles cost no compute.

Query rows past the true chunk length are padding: every score they keep
is finite (column 0 is always causally valid), so they produce garbage-
but-finite output rows the caller slices off.

**Fused int8 dequant-on-gather.** With ``k_scale``/``v_scale`` (per-row
f32 scales, block-indexed like the pools) the K/V pools are int8: the
gather DMA moves half the bytes and dequantization folds into the score
tile — ``S *= k_scale`` per column after the QK dot, ``p *= v_scale``
before the AV dot (both exact; a scale is constant along its K/V row).
The rescales are O(group·BQ·T·BS) where widening the tiles would be
O(T·BS·D), and the accumulator stays fp32 either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.numerics import NEG_INF
from repro.core.softermax import softermax_finalize
from repro.kernels.flash_decode_paged.flash_decode_paged import concat_tiles
from repro.kernels.flash_decode_paged.ref import split_layout


def _paged_prefill_kernel(bt_ref, pos_ref, q_ref, *rest, intmax: bool,
                          block_q: int, block_size: int, tile_blocks: int,
                          group: int, quantized: bool):
    T = tile_blocks
    k_refs, v_refs = rest[:T], rest[T:2 * T]
    n = 2 * T
    if quantized:
        ksc_refs, vsc_refs = rest[n:n + T], rest[n + T:n + 2 * T]
        n += 2 * T
    o_ref, acc_scr, m_scr, d_scr = rest[n:]
    i, j = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        d_scr[...] = jnp.zeros_like(d_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = pos_ref[0, 0] + i * block_q     # absolute pos of q row 0
    k_start = j * (T * block_size)            # logical pos of kv tile row 0

    @pl.when(k_start <= q_start + block_q - 1)
    def _body():
        # (G, BQ, D) query tile flattened to (G*BQ, D): every group head
        # shares the gathered KV tile and the mask repeats per head
        q = q_ref[0].astype(jnp.float32).reshape(group * block_q, -1)
        k = concat_tiles(k_refs)
        v = concat_tiles(v_refs)
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (G*BQ, T*BS)
        if quantized:
            # k_scale is constant per K row: scaling the score columns is
            # the exact dequant, for O(G·BQ·T·BS) instead of O(T·BS·D)
            s = s * concat_tiles(ksc_refs, axis=1)    # (1, T*BS) broadcast
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        qi = q_start + rows % block_q                 # same mask per head
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj <= qi, s, NEG_INF)
        m_prev = m_scr[...]
        # IntMax via ceil-after-reduce (ceil is monotone, so this equals
        # max(ceil(s)) with a (G*BQ, 1) ceil instead of a full-size pass)
        sm = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, jnp.ceil(sm) if intmax else sm)
        alpha = jnp.exp2(m_prev - m_new)              # exact power-of-two
        p = jnp.exp2(s - m_new)
        if quantized:
            pv = p * concat_tiles(vsc_refs, axis=1)   # fold v_scale into p
        else:
            pv = p
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            pv, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        d_scr[...] = d_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _fin():
        o = softermax_finalize(acc_scr[...], d_scr[...])   # (G*BQ, D)
        o_ref[0] = o.reshape(group, block_q, -1).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("intmax", "block_q", "kv_tile_blocks", "interpret"))
def flash_prefill_paged(
    q: jax.Array,             # (B, Hq, Sq, D) pre-scaled chunk queries
    k_pool: jax.Array,        # (N, Hkv, BS, D) physical block pool
    v_pool: jax.Array,        # (N, Hkv, BS, D)
    block_tables: jax.Array,  # (B, W) int32, logical order; must cover every
    #                           position <= pos0 + Sq - 1
    q_pos0: jax.Array,        # (B,) int32 absolute position of q[:, :, 0]
    *,
    k_scale: jax.Array = None,   # (N, Hkv, BS) f32: int8 pools' row scales
    v_scale: jax.Array = None,
    intmax: bool = True,
    block_q: int = 128,
    kv_tile_blocks: int = 1,  # pool blocks gathered per kv grid step (T)
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    N, Hkv, BS, _ = k_pool.shape
    W = block_tables.shape[1]
    G = Hq // Hkv
    quantized = k_scale is not None

    # prefill has no split axis: split_layout with split_k=1 degenerates
    # to the pure tile clamp + pad, keeping the geometry derivation shared
    T, _, nk, Wp = split_layout(W, kv_tile_blocks, 1)
    bt = jnp.pad(block_tables.astype(jnp.int32), ((0, 0), (0, Wp - W)))

    block_q = min(block_q, Sq)
    pq = (-Sq) % block_q
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    Sqp = Sq + pq
    nq = Sqp // block_q

    qf = qp.reshape(B, Hkv, G, Sqp, D).reshape(B * Hkv, G, Sqp, D)
    pos = q_pos0.astype(jnp.int32).reshape(B, 1)

    def kv_map(t):
        # one gather map per tile slot; values and scales share it
        def _map(bh, i, j, bt_ref):
            return (bt_ref[bh // Hkv, j * T + t], bh % Hkv, 0, 0)
        return _map

    in_specs = [
        pl.BlockSpec((1, 1), lambda bh, i, j, bt_ref: (bh // Hkv, 0)),
        pl.BlockSpec((1, G, block_q, D),
                     lambda bh, i, j, bt_ref: (bh, 0, i, 0)),
    ]
    in_specs += [pl.BlockSpec((1, 1, BS, D), kv_map(t)) for t in range(T)]
    in_specs += [pl.BlockSpec((1, 1, BS, D), kv_map(t)) for t in range(T)]
    inputs = [pos, qf] + [k_pool] * T + [v_pool] * T
    if quantized:
        # scales ride the same scalar-prefetch gather as the values; the
        # trailing unit axis keeps in-kernel reads 2-D (TPU-friendly)
        ksr = k_scale.astype(jnp.float32).reshape(N, Hkv, 1, BS)
        vsr = v_scale.astype(jnp.float32).reshape(N, Hkv, 1, BS)
        in_specs += [pl.BlockSpec((1, 1, 1, BS), kv_map(t))
                     for t in range(T)]
        in_specs += [pl.BlockSpec((1, 1, 1, BS), kv_map(t))
                     for t in range(T)]
        inputs += [ksr] * T + [vsr] * T

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hkv, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, G, block_q, D),
                               lambda bh, i, j, bt_ref: (bh, 0, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((G * block_q, D), jnp.float32),
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, 1), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        functools.partial(_paged_prefill_kernel, intmax=intmax,
                          block_q=block_q, block_size=BS, tile_blocks=T,
                          group=G, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, Sqp, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bt, *inputs)

    out = out.reshape(B, Hkv, G, Sqp, D).reshape(B, Hq, Sqp, D)
    return out[:, :, :Sq, :]
