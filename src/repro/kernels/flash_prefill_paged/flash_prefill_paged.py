"""Pallas TPU kernel: paged chunked-prefill attention with Softermax.

The prefill-side sibling of ``kernels/flash_decode_paged``: a *tile* of
suffix queries (one chunk of a long prompt, at absolute positions
``pos0 .. pos0+Sq-1``) attends directly against block-table-resident KV —
the cached prefix plus the chunk's own freshly scattered rows — with the
paper's Unnormed-Softmax-Unit recurrence carrying the running (IntMax,
denominator, accumulator) triple across KV tiles. Because every Softermax
rescale is an exact power-of-two exponent add, the physical blocks can be
streamed in table order with no pre-pass and no gather: the online state
*is* the carry, which is what makes chunked prefill free for this layout —
across chunk boundaries nothing needs to be handed over, the earlier
chunks' contribution lives in the pool and the recurrence is order-free.

Layout (same conventions as the decode kernel):

* KV is the pool ``(N, Hkv, BS, D)``; ``block_tables`` holds each
  sequence's physical block ids in *logical* order, so the key at logical
  position ``p`` lives at ``pool[table[p // BS], :, p % BS]``.
* The table is a scalar-prefetch operand: the KV BlockSpec index map does
  the gather, DMAing one physical block per kv grid step into VMEM.
* Grid ``(B*Hq, nq, W)``; the kv axis is sequential and scratch carries
  (m, d, acc) across it. Causality is positional: column ``j*BS + r`` is
  valid for query row ``pos0 + i*BQ + s`` iff ``col <= row`` — this one
  mask covers the all-valid prefix columns, the in-chunk triangle, and the
  not-yet-written tail rows of the last block alike. KV tiles entirely
  above the diagonal of a query tile are skipped (prefix tiles are the
  workload and are never skippable).

Query rows past the true chunk length are padding: every score they keep
is finite (column 0 is always causally valid), so they produce garbage-
but-finite output rows the caller slices off.

**Fused int8 dequant-on-gather.** With ``k_scale``/``v_scale`` (per-row
f32 scales, block-indexed like the pools) the K/V pools are int8: the
gather DMA moves half the bytes and dequantization folds into the score
row — ``S *= k_scale`` per column after the QK dot, ``p *= v_scale``
before the AV dot (both exact; a scale is constant along its K/V row).
The rescales are O(BQ·BS) where widening the tiles would be O(BS·D), and
the accumulator stays fp32 either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.numerics import NEG_INF


def _paged_prefill_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, *rest,
                          intmax: bool, block_q: int, block_size: int,
                          quantized: bool):
    if quantized:
        ksc_ref, vsc_ref, o_ref, acc_scr, m_scr, d_scr = rest
    else:
        o_ref, acc_scr, m_scr, d_scr = rest
    i, j = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        d_scr[...] = jnp.zeros_like(d_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = pos_ref[0, 0] + i * block_q     # absolute pos of q row 0
    k_start = j * block_size                  # logical pos of kv row 0

    @pl.when(k_start <= q_start + block_q - 1)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (BS, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (BS, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (BQ, BS)
        if quantized:
            # k_scale is constant per K row: scaling the score columns is
            # the exact dequant, for O(BQ·BS) instead of O(BS·D) work
            s = s * ksc_ref[0, 0]                     # (1, BS) broadcast
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj <= qi, s, NEG_INF)
        m_prev = m_scr[...]
        # IntMax via ceil-after-reduce (ceil is monotone, so this equals
        # max(ceil(s)) with a (BQ, 1) ceil instead of a (BQ, BS) pass)
        sm = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, jnp.ceil(sm) if intmax else sm)
        alpha = jnp.exp2(m_prev - m_new)              # exact power-of-two
        p = jnp.exp2(s - m_new)
        pv = p * vsc_ref[0, 0] if quantized else p    # fold v_scale into p
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            pv, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        d_scr[...] = d_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _fin():
        d = d_scr[...]
        recip = jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
        o_ref[0] = (acc_scr[...] * recip).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("intmax", "block_q", "interpret"))
def flash_prefill_paged(
    q: jax.Array,             # (B, Hq, Sq, D) pre-scaled chunk queries
    k_pool: jax.Array,        # (N, Hkv, BS, D) physical block pool
    v_pool: jax.Array,        # (N, Hkv, BS, D)
    block_tables: jax.Array,  # (B, W) int32, logical order; must cover every
    #                           position <= pos0 + Sq - 1
    q_pos0: jax.Array,        # (B,) int32 absolute position of q[:, :, 0]
    *,
    k_scale: jax.Array = None,   # (N, Hkv, BS) f32: int8 pools' row scales
    v_scale: jax.Array = None,
    intmax: bool = True,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    N, Hkv, BS, _ = k_pool.shape
    W = block_tables.shape[1]
    group = Hq // Hkv
    quantized = k_scale is not None

    block_q = min(block_q, Sq)
    pq = (-Sq) % block_q
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    Sqp = Sq + pq
    nq = Sqp // block_q

    qf = qp.reshape(B * Hq, Sqp, D)
    pos = q_pos0.astype(jnp.int32).reshape(B, 1)
    bt = block_tables.astype(jnp.int32)

    def kv_map(bh, i, j, bt_ref):
        return (bt_ref[bh // Hq, j], (bh % Hq) // group, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1), lambda bh, i, j, bt_ref: (bh // Hq, 0)),
        pl.BlockSpec((1, block_q, D),
                     lambda bh, i, j, bt_ref: (bh, i, 0)),
        pl.BlockSpec((1, 1, BS, D), kv_map),
        pl.BlockSpec((1, 1, BS, D), kv_map),
    ]
    inputs = [pos, qf, k_pool, v_pool]
    if quantized:
        # scales ride the same scalar-prefetch gather as the values; the
        # trailing unit axis keeps in-kernel reads 2-D (TPU-friendly)
        in_specs += [pl.BlockSpec((1, 1, 1, BS), kv_map),
                     pl.BlockSpec((1, 1, 1, BS), kv_map)]
        inputs += [k_scale.astype(jnp.float32).reshape(N, Hkv, 1, BS),
                   v_scale.astype(jnp.float32).reshape(N, Hkv, 1, BS)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hq, nq, W),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, i, j, bt_ref: (bh, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        functools.partial(_paged_prefill_kernel, intmax=intmax,
                          block_q=block_q, block_size=BS,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sqp, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bt, *inputs)

    return out.reshape(B, Hq, Sqp, D)[:, :, :Sq, :]
