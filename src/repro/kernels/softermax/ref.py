"""Pure-jnp oracle for the softermax row kernel."""
from __future__ import annotations

import jax

from repro.core.softermax import softermax, softmax_base2


def softermax_rows_ref(x: jax.Array, intmax: bool = True) -> jax.Array:
    """Closed-form reference: base-2 softmax with (optionally integer) max."""
    if intmax:
        return softermax(x, axis=-1)
    return softmax_base2(x, axis=-1)
