from repro.kernels.softermax.ops import softermax_op
from repro.kernels.softermax.ref import softermax_rows_ref

__all__ = ["softermax_op", "softermax_rows_ref"]
