"""Pallas TPU kernel: row-wise Softermax (two-phase, §IV).

The kernel pair mirrors the paper's microarchitecture exactly:

* ``_unnormed_kernel``   — the *Unnormed Softmax Unit*: streams V-blocks of
  each row through VMEM, keeps a running IntMax ``m`` and running denominator
  ``d`` in VMEM scratch, renormalizing ``d`` by the exact power-of-two
  ``2^(m_prev - m_new)`` (integer exponent ⇒ exponent-add, the TPU analogue of
  the paper's shifter), and writes *unnormed* numerators ``2^(x - m_running)``
  plus the per-block running max.
* ``_normalize_kernel``  — the *Normalization Unit*: rescales each numerator
  block by ``2^(m_block - m_final)`` (again an exact power of two) and
  multiplies by the reciprocal of the final denominator.

Grid layout: ``(num_row_blocks, num_v_blocks)`` with the V dimension iterated
sequentially ("arbitrary" semantics) so scratch carries across V-blocks —
the same dataflow as the hardware streaming slices of VectorSize.

BlockSpec tiling: ``(block_rows, block_v)`` tiles live in VMEM; block_v is a
multiple of 128 (lane width) and block_rows a multiple of 8 (sublanes) so the
VPU operates on full registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.numerics import NEG_INF

_SAFE_NEG = NEG_INF  # finite mask value; (-inf)-(-inf) NaNs are avoided


def _unnormed_kernel(x_ref, y_ref, mrun_ref, mfin_ref, dfin_ref, m_scr, d_scr,
                     *, intmax: bool):
    j = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _SAFE_NEG)
        d_scr[...] = jnp.zeros_like(d_scr)

    x = x_ref[...].astype(jnp.float32)
    m_prev = m_scr[...]
    xl = jnp.ceil(x) if intmax else x  # IntMax unit applies ceil pre-max
    local_m = jnp.max(xl, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, local_m)
    y = jnp.exp2(x - m_new)  # Power-of-Two unit (base-2: no log2e multiply)
    y_ref[...] = y.astype(y_ref.dtype)
    # Reduction unit: shift-renormalize the running sum, add local sum.
    d_scr[...] = d_scr[...] * jnp.exp2(m_prev - m_new) + jnp.sum(
        y, axis=1, keepdims=True)
    m_scr[...] = m_new
    mrun_ref[...] = m_new

    @pl.when(j == nv - 1)
    def _fin():
        mfin_ref[...] = m_scr[...]
        dfin_ref[...] = d_scr[...]


def _normalize_kernel(y_ref, mrun_ref, mfin_ref, dfin_ref, o_ref):
    y = y_ref[...].astype(jnp.float32)
    # 2^(m_block - m_final): integer exponent under IntMax ⇒ exact scaling.
    shift = jnp.exp2(mrun_ref[...] - mfin_ref[...])
    d = dfin_ref[...]
    recip = jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
    o_ref[...] = (y * shift * recip).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("intmax", "block_rows", "block_v", "interpret"),
)
def softermax_rows(
    x: jax.Array,
    *,
    intmax: bool = True,
    block_rows: int = 8,
    block_v: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Softermax over the last axis of a 2-D array ``(rows, V)``.

    ``intmax=True`` is the paper's algorithm; ``intmax=False`` gives the plain
    base-2 online softmax (ablation).
    """
    rows, V = x.shape
    pr = (-rows) % block_rows
    pv = (-V) % block_v
    xp = jnp.pad(x, ((0, pr), (0, pv)), constant_values=_SAFE_NEG)
    R, Vp = xp.shape
    nr, nv = R // block_rows, Vp // block_v

    y, mrun, mfin, dfin = pl.pallas_call(
        functools.partial(_unnormed_kernel, intmax=intmax),
        grid=(nr, nv),
        in_specs=[pl.BlockSpec((block_rows, block_v), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_rows, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, Vp), jnp.float32),
            jax.ShapeDtypeStruct((R, nv), jnp.float32),
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_rows, 1), jnp.float32),
            pltpu.VMEM((block_rows, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp)

    out = pl.pallas_call(
        _normalize_kernel,
        grid=(nr, nv),
        in_specs=[
            pl.BlockSpec((block_rows, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_v), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, Vp), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(y, mrun, mfin, dfin)

    return out[:rows, :V]
