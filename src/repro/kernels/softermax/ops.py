"""Jitted public wrapper for the softermax row kernel: arbitrary leading dims."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.softermax.softermax import softermax_rows


def softermax_op(
    x: jax.Array,
    *,
    intmax: bool = True,
    block_rows: int = 8,
    block_v: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Softermax over the last axis of an arbitrarily-shaped array."""
    shape = x.shape
    x2 = x.reshape((-1, shape[-1]))
    out = softermax_rows(
        x2,
        intmax=intmax,
        block_rows=block_rows,
        block_v=block_v,
        interpret=interpret,
    )
    return out.reshape(shape)
