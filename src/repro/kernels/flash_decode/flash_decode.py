"""Pallas TPU kernel: single-token decode attention with Softermax.

The decode step is the pure form of the paper's workload: one query row, a
streaming reduction over a (possibly very long) KV cache. The kernel is the
Unnormed-Softmax-Unit dataflow verbatim — running IntMax + running
denominator with power-of-two rescales — fused with the A·V accumulation, so
the cache is read from HBM exactly once per token.

Grid: ``(B*Hq, num_kv_blocks)``; kv sequential, scratch carries (m, d, acc).
Per-batch valid lengths mask the cache tail.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.numerics import NEG_INF


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_scr, m_scr, d_scr,
                   *, intmax: bool, block_k: int):
    j = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        d_scr[...] = jnp.zeros_like(d_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0, 0]
    k_start = j * block_k

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # (1, D)
        k = k_ref[0].astype(jnp.float32)              # (BK, D)
        v = v_ref[0].astype(jnp.float32)              # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (1, BK)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        sl = jnp.ceil(s) if intmax else s
        m_new = jnp.maximum(m_prev, jnp.max(sl, axis=1, keepdims=True))
        alpha = jnp.exp2(m_prev - m_new)
        p = jnp.exp2(s - m_new)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        d_scr[...] = d_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _fin():
        d = d_scr[...]
        recip = jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
        o_ref[0] = (acc_scr[...] * recip).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("intmax", "block_k", "interpret"),
)
def flash_decode(
    q: jax.Array,        # (B, Hq, D) — pre-scaled single-token queries
    k: jax.Array,        # (B, Hkv, S, D) cache
    v: jax.Array,        # (B, Hkv, S, D)
    lengths: jax.Array,  # (B,) int32 valid cache lengths
    *,
    intmax: bool = True,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    group = Hq // Hkv
    block_k = min(block_k, S)
    pk = (-S) % block_k
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sp = S + pk
    nk = Sp // block_k

    qf = q.reshape(B * Hq, 1, D)
    kf = kp.reshape(B * Hkv, Sp, D)
    vf = vp.reshape(B * Hkv, Sp, D)
    lens = lengths.astype(jnp.int32).reshape(B, 1)

    def kv_map(bh, j):
        return ((bh // Hq) * Hkv + (bh % Hq) // group, j, 0)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, intmax=intmax, block_k=block_k),
        grid=(B * Hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, j: (bh // Hq, 0)),
            pl.BlockSpec((1, 1, D), lambda bh, j: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda bh, j: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, qf, kf, vf)

    return out.reshape(B, Hq, D)
