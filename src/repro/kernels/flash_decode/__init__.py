from repro.kernels.flash_decode.flash_decode import flash_decode
from repro.kernels.flash_decode.ops import flash_decode_op
from repro.kernels.flash_decode.ref import decode_ref

__all__ = ["flash_decode", "flash_decode_op", "decode_ref"]
