"""Jitted public wrapper for decode attention."""
from __future__ import annotations

import jax

from repro.kernels.flash_decode.flash_decode import flash_decode
from repro.kernels.flash_decode.ref import decode_ref


def flash_decode_op(q, k, v, lengths, *, intmax: bool = True,
                    block_k: int = 256, interpret: bool = False) -> jax.Array:
    return flash_decode(q, k, v, lengths, intmax=intmax, block_k=block_k,
                        interpret=interpret)


__all__ = ["flash_decode_op", "decode_ref"]
