"""Pure-jnp oracle for the decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.numerics import NEG_INF
from repro.core.softermax import softermax, softmax_base2


def decode_ref(
    q: jax.Array,        # (B, Hq, D) pre-scaled
    k: jax.Array,        # (B, Hkv, S, D)
    v: jax.Array,
    lengths: jax.Array,  # (B,)
    *,
    intmax: bool = True,
) -> jax.Array:
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    mask = jnp.arange(S)[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = softermax(s, axis=-1) if intmax else softmax_base2(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)
