from repro.kernels.flash_decode_paged.flash_decode_paged import (
    flash_decode_paged, flash_decode_paged_single)
from repro.kernels.flash_decode_paged.ops import flash_decode_paged_op
from repro.kernels.flash_decode_paged.ref import (gather_kv, gather_scales,
                                                  gather_kv_dequant,
                                                  decode_gather_oracle,
                                                  paged_decode_ref,
                                                  paged_decode_split_ref,
                                                  split_layout)

__all__ = ["flash_decode_paged", "flash_decode_paged_single",
           "flash_decode_paged_op", "paged_decode_ref",
           "paged_decode_split_ref", "split_layout", "gather_kv",
           "gather_scales", "gather_kv_dequant", "decode_gather_oracle"]
