from repro.kernels.flash_decode_paged.flash_decode_paged import (
    flash_decode_paged)
from repro.kernels.flash_decode_paged.ops import flash_decode_paged_op
from repro.kernels.flash_decode_paged.ref import (gather_kv, gather_scales,
                                                  gather_kv_dequant,
                                                  paged_decode_ref)

__all__ = ["flash_decode_paged", "flash_decode_paged_op", "paged_decode_ref",
           "gather_kv", "gather_scales", "gather_kv_dequant"]
