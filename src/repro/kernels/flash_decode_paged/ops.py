"""Backend dispatch for paged decode attention.

Single dispatcher for every caller (the serving engine's fused decode step
routes here too):

* TPU backend          — the compiled Pallas kernel: GQA-grouped lanes,
  ``kv_tile_blocks``-block KV tiles, ``split_k`` parallel partitions merged
  by the associative Softermax combine.
* ``interpret=True``   — the same kernel under the Pallas interpreter (CPU
  CI exercises the exact grid/tile/split dataflow this way).
* anywhere else        — pure JAX: the gather oracle ``paged_decode_ref``.
  The tile/split parameters are *layout* knobs, not math knobs — every
  setting computes the identical attention — so the CPU fallback always
  runs the single-pass oracle (the fastest XLA evaluation) regardless of
  the requested tiling; ``paged_decode_split_ref`` exists for parity
  testing the partition structure itself.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_decode_paged.flash_decode_paged import (
    flash_decode_paged, flash_decode_paged_single)
from repro.kernels.flash_decode_paged.ref import (decode_gather_oracle,
                                                  gather_kv, gather_scales,
                                                  gather_kv_dequant,
                                                  paged_decode_ref,
                                                  paged_decode_split_ref)


def flash_decode_paged_op(q, k_pool, v_pool, block_tables, lengths, *,
                          k_scale=None, v_scale=None,
                          intmax: bool = True,
                          kv_tile_blocks: int = 1,
                          split_k: int = 1,
                          interpret: bool = False) -> jax.Array:
    if interpret:
        return flash_decode_paged(q, k_pool, v_pool, block_tables, lengths,
                                  k_scale=k_scale, v_scale=v_scale,
                                  intmax=intmax,
                                  kv_tile_blocks=kv_tile_blocks,
                                  split_k=split_k, interpret=True)
    if jax.default_backend() == "tpu":
        return flash_decode_paged(q, k_pool, v_pool, block_tables, lengths,
                                  k_scale=k_scale, v_scale=v_scale,
                                  intmax=intmax,
                                  kv_tile_blocks=kv_tile_blocks,
                                  split_k=split_k)
    return paged_decode_ref(q, k_pool, v_pool, block_tables, lengths,
                            k_scale=k_scale, v_scale=v_scale, intmax=intmax)


__all__ = ["flash_decode_paged_op", "paged_decode_ref",
           "paged_decode_split_ref", "flash_decode_paged_single",
           "gather_kv", "gather_scales", "gather_kv_dequant",
           "decode_gather_oracle"]
