"""Jitted public wrapper for paged decode attention."""
from __future__ import annotations

import jax

from repro.kernels.flash_decode_paged.flash_decode_paged import (
    flash_decode_paged)
from repro.kernels.flash_decode_paged.ref import gather_kv, paged_decode_ref


def flash_decode_paged_op(q, k_pool, v_pool, block_tables, lengths, *,
                          intmax: bool = True,
                          interpret: bool = False) -> jax.Array:
    return flash_decode_paged(q, k_pool, v_pool, block_tables, lengths,
                              intmax=intmax, interpret=interpret)


__all__ = ["flash_decode_paged_op", "paged_decode_ref", "gather_kv"]
