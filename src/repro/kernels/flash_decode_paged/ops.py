"""Jitted public wrapper for paged decode attention."""
from __future__ import annotations

import jax

from repro.kernels.flash_decode_paged.flash_decode_paged import (
    flash_decode_paged)
from repro.kernels.flash_decode_paged.ref import (gather_kv, gather_scales,
                                                  gather_kv_dequant,
                                                  paged_decode_ref)


def flash_decode_paged_op(q, k_pool, v_pool, block_tables, lengths, *,
                          k_scale=None, v_scale=None,
                          intmax: bool = True,
                          interpret: bool = False) -> jax.Array:
    return flash_decode_paged(q, k_pool, v_pool, block_tables, lengths,
                              k_scale=k_scale, v_scale=v_scale,
                              intmax=intmax, interpret=interpret)


__all__ = ["flash_decode_paged_op", "paged_decode_ref", "gather_kv",
           "gather_scales", "gather_kv_dequant"]
