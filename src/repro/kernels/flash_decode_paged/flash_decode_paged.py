"""Pallas TPU kernel: paged single-token decode attention with Softermax.

Same Unnormed-Softmax-Unit dataflow as ``kernels/flash_decode`` — running
IntMax + running denominator with power-of-two rescales, fused with the A·V
accumulation — but the KV cache is a *block pool*: a flat array of fixed-size
physical blocks, indirected through a per-sequence block table. Because the
Softermax recurrence is order-free (every rescale is an exact exponent add),
blocks can be streamed in table order with no pre-pass over the scores, which
is exactly what makes the paged layout free for this kernel.

The block table is a scalar-prefetch operand (``PrefetchScalarGridSpec``):
its entries are available *before* the kernel body runs, so the KV BlockSpec
index maps perform the gather — each grid step DMAs physical blocks from the
pool directly into VMEM.

Three grid-level restructurings over the naive per-head walk (all three are
pure reorganizations of the same recurrence — outputs are unchanged):

* **GQA grouping.** Grid axis 0 is ``B*Hkv``, not ``B*Hq``: one lane owns a
  whole GQA group, its query tile is ``(group, D)``, and the block-table
  gather that used to run once per *query* head now runs once per *KV*
  head — a ``group``× cut in gather DMA — while the QK/AV dots grow from
  ``(1, D)`` vector products into real ``(group, ·)`` MXU matmuls.
* **Multi-block KV tiles.** Each kv grid step gathers ``kv_tile_blocks``
  (T) pool blocks — T block-granular DMAs the pipeline overlaps within one
  step — and processes them as a single ``(T*BS, D)`` VMEM tile, so with
  ``T*BS >= 128`` the dots are MXU-shaped and the per-step mask/rescale
  overhead amortizes over T blocks. Table entries past the real table width
  are clamped to the pool's reserved garbage block 0 (the wrapper pads the
  table), and ``@pl.when`` skips compute on tiles that start past the
  sequence length, so short requests stop paying for the batch-max table
  width.
* **Split-K.** The KV walk is partitioned across a *parallel* grid axis of
  ``split_k`` lanes; each lane emits its partial ``(m, d, acc)`` state and
  a small jnp second stage merges them with the associative Softermax
  combine (``core.softermax.softermax_merge`` — exact power-of-two
  rescales under the joint IntMax) before the final normalize. One long
  request's decode step then finishes in ~1/split_k of the serial table
  walk instead of serializing on a single lane.

**Fused int8 dequant-on-gather.** With ``k_scale``/``v_scale`` (per-row f32
scales, block-indexed like the pool) the K/V pools are int8: the HBM→VMEM
DMA moves half the bytes, and dequantization is fused *after* the matmuls
instead of widening the tiles — ``S = q·Kᵀ`` against the raw int8 codes
then ``S *= k_scale`` per column (exact: the scale is a per-row constant of
K), and ``p *= v_scale`` before ``p·V`` (same identity on the V side). Both
rescales touch the (group, T*BS) score tile, not the (T*BS, D) value tile,
so the dequant cost stays O(tile-row) while the accumulate stays fp32 — the
paper's int-storage / wide-accumulate split applied to the KV side. TPU
tiling note: int8 VMEM tiles are (32, 128)-granular (vs (16, 128) for
bf16), so int8 pools waste no sublane padding when ``block_size >= 32``.

Table entries past a sequence's length may be garbage (the pool's reserved
block 0): the length mask zeroes their contribution and the gather of block
0 is a wasted-but-harmless DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.numerics import NEG_INF
from repro.core.softermax import softermax_finalize, softermax_merge
from repro.kernels.flash_decode_paged.ref import split_layout


def concat_tiles(refs, axis: int = 0):
    """Assemble one VMEM tile from the T per-slot gather operands (each
    ref holds one pool block, leading (1, 1) block axes stripped). Shared
    by the decode and prefill kernel bodies — values concat along rows
    (axis 0), the (1, BS) scale rows along columns (axis 1)."""
    if len(refs) == 1:
        return refs[0][0, 0]
    return jnp.concatenate([r[0, 0] for r in refs], axis=axis)


def _paged_decode_kernel(bt_ref, len_ref, q_ref, *rest, intmax: bool,
                         block_size: int, tile_blocks: int, quantized: bool):
    T = tile_blocks
    k_refs, v_refs = rest[:T], rest[T:2 * T]
    n = 2 * T
    if quantized:
        ksc_refs, vsc_refs = rest[n:n + T], rest[n + T:n + 2 * T]
        n += 2 * T
    acc_ref, m_ref, d_ref, acc_scr, m_scr, d_scr = rest[n:]
    j = pl.program_id(2)
    spl = pl.num_programs(2)                  # kv tiles per split lane

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        d_scr[...] = jnp.zeros_like(d_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0, 0]
    jj = pl.program_id(1) * spl + j           # global kv tile index
    k_start = jj * (T * block_size)

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0].astype(jnp.float32)      # (G, D)
        # T block-granular gathers assembled into one (T*BS, D) VMEM tile
        k = concat_tiles(k_refs)
        v = concat_tiles(v_refs)
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)   # (G, T*BS)
        if quantized:
            # dequant fused post-dot: k_scale is constant per K row, so
            # scaling the (G, T*BS) score columns equals scaling the
            # (T*BS, D) tile — for a fraction of the flops
            s = s * concat_tiles(ksc_refs, axis=1)   # (1, T*BS) broadcast
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        # IntMax via ceil-after-reduce (ceil is monotone, so this equals
        # max(ceil(s)) with a (G, 1) ceil instead of a (G, T*BS) pass)
        sm = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, jnp.ceil(sm) if intmax else sm)
        alpha = jnp.exp2(m_prev - m_new)      # exact power-of-two
        p = jnp.exp2(s - m_new)
        if quantized:
            pv = p * concat_tiles(vsc_refs, axis=1)  # fold v_scale into p
        else:
            pv = p
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            pv, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        d_scr[...] = d_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new

    @pl.when(j == spl - 1)
    def _fin():
        # emit the lane's partial state; lanes whose every tile sat past
        # kv_len emit the merge identity (NEG_INF, 0, 0) from _init
        acc_ref[0, 0] = acc_scr[...]
        m_ref[0, 0] = m_scr[...]
        d_ref[0, 0] = d_scr[...]


@functools.partial(
    jax.jit,
    static_argnames=("intmax", "kv_tile_blocks", "split_k", "interpret"))
def flash_decode_paged(
    q: jax.Array,             # (B, Hq, D) — pre-scaled single-token queries
    k_pool: jax.Array,        # (N, Hkv, BS, D) physical block pool
    v_pool: jax.Array,        # (N, Hkv, BS, D)
    block_tables: jax.Array,  # (B, W) int32 physical block ids
    lengths: jax.Array,       # (B,) int32 valid cache lengths
    *,
    k_scale: jax.Array = None,   # (N, Hkv, BS) f32: int8 pools' row scales
    v_scale: jax.Array = None,
    intmax: bool = True,
    kv_tile_blocks: int = 1,  # pool blocks gathered per kv grid step (T)
    split_k: int = 1,         # parallel partitions of the KV walk
    interpret: bool = False,
) -> jax.Array:
    B, Hq, D = q.shape
    N, Hkv, BS, _ = k_pool.shape
    W = block_tables.shape[1]
    G = Hq // Hkv
    quantized = k_scale is not None

    # clamp the tiling to the table (shared geometry — ref.split_layout):
    # T-block tiles, S split lanes of spl tiles each; the table pads to
    # the S*spl*T cover with garbage block 0 (padded entries sit past
    # every length — masked, and their repeated block-0 gather is a
    # harmless DMA)
    T, S, spl, Wp = split_layout(W, kv_tile_blocks, split_k)
    bt = jnp.pad(block_tables.astype(jnp.int32), ((0, 0), (0, Wp - W)))

    qf = q.reshape(B * Hkv, G, D)
    lens = lengths.astype(jnp.int32).reshape(B, 1)

    def kv_map(t):
        # one gather map per tile slot; values and scales share it
        def _map(bh, s, j, bt_ref):
            jj = s * spl + j
            return (bt_ref[bh // Hkv, jj * T + t], bh % Hkv, 0, 0)
        return _map

    in_specs = [
        pl.BlockSpec((1, 1), lambda bh, s, j, bt_ref: (bh // Hkv, 0)),
        pl.BlockSpec((1, G, D), lambda bh, s, j, bt_ref: (bh, 0, 0)),
    ]
    in_specs += [pl.BlockSpec((1, 1, BS, D), kv_map(t)) for t in range(T)]
    in_specs += [pl.BlockSpec((1, 1, BS, D), kv_map(t)) for t in range(T)]
    inputs = [lens, qf] + [k_pool] * T + [v_pool] * T
    if quantized:
        # scales ride the same scalar-prefetch block-table gather as the
        # values; the trailing unit axis keeps in-kernel reads 2-D
        ksr = k_scale.astype(jnp.float32).reshape(N, Hkv, 1, BS)
        vsr = v_scale.astype(jnp.float32).reshape(N, Hkv, 1, BS)
        in_specs += [pl.BlockSpec((1, 1, 1, BS), kv_map(t))
                     for t in range(T)]
        in_specs += [pl.BlockSpec((1, 1, 1, BS), kv_map(t))
                     for t in range(T)]
        inputs += [ksr] * T + [vsr] * T

    part = pl.BlockSpec((1, 1, G, 1), lambda bh, s, j, bt_ref: (bh, s, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hkv, S, spl),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda bh, s, j, bt_ref: (bh, s, 0, 0)),
            part, part,
        ],
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )

    acc, m, d = pl.pallas_call(
        functools.partial(_paged_decode_kernel, intmax=intmax,
                          block_size=BS, tile_blocks=T, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, S, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B * Hkv, S, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * Hkv, S, G, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bt, *inputs)

    # second stage: associative Softermax merge of the split partials under
    # the joint (Int)Max, then the one deferred normalize. With split_k=1
    # this is exactly the old in-kernel epilogue (scale = 2^0 = 1).
    _, d2, acc2 = softermax_merge(m, d, acc, axis=1)
    o = softermax_finalize(acc2, d2)          # (B*Hkv, G, D)
    return o.reshape(B, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Legacy per-head single-block kernel — benchmark baseline only.
# ---------------------------------------------------------------------------


def _paged_decode_kernel_single(bt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                                intmax: bool, block_size: int,
                                quantized: bool):
    if quantized:
        ksc_ref, vsc_ref, o_ref, acc_scr, m_scr, d_scr = rest
    else:
        o_ref, acc_scr, m_scr, d_scr = rest
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        d_scr[...] = jnp.zeros_like(d_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0, 0]
    k_start = j * block_size

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (BS, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (BS, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (1, BS)
        if quantized:
            s = s * ksc_ref[0, 0]                     # (1, BS)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        sl = jnp.ceil(s) if intmax else s
        m_new = jnp.maximum(m_prev, jnp.max(sl, axis=1, keepdims=True))
        alpha = jnp.exp2(m_prev - m_new)              # exact power-of-two
        p = jnp.exp2(s - m_new)
        pv = p * vsc_ref[0, 0] if quantized else p
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            pv, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        d_scr[...] = d_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new

    @pl.when(j == nb - 1)
    def _fin():
        d = d_scr[...]
        recip = jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
        o_ref[0] = (acc_scr[...] * recip).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("intmax", "interpret"))
def flash_decode_paged_single(
    q: jax.Array,             # (B, Hq, D) — pre-scaled single-token queries
    k_pool: jax.Array,        # (N, Hkv, BS, D) physical block pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32 physical block ids
    lengths: jax.Array,       # (B,) int32 valid cache lengths
    *,
    k_scale: jax.Array = None,
    v_scale: jax.Array = None,
    intmax: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """The pre-tiling kernel: grid ``(B*Hq, nb)``, one pool block per kv
    step, every query head of a GQA group re-gathering the group's shared
    KV. Kept ONLY as the baseline that ``benchmarks/decode_paged_bench.py``
    measures the grouped/tiled/split kernel against (and as a parity oracle
    for the restructure); serving dispatches the grouped kernel above."""
    B, Hq, D = q.shape
    N, Hkv, BS, _ = k_pool.shape
    nb = block_tables.shape[1]
    group = Hq // Hkv
    quantized = k_scale is not None

    qf = q.reshape(B * Hq, 1, D)
    lens = lengths.astype(jnp.int32).reshape(B, 1)
    bt = block_tables.astype(jnp.int32)

    def kv_map(bh, j, bt_ref):
        return (bt_ref[bh // Hq, j], (bh % Hq) // group, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1), lambda bh, j, bt_ref: (bh // Hq, 0)),
        pl.BlockSpec((1, 1, D), lambda bh, j, bt_ref: (bh, 0, 0)),
        pl.BlockSpec((1, 1, BS, D), kv_map),
        pl.BlockSpec((1, 1, BS, D), kv_map),
    ]
    inputs = [lens, qf, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1, 1, BS), kv_map),
                     pl.BlockSpec((1, 1, 1, BS), kv_map)]
        inputs += [k_scale.astype(jnp.float32).reshape(N, Hkv, 1, BS),
                   v_scale.astype(jnp.float32).reshape(N, Hkv, 1, BS)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hq, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, D), lambda bh, j, bt_ref: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel_single, intmax=intmax,
                          block_size=BS, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, 1, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bt, *inputs)

    return out.reshape(B, Hq, D)
