"""Pallas TPU kernel: paged single-token decode attention with Softermax.

Same Unnormed-Softmax-Unit dataflow as ``kernels/flash_decode`` — running
IntMax + running denominator with power-of-two rescales, fused with the A·V
accumulation — but the KV cache is a *block pool*: a flat array of fixed-size
physical blocks, indirected through a per-sequence block table. Because the
Softermax recurrence is order-free (every rescale is an exact exponent add),
blocks can be streamed in table order with no pre-pass over the scores, which
is exactly what makes the paged layout free for this kernel.

The block table is a scalar-prefetch operand (``PrefetchScalarGridSpec``):
its entries are available *before* the kernel body runs, so the KV BlockSpec
index map performs the gather — each grid step DMAs one physical block from
the pool directly into VMEM. Grid: ``(B*Hq, blocks_per_seq)``; the kv axis is
sequential and scratch carries (m, d, acc) across it.

**Fused int8 dequant-on-gather.** With ``k_scale``/``v_scale`` (per-row f32
scales, block-indexed like the pool) the K/V pools are int8: the HBM→VMEM
DMA moves half the bytes, and dequantization is fused *after* the matmuls
instead of widening the tiles — ``S = q·Kᵀ`` against the raw int8 codes
then ``S *= k_scale`` per column (exact: the scale is a per-row constant of
K), and ``p *= v_scale`` before ``p·V`` (same identity on the V side). Both
rescales touch the (1, BS) score row, not the (BS, D) tile, so the dequant
cost is O(BS) per block while the accumulate stays fp32 — the paper's
int-storage / wide-accumulate split applied to the KV side. TPU tiling
note: int8 VMEM tiles are (32, 128)-granular (vs (16, 128) for bf16), so
int8 pools waste no sublane padding when ``block_size >= 32``.

Table entries past a sequence's length may be garbage (the pool's reserved
block 0): the length mask zeroes their contribution and the gather of block 0
is a wasted-but-harmless DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.numerics import NEG_INF


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                         intmax: bool, block_size: int, quantized: bool):
    if quantized:
        ksc_ref, vsc_ref, o_ref, acc_scr, m_scr, d_scr = rest
    else:
        o_ref, acc_scr, m_scr, d_scr = rest
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        d_scr[...] = jnp.zeros_like(d_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0, 0]
    k_start = j * block_size

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (BS, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (BS, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (1, BS)
        if quantized:
            # dequant fused post-dot: k_scale is constant per K row, so
            # scaling the (1, BS) score column-wise equals scaling the
            # (BS, D) tile — for a fraction of the flops
            s = s * ksc_ref[0, 0]                     # (1, BS)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        sl = jnp.ceil(s) if intmax else s
        m_new = jnp.maximum(m_prev, jnp.max(sl, axis=1, keepdims=True))
        alpha = jnp.exp2(m_prev - m_new)              # exact power-of-two
        p = jnp.exp2(s - m_new)
        if quantized:
            pv = p * vsc_ref[0, 0]                    # fold v_scale into p
        else:
            pv = p
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            pv, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        d_scr[...] = d_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new

    @pl.when(j == nb - 1)
    def _fin():
        d = d_scr[...]
        recip = jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
        o_ref[0] = (acc_scr[...] * recip).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("intmax", "interpret"))
def flash_decode_paged(
    q: jax.Array,             # (B, Hq, D) — pre-scaled single-token queries
    k_pool: jax.Array,        # (N, Hkv, BS, D) physical block pool
    v_pool: jax.Array,        # (N, Hkv, BS, D)
    block_tables: jax.Array,  # (B, nb) int32 physical block ids
    lengths: jax.Array,       # (B,) int32 valid cache lengths
    *,
    k_scale: jax.Array = None,   # (N, Hkv, BS) f32: int8 pools' row scales
    v_scale: jax.Array = None,
    intmax: bool = True,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, D = q.shape
    N, Hkv, BS, _ = k_pool.shape
    nb = block_tables.shape[1]
    group = Hq // Hkv
    quantized = k_scale is not None

    qf = q.reshape(B * Hq, 1, D)
    lens = lengths.astype(jnp.int32).reshape(B, 1)
    bt = block_tables.astype(jnp.int32)

    def kv_map(bh, j, bt_ref):
        return (bt_ref[bh // Hq, j], (bh % Hq) // group, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1), lambda bh, j, bt_ref: (bh // Hq, 0)),
        pl.BlockSpec((1, 1, D), lambda bh, j, bt_ref: (bh, 0, 0)),
        pl.BlockSpec((1, 1, BS, D), kv_map),
        pl.BlockSpec((1, 1, BS, D), kv_map),
    ]
    inputs = [lens, qf, k_pool, v_pool]
    if quantized:
        # scales ride the same scalar-prefetch gather as the values; the
        # trailing unit axis keeps in-kernel reads 2-D (TPU-friendly)
        in_specs += [pl.BlockSpec((1, 1, 1, BS), kv_map),
                     pl.BlockSpec((1, 1, 1, BS), kv_map)]
        inputs += [k_scale.astype(jnp.float32).reshape(N, Hkv, 1, BS),
                   v_scale.astype(jnp.float32).reshape(N, Hkv, 1, BS)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hq, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, D), lambda bh, j, bt_ref: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, intmax=intmax,
                          block_size=BS, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, 1, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bt, *inputs)

    return out.reshape(B, Hq, D)
