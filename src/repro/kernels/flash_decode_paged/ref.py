"""Pure-jnp oracle for the paged decode kernel.

``gather_kv`` materializes a request's logical cache from the pool through
its block table; ``paged_decode_ref`` is then exactly the contiguous decode
oracle on the gathered cache. This is also the CPU execution path of the
serving engine (``serve/paged_step.py``) — XLA turns the block-table gather
into one take per step, and the attention math is bit-for-bit the contiguous
``_masked_decode`` computation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.ref import decode_ref


def gather_kv(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(N, Hkv, BS, D) pool + (B, nb) table -> (B, Hkv, nb*BS, D) caches."""
    B, nb = block_tables.shape
    _, Hkv, BS, D = pool.shape
    g = pool[block_tables]                    # (B, nb, Hkv, BS, D)
    g = jnp.moveaxis(g, 2, 1)                 # (B, Hkv, nb, BS, D)
    return g.reshape(B, Hkv, nb * BS, D)


def gather_scales(scales: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(N, Hkv, BS) scale pool + (B, nb) table -> (B, Hkv, nb*BS)."""
    B, nb = block_tables.shape
    _, Hkv, BS = scales.shape
    g = scales[block_tables]                  # (B, nb, Hkv, BS)
    g = jnp.moveaxis(g, 2, 1)
    return g.reshape(B, Hkv, nb * BS)


def gather_kv_dequant(pool: jax.Array, scales, block_tables: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    """Gather + (optional) int8 dequant: the pure-JAX mirror of the
    kernels' fused dequant-on-gather. ``scales=None`` is the plain path."""
    g = gather_kv(pool, block_tables)
    if scales is None:
        return g
    s = gather_scales(scales, block_tables)
    return (g.astype(jnp.float32) * s[..., None].astype(jnp.float32)
            ).astype(dtype)


def paged_decode_ref(
    q: jax.Array,             # (B, Hq, D) pre-scaled
    k_pool: jax.Array,        # (N, Hkv, BS, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) int32
    *,
    k_scale: jax.Array = None,   # (N, Hkv, BS) f32 when the pools are int8
    v_scale: jax.Array = None,
    intmax: bool = True,
) -> jax.Array:
    k = gather_kv_dequant(k_pool, k_scale, block_tables)
    v = gather_kv_dequant(v_pool, v_scale, block_tables)
    return decode_ref(q, k, v, lengths, intmax=intmax)
