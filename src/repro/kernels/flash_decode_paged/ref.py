"""Pure-jnp oracles for the paged decode kernel.

``gather_kv`` materializes a request's logical cache from the pool through
its block table; ``paged_decode_ref`` is then exactly the contiguous decode
oracle on the gathered cache. This is also the CPU execution path of the
serving engine (``serve/paged_step.py``) — XLA turns the block-table gather
into one take per step, and the attention math is bit-for-bit the contiguous
``_masked_decode`` computation.

**Grouped-gather cost faithfulness.** Every oracle here gathers KV exactly
once per *KV* head — ``pool[block_tables]`` pulls all ``Hkv`` heads of a
block in one take — and queries are reshaped to ``(B, Hkv, group, …)`` so
the group dimension rides the einsum batch axes; KV is never expanded
(repeated/broadcast-materialized) across the query group. That is the same
operand-movement shape as the grouped Pallas kernel's one-gather-per-group
lanes, so the refs stay cost-faithful oracles, not just numeric ones.

``paged_decode_split_ref`` mirrors the kernel's split-K structure: the
(padded) KV walk is cut into ``split_k`` partitions, each reduced to its
partial ``(m, d, acc)`` state in closed form, and the partials are combined
with the associative Softermax merge (``core.softermax.softermax_merge``)
— the exact contract the kernel's parallel split lanes + jnp combine stage
implement, including the identity state ``(NEG_INF, 0, 0)`` for partitions
that sit entirely past a sequence's length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.numerics import NEG_INF
from repro.core.softermax import softermax_finalize, softermax_merge
from repro.kernels.flash_decode.ref import decode_ref


def split_layout(W: int, kv_tile_blocks: int, split_k: int):
    """THE clamped tile/split geometry for a table of ``W`` blocks —
    ``(T, S, spl, Wp)``: T blocks per kv tile, S split lanes of ``spl``
    tiles each, table padded to ``Wp = S*spl*T`` blocks. The kernel
    wrapper, the split oracle, and the decode bench's gather-traffic model
    must all partition identically, so the derivation lives here once."""
    T = max(1, min(kv_tile_blocks, W))
    tiles = -(-W // T)
    S = max(1, min(split_k, tiles))
    spl = -(-tiles // S)
    return T, S, spl, S * spl * T


def gather_kv(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(N, Hkv, BS, D) pool + (B, nb) table -> (B, Hkv, nb*BS, D) caches."""
    B, nb = block_tables.shape
    _, Hkv, BS, D = pool.shape
    g = pool[block_tables]                    # (B, nb, Hkv, BS, D)
    g = jnp.moveaxis(g, 2, 1)                 # (B, Hkv, nb, BS, D)
    return g.reshape(B, Hkv, nb * BS, D)


def gather_scales(scales: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(N, Hkv, BS) scale pool + (B, nb) table -> (B, Hkv, nb*BS)."""
    B, nb = block_tables.shape
    _, Hkv, BS = scales.shape
    g = scales[block_tables]                  # (B, nb, Hkv, BS)
    g = jnp.moveaxis(g, 2, 1)
    return g.reshape(B, Hkv, nb * BS)


def gather_kv_dequant(pool: jax.Array, scales, block_tables: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    """Gather + (optional) int8 dequant: the pure-JAX mirror of the
    kernels' fused dequant-on-gather. ``scales=None`` is the plain path."""
    g = gather_kv(pool, block_tables)
    if scales is None:
        return g
    s = gather_scales(scales, block_tables)
    return (g.astype(jnp.float32) * s[..., None].astype(jnp.float32)
            ).astype(dtype)


def decode_gather_oracle(
    k_pool: jax.Array,        # (N, Hkv, BS, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, W) int32
    lengths,                  # (B,) kv lengths the kernel attends
    *,
    kv_tile_blocks: int = 1,
    split_k: int = 1,
    k_scale: jax.Array = None,   # (N, Hkv, BS) f32 when the pools are int8
    v_scale: jax.Array = None,
):
    """MEASURE (not model) one decode launch's gather traffic: pad the
    table exactly as the kernel wrapper does (``split_layout``), run the
    ref layer's actual gathers on it, and count bytes off the gathered
    array shapes. The analytic model in ``serve/kernel_costs.py`` must
    reproduce these numbers exactly — that agreement is the cross-check
    against the grouped-gather contract pinned in this module's docstring.

    Returns ``{"gather_bytes", "useful_bytes", "waste_bytes",
    "grid_steps", "padded_width"}``; waste counts table entries at or past
    each row's real block cover ``ceil(len/BS)`` (pow2 bucketing, tile
    padding, dead tail blocks alike), including int8 scale siblings.
    """
    B, W = block_tables.shape
    _, Hkv, BS, _ = k_pool.shape
    T, S, spl, Wp = split_layout(W, kv_tile_blocks, split_k)
    bt = jnp.pad(block_tables.astype(jnp.int32), ((0, 0), (0, Wp - W)))

    gk = gather_kv(k_pool, bt)                    # the real takes — bytes
    gv = gather_kv(v_pool, bt)                    # come off their shapes
    gather = int(gk.nbytes) + int(gv.nbytes)
    per_block = gk.dtype.itemsize * BS * k_pool.shape[-1] * 2
    if k_scale is not None:
        gks = gather_scales(k_scale, bt)
        gvs = gather_scales(v_scale, bt)
        gather += int(gks.nbytes) + int(gvs.nbytes)
        per_block += gks.dtype.itemsize * BS * 2
    useful_blocks = sum(min(-(-int(ln) // BS), Wp) for ln in list(lengths))
    useful = useful_blocks * Hkv * per_block
    return {"gather_bytes": gather, "useful_bytes": useful,
            "waste_bytes": gather - useful, "grid_steps": B * Hkv * S * spl,
            "padded_width": Wp}


def paged_decode_ref(
    q: jax.Array,             # (B, Hq, D) pre-scaled
    k_pool: jax.Array,        # (N, Hkv, BS, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32
    lengths: jax.Array,       # (B,) int32
    *,
    k_scale: jax.Array = None,   # (N, Hkv, BS) f32 when the pools are int8
    v_scale: jax.Array = None,
    intmax: bool = True,
) -> jax.Array:
    k = gather_kv_dequant(k_pool, k_scale, block_tables)
    v = gather_kv_dequant(v_pool, v_scale, block_tables)
    return decode_ref(q, k, v, lengths, intmax=intmax)


def paged_decode_split_ref(
    q: jax.Array,             # (B, Hq, D) pre-scaled
    k_pool: jax.Array,        # (N, Hkv, BS, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, W) int32
    lengths: jax.Array,       # (B,) int32
    *,
    split_k: int = 1,
    kv_tile_blocks: int = 1,
    k_scale: jax.Array = None,   # (N, Hkv, BS) f32 when the pools are int8
    v_scale: jax.Array = None,
    intmax: bool = True,
) -> jax.Array:
    """Partition-structured oracle for the split-K kernel: pads the table
    the way the kernel wrapper does (to ``split_k * spl * kv_tile_blocks``
    blocks, pad entries = garbage block 0), reduces each partition to its
    partial ``(m, d, acc)`` in closed form, and merges with
    ``softermax_merge``. Numerically equal to ``paged_decode_ref`` up to fp
    reduction order (exactly equal where IntMax makes every rescale an
    integer exponent add and each partition's sums coincide)."""
    B, Hq, D = q.shape
    _, Hkv, BS, _ = k_pool.shape
    W = block_tables.shape[1]
    G = Hq // Hkv

    _, S, _, Wp = split_layout(W, kv_tile_blocks, split_k)
    bt = jnp.pad(block_tables.astype(jnp.int32), ((0, 0), (0, Wp - W)))

    k = gather_kv_dequant(k_pool, k_scale, bt)     # (B, Hkv, Wp*BS, D)
    v = gather_kv_dequant(v_pool, v_scale, bt)
    P = (Wp * BS) // S                             # columns per partition
    k = k.reshape(B, Hkv, S, P, D)
    v = v.reshape(B, Hkv, S, P, D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bhspd->bhgsp", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    kj = jnp.arange(Wp * BS, dtype=jnp.int32).reshape(S, P)
    valid = kj[None] < lengths.astype(jnp.int32)[:, None, None]  # (B, S, P)
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)         # (B, Hkv, G, S, 1)
    m = jnp.ceil(m) if intmax else m
    # masked columns contribute exactly 0 (exp2(NEG_INF - m) underflows),
    # but a *fully* masked partition would see exp2(0) = 1 per column —
    # zero those explicitly so empty partitions carry the merge identity
    p = jnp.where(valid[:, None, None, :, :], jnp.exp2(s - m), 0.0)
    d = jnp.sum(p, axis=-1, keepdims=True)         # (B, Hkv, G, S, 1)
    m = jnp.where(d > 0, m, NEG_INF)               # identity for empties
    acc = jnp.einsum("bhgsp,bhspd->bhgsd", p, v.astype(jnp.float32))
    _, d2, acc2 = softermax_merge(m, d, acc, axis=3)
    o = softermax_finalize(acc2, d2)               # (B, Hkv, G, D)
    return o.reshape(B, Hq, D).astype(q.dtype)
