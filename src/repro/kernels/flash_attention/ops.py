"""Public flash-attention op: scaling conventions + trainable custom_vjp.

Forward runs the Pallas kernel (Softermax online recurrence) and saves the
per-row (IntMax m, denominator d) statistics; backward runs the Pallas flash
backward kernels (``flash_backward.py``) which recompute P blockwise from
those statistics — memory-linear training. A reference-VJP backward is kept
selectable for cross-checking (``bwd_impl="ref"``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.numerics import LOG2_E
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.flash_backward import flash_attention_bwd
from repro.kernels.flash_attention.ref import attention_ref


def scale_queries(q: jax.Array, d_head: int, base2: bool) -> jax.Array:
    """Fold 1/sqrt(d) — and log2(e) for the e-base ablation — into Q.

    This is the software half of base replacement: the conversion multiply
    happens once on a [*, d_head] tensor, never on the [*, S, S] scores.
    """
    scale = d_head ** -0.5
    if not base2:
        scale = scale * LOG2_E
    return q * jnp.asarray(scale, q.dtype)


@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7),
)
def flash_attention_op(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    intmax: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    return flash_attention(
        q, k, v,
        causal=causal, intmax=intmax,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def _fwd(q, k, v, causal, intmax, block_q, block_k, interpret):
    out, m, d = flash_attention(
        q, k, v, causal=causal, intmax=intmax,
        block_q=block_q, block_k=block_k, interpret=interpret,
        return_stats=True)
    return out, (q, k, v, out, m, d)


def _bwd(causal, intmax, block_q, block_k, interpret, res, g):
    q, k, v, o, m, d = res
    return flash_attention_bwd(
        q, k, v, o, g, m, d, causal=causal,
        block_q=block_q, block_k=block_k, interpret=interpret)


flash_attention_op.defvjp(_fwd, _bwd)


def flash_attention_op_refbwd(q, k, v, *, causal=True, intmax=True,
                              interpret=False):
    """Cross-check variant: kernel forward, reference-autodiff backward."""

    @jax.custom_vjp
    def op(q, k, v):
        return flash_attention(q, k, v, causal=causal, intmax=intmax,
                               interpret=interpret)

    def fwd(q, k, v):
        return op(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                             intmax=intmax), q, k, v)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op(q, k, v)
