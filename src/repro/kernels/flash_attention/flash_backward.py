"""Pallas TPU kernels: flash-attention backward pass (softermax-aware).

Standard two-kernel flash backward, adapted to the base-2 softmax: with
``p = 2^(s - m)/d`` (m the running IntMax — a constant under differentiation
since ceil has zero gradient, and it cancels from the simplex Jacobian),

    dP_ij   = dO_i · V_j
    delta_i = Σ_j P_ij dP_ij = dO_i · O_i
    dS_ij   = ln(2) · P_ij (dP_ij - delta_i)      ← the base-2 factor
    dV_j    = Σ_i P_ij dO_i
    dK_j    = Σ_i dS_ij Q_i
    dQ_i    = Σ_j dS_ij K_j

P is recomputed blockwise from the forward's saved (m, d) row statistics —
the recompute-instead-of-store trade that makes flash training memory-linear.
GQA: gradients are produced at Hq granularity; the caller group-sums dK/dV.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.numerics import LN_2, NEG_INF


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, d_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, causal: bool, block_q: int, block_k: int, q_offset: int):
    """grid (BH, nK, nQ): one K/V block accumulates over all Q blocks."""
    j, i = pl.program_id(1), pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0].astype(jnp.float32)          # (BQ, D)
    k = k_ref[0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)        # (BQ, D)
    m = m_ref[0].astype(jnp.float32)          # (BQ, 1)
    d = d_ref[0].astype(jnp.float32)
    delta = delta_ref[0].astype(jnp.float32)  # (BQ, 1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (BQ, BK)
    if causal:
        qi = (i * block_q + q_offset
              + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0))
        kj = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qi >= kj, s, NEG_INF)
    p = jnp.exp2(s - m) / jnp.maximum(d, 1e-30)                  # (BQ, BK)
    dv_scr[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                      # (BK, D)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = LN_2 * p * (dp - delta)                                 # (BQ, BK)
    dk_scr[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                      # (BK, D)

    @pl.when(i == nq - 1)
    def _fin():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, d_ref, delta_ref,
               dq_ref, dq_scr,
               *, causal: bool, block_q: int, block_k: int, q_offset: int):
    """grid (BH, nQ, nK): one Q block accumulates over all K blocks."""
    i, j = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    m = m_ref[0].astype(jnp.float32)
    d = d_ref[0].astype(jnp.float32)
    delta = delta_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if causal:
        qi = (i * block_q + q_offset
              + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0))
        kj = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qi >= kj, s, NEG_INF)
    p = jnp.exp2(s - m) / jnp.maximum(d, 1e-30)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = LN_2 * p * (dp - delta)
    dq_scr[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _fin():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention_bwd(
    q: jax.Array,   # (B, Hq, Sq, D) pre-scaled (same as forward)
    k: jax.Array,   # (B, Hkv, Sk, D)
    v: jax.Array,
    o: jax.Array,   # forward output (B, Hq, Sq, D)
    do: jax.Array,  # cotangent
    m: jax.Array,   # (B, Hq, Sq, 1) forward row max (IntMax)
    d: jax.Array,   # (B, Hq, Sq, 1) forward denominator
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """Returns (dq, dk, dv) with dk/dv at (B, Hkv, ...) (group-summed)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    op = jnp.pad(o, ((0, 0), (0, 0), (0, pq), (0, 0)))
    dop = jnp.pad(do, ((0, 0), (0, 0), (0, pq), (0, 0)))
    # padded q rows: force empty softmax rows (d=1, m=0 → p=2^NEG_INF=0)
    mp = jnp.pad(m, ((0, 0), (0, 0), (0, pq), (0, 0)))
    dp_ = jnp.pad(d, ((0, 0), (0, 0), (0, pq), (0, 0)),
                  constant_values=1.0)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sqp, Skp = Sq + pq, Sk + pk
    nq, nk = Sqp // block_q, Skp // block_k
    q_offset = Sk - Sq

    delta = jnp.sum(dop.astype(jnp.float32) * op.astype(jnp.float32),
                    axis=-1, keepdims=True)

    qf = qp.reshape(B * Hq, Sqp, D)
    of = dop.reshape(B * Hq, Sqp, D)
    mf = mp.reshape(B * Hq, Sqp, 1)
    df = dp_.reshape(B * Hq, Sqp, 1)
    deltaf = delta.reshape(B * Hq, Sqp, 1)
    kf = kp.reshape(B * Hkv, Skp, D)
    vf = vp.reshape(B * Hkv, Skp, D)

    def kv_map_j_first(h, j, i):
        return ((h // Hq) * Hkv + (h % Hq) // group, j, 0)

    def kv_map_i_first(h, i, j):
        return ((h // Hq) * Hkv + (h % Hq) // group, j, 0)

    common = dict(causal=causal, block_q=block_q, block_k=block_k,
                  q_offset=q_offset)

    dkv = pl.pallas_call(
        functools.partial(_dkv_kernel, **common),
        grid=(B * Hq, nk, nq),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, j, i: (h, i, 0)),
            pl.BlockSpec((1, block_k, D), kv_map_j_first),
            pl.BlockSpec((1, block_k, D), kv_map_j_first),
            pl.BlockSpec((1, block_q, D), lambda h, j, i: (h, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda h, j, i: (h, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda h, j, i: (h, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda h, j, i: (h, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, D), lambda h, j, i: (h, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, j, i: (h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hq, Skp, D), jnp.float32),
            jax.ShapeDtypeStruct((B * Hq, Skp, D), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, D), jnp.float32),
            pltpu.VMEM((block_k, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf, of, mf, df, deltaf)
    dk_full, dv_full = dkv

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, D), kv_map_i_first),
            pl.BlockSpec((1, block_k, D), kv_map_i_first),
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda h, i, j: (h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sqp, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf, of, mf, df, deltaf)

    dq = dq.reshape(B, Hq, Sqp, D)[:, :, :Sq].astype(q.dtype)
    dk_full = dk_full.reshape(B, Hkv, group, Skp, D)[:, :, :, :Sk]
    dv_full = dv_full.reshape(B, Hkv, group, Skp, D)[:, :, :, :Sk]
    dk = jnp.sum(dk_full, axis=2).astype(k.dtype)   # group-sum (GQA)
    dv = jnp.sum(dv_full, axis=2).astype(v.dtype)
    return dq, dk, dv
