"""Pure-jnp oracle for flash attention: full-matrix attention with the same
softmax variant. Queries sit at the end of the kv axis (decode convention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.numerics import NEG_INF
from repro.core.softermax import softermax, softmax_base2


def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D) — pre-scaled
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    intmax: bool = True,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    if causal:
        qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kj = jnp.arange(Sk)[None, :]
        s = jnp.where(qi >= kj, s, NEG_INF)
    p = softermax(s, axis=-1) if intmax else softmax_base2(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)
