"""Pallas TPU kernel: fused attention with the Softermax online recurrence.

This is the paper's co-design mapped to the TPU memory hierarchy: the ASIC's
Unnormed-Softmax-Unit / Normalization-Unit split becomes the classic
flash-attention two-phase structure, with three Softermax-specific changes:

1. **Base 2** — scores are exponentiated with ``exp2`` directly. For the
   e-base ablation the ``log2(e)`` factor is folded into the Q scaling
   *outside* the kernel (one multiply on a [*, d_head] tensor instead of a
   [*, S, S] tensor — the software form of the paper's base replacement).
2. **IntMax** — the running max is kept as ``ceil`` of the true max, so every
   rescale factor ``2^(m_prev - m_new)`` has an integer exponent and is an
   exact power of two (the paper's shifter; an exponent-add on the VPU).
3. **Online normalization** — one pass over K/V, no explicit max pass. The
   HBM pass the ASIC saves is exactly the HBM round-trip flash attention
   saves.

Grid: ``(batch*q_heads, num_q_blocks, num_kv_blocks)`` with kv sequential.
GQA is handled in the K/V index maps (q head → kv head = h // group).
Block sizes: q/kv blocks multiples of (8, 128); d_head is kept whole in VMEM
(the assigned archs have d_head ∈ {64, 128, 192}).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.numerics import NEG_INF


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_out_ref, d_out_ref,
                  acc_scr, m_scr, d_scr,
                  *, intmax: bool, causal: bool, block_q: int, block_k: int,
                  q_offset: int, kv_len: int):
    i, j = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        d_scr[...] = jnp.zeros_like(d_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q + q_offset
    k_start = j * block_k

    def _body():
        q = q_ref[0].astype(jnp.float32)          # (BQ, D)
        k = k_ref[0].astype(jnp.float32)          # (BK, D)
        v = v_ref[0].astype(jnp.float32)          # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)   # (BQ, BK)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if causal:
            qi = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            s = jnp.where(qi >= kj, s, NEG_INF)
        else:
            # padded kv tail (non-causal): mask positions beyond the true Sk
            s = jnp.where(kj < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        sl = jnp.ceil(s) if intmax else s         # IntMax
        m_new = jnp.maximum(m_prev, jnp.max(sl, axis=1, keepdims=True))
        alpha = jnp.exp2(m_prev - m_new)          # exact power-of-two rescale
        p = jnp.exp2(s - m_new)                   # base-2, no log2e multiply
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        d_scr[...] = d_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new

    if causal:
        # Skip kv blocks strictly above the diagonal for every row in the tile.
        pl.when(k_start <= q_start + block_q - 1)(_body)
    else:
        _body()

    @pl.when(j == nk - 1)
    def _fin():
        d = d_scr[...]
        recip = jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
        o_ref[0] = (acc_scr[...] * recip).astype(o_ref.dtype)
        # row statistics saved for the flash backward pass
        m_out_ref[0] = m_scr[...]
        d_out_ref[0] = d_scr[...]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "intmax", "block_q", "block_k", "interpret",
                     "return_stats"),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D) — pre-scaled (1/sqrt d, and log2e if e-base)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    intmax: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    return_stats: bool = False,  # also return (m, d) rows for the backward
):
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sqp, Skp = Sq + pq, Sk + pk

    qf = qp.reshape(B * Hq, Sqp, D)
    kf = kp.reshape(B * Hkv, Skp, D)
    vf = vp.reshape(B * Hkv, Skp, D)
    nq, nk = Sqp // block_q, Skp // block_k

    def kv_map(h, i, j):
        return ((h // Hq) * Hkv + (h % Hq) // group, j, 0)

    # Decode/extension convention: queries sit at the END of the kv axis
    # (q row r attends to kv positions <= Sk - Sq + r).
    q_offset = Sk - Sq

    out, m_rows, d_rows = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            intmax=intmax,
            causal=causal,
            block_q=block_q,
            block_k=block_k,
            q_offset=q_offset,
            kv_len=Sk,
        ),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda h, i, j: (h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hq, Sqp, D), q.dtype),
            jax.ShapeDtypeStruct((B * Hq, Sqp, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * Hq, Sqp, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)

    o = out.reshape(B, Hq, Sqp, D)[:, :, :Sq, :]
    if return_stats:
        return (o,
                m_rows.reshape(B, Hq, Sqp, 1)[:, :, :Sq],
                d_rows.reshape(B, Hq, Sqp, 1)[:, :, :Sq])
    return o
