"""Pallas TPU kernels for the paper's compute hot-spots.

softermax/        row-wise Softermax, two-phase (Unnormed + Normalization unit)
softermax_quant/  bit-faithful fixed-point Softermax (Table-I Q-formats, LPW)
flash_attention/  fused attention with the Softermax online recurrence
flash_decode/     single-token decode attention over long KV caches
flash_decode_paged/  decode attention over a paged block pool via block
                  tables (scalar-prefetch gather; serving engine hot path)
"""
