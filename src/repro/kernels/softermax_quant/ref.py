"""Oracle for the fixed-point kernel: core's block-online fixed-point softermax.

Note on rounding points: the jnp reference quantizes unnormed numerators at
the *running* max and then rescales by an exact power of two in float; the
kernel (like the silicon) holds the post-shift value in Q(1,15). The two can
differ by 1 ulp of Q(1,15) at ties, which after the Q(1,7) output
quantization is at most 1 output ulp (2^-7) — the test tolerance.
"""
from __future__ import annotations

import jax

from repro.core.softermax import softermax_fixed


def softermax_quant_ref(x: jax.Array, vector_size: int = 16) -> jax.Array:
    return softermax_fixed(x, block=vector_size)
