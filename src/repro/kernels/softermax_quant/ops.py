"""Public wrapper for the fixed-point softermax kernel."""
from __future__ import annotations

import jax

from repro.kernels.softermax_quant.softermax_quant import softermax_quant_rows


def softermax_quant_op(x: jax.Array, *, vector_size: int = 16,
                       block_rows: int = 8,
                       interpret: bool = False) -> jax.Array:
    shape = x.shape
    x2 = x.reshape((-1, shape[-1]))
    out = softermax_quant_rows(x2, vector_size=vector_size,
                               block_rows=block_rows, interpret=interpret)
    return out.reshape(shape)
