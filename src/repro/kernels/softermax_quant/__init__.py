from repro.kernels.softermax_quant.ops import softermax_quant_op
from repro.kernels.softermax_quant.ref import softermax_quant_ref
from repro.kernels.softermax_quant.softermax_quant import softermax_quant_rows

__all__ = ["softermax_quant_op", "softermax_quant_ref", "softermax_quant_rows"]
