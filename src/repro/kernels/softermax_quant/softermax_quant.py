"""Pallas kernel: bit-faithful fixed-point Softermax (§III.B + Table I).

Simulates the exact hardware pipeline per row, VectorSize elements at a time:

    Q(6,2) input → IntMax → LPW power-of-two → Q(1,15) unnormed numerators
    → Q(10,6) running PowSum with shift renormalization
    → LPW reciprocal Q(1,7) → Q(1,7) output

One grid step owns a ``(block_rows, V)`` tile in VMEM and iterates the
hardware's VectorSize-wide slices with ``lax.fori_loop`` — the loop carries
(running IntMax, running PowSum) exactly like the Reduction unit's buffers.
All arithmetic is float-simulated fixed point: every value is snapped to its
Q-format grid at the same interface the silicon would quantize it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import quant


def _quant_kernel(x_ref, o_ref, *, vector_size: int,
                  bw: quant.SoftermaxBitwidths):
    x = x_ref[...].astype(jnp.float32)
    rows, V = x.shape
    n_slices = V // vector_size

    xq = bw.inp.quantize_exact(x)  # Q(6,2) scores

    def slice_step(s, carry):
        m, d = carry
        xv = jax.lax.dynamic_slice(xq, (0, s * vector_size),
                                   (rows, vector_size))
        # IntMax unit: ceil per element, then slice max and running max.
        local_m = jnp.max(jnp.ceil(xv), axis=1)
        m_new = jnp.maximum(m, local_m)
        # Power-of-two unit (LPW) → Q(1,15); Reduction unit accumulate.
        un = quant.lpw_exp2(xv - m_new[:, None], out_fmt=bw.unnormed)
        local_d = jnp.sum(un, axis=1)
        # Shift-renormalize the running PowSum (integer exponent ⇒ exact).
        d = bw.powsum.quantize_exact(d * jnp.exp2(m - m_new) + local_d)
        return (m_new, d)

    init = (jnp.full((rows,), float(bw.inp.min_value), jnp.float32),
            jnp.zeros((rows,), jnp.float32))
    m_fin, d_fin = jax.lax.fori_loop(0, n_slices, slice_step, init)

    # Normalization unit: recompute unnormed numerators against the final max
    # (equivalent to the stored-numerator + shift path: 2^(x-m_run) *
    # 2^(m_run-m_fin) == 2^(x-m_fin) exactly, since all shifts are integer),
    # then multiply by the LPW reciprocal of the PowSum.
    un_fin = quant.lpw_exp2(xq - m_fin[:, None], out_fmt=bw.unnormed)
    recip = quant.lpw_reciprocal(d_fin, out_fmt=bw.recip)
    y = bw.outp.quantize_exact(un_fin * recip[:, None])
    y = jnp.where(d_fin[:, None] > 0, y, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("vector_size", "block_rows", "interpret"),
)
def softermax_quant_rows(
    x: jax.Array,
    *,
    vector_size: int = 16,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Fixed-point Softermax over the last axis of ``(rows, V)``."""
    rows, V = x.shape
    pr = (-rows) % block_rows
    pv = (-V) % vector_size
    bw = quant.DEFAULT_BITWIDTHS
    xp = jnp.pad(x, ((0, pr), (0, pv)), constant_values=bw.inp.min_value)
    R, Vp = xp.shape

    out = pl.pallas_call(
        functools.partial(_quant_kernel, vector_size=vector_size, bw=bw),
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, Vp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, Vp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, Vp), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:rows, :V]
