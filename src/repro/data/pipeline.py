"""Synthetic LM data pipeline: sharded, deterministic, checkpointable.

Production framing without external datasets: a seeded generator produces
structured token streams (a mixture of copy/induction patterns and Zipfian
noise — learnable, so train-loss curves are meaningful), batched to the
global batch and shardable across hosts. The iterator state is a single
(seed, step) pair, so data position is restored exactly on restart —
checkpoint/resume of the *pipeline* is what matters at fleet scale, and this
keeps it byte-exact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(int(d["seed"]), int(d["step"]))


class SyntheticLMData:
    """Deterministic synthetic LM batches.

    Each sequence: a random "program" of period-p repetition: tokens repeat
    with period p ∈ [4, 32], corrupted by Zipf noise — next-token prediction
    is learnable (copy heads) but not trivial.
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1):
        assert global_batch % num_hosts == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // num_hosts
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.state = DataState(seed=seed, step=0)

    def _gen(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.state.seed * 1_000_003 + step) * 65_521 + self.host_id)
        B, S, V = self.local_batch, self.seq, self.vocab
        periods = rng.integers(4, 33, size=(B, 1))
        base = rng.integers(1, V, size=(B, 33))
        idx = np.arange(S + 1)[None, :] % periods
        toks = np.take_along_axis(
            np.broadcast_to(base, (B, 33)), idx.clip(max=32), axis=1)
        noise = rng.random((B, S + 1)) < 0.05
        toks = np.where(noise, rng.integers(1, V, size=(B, S + 1)), toks)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self._gen(self.state.step)
        self.state.step += 1
        return batch

    def restore(self, state: DataState) -> None:
        self.state = dataclasses.replace(state)
