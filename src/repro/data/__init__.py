from repro.data.pipeline import DataState, SyntheticLMData

__all__ = ["DataState", "SyntheticLMData"]
