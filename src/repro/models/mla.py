"""Multi-head Latent Attention (DeepSeek-V2) with Softermax.

Train/prefill uses the *expanded* formulation: the compressed KV latent
``c_kv`` (rank ``kv_lora``) is up-projected to per-head keys/values and
attention runs through the shared chunked online-softermax path (qk dim =
qk_nope + qk_rope, v dim = v_head — the chunked kernel supports Dk != Dv).

Decode uses the *absorbed* formulation faithful to DeepSeek inference: the
cache stores only ``c_kv`` (B,S,kv_lora) + the shared roped key
(B,S,qk_rope); queries are absorbed through the k up-projection so scores are
computed directly against the latent — softmax (softermax here) over the
latent scores, then the attention-weighted latent is pushed through the v
up-projection. MLA changes *what* QK^T is; the softmax between the two
matmuls is exactly where the paper's technique drops in.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.numerics import NEG_INF
from repro.models.attention import _mode, chunked_attention
from repro.models.layers import rmsnorm, rmsnorm_schema, rope
from repro.models.schema import ParamSpec
from repro.parallel.sharding import shard_act


def mla_schema(cfg: ModelConfig):
    a = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = a.qk_nope + a.qk_rope
    s = {}
    if a.q_lora > 0:
        s["wq_a"] = ParamSpec((d, a.q_lora), ("embed", "q_lora"))
        s["q_norm"] = rmsnorm_schema(a.q_lora, "q_lora")
        s["wq_b"] = ParamSpec((a.q_lora, H, qk), ("q_lora", "heads", "head_dim"))
    else:
        s["wq"] = ParamSpec((d, H, qk), ("embed", "heads", "head_dim"))
    s["wkv_a"] = ParamSpec((d, a.kv_lora + a.qk_rope), ("embed", "kv_lora"))
    s["kv_norm"] = rmsnorm_schema(a.kv_lora, "kv_lora")
    s["wk_b"] = ParamSpec((a.kv_lora, H, a.qk_nope),
                          ("kv_lora", "heads", "head_dim"))
    s["wv_b"] = ParamSpec((a.kv_lora, H, a.v_head),
                          ("kv_lora", "heads", "head_dim"))
    s["wo"] = ParamSpec((H, a.v_head, d), ("heads", "head_dim", "embed"))
    return s


def _queries(params, x, cfg: ModelConfig, positions):
    """(B,S,d) → q_nope (B,H,S,nope), q_rope (B,H,S,rope)."""
    a = cfg.mla
    dt = cfg.compute_dtype_
    if a.q_lora > 0:
        cq = rmsnorm(params["q_norm"], x @ params["wq_a"].astype(dt),
                     cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bhsk", cq, params["wq_b"].astype(dt))
    else:
        q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(dt))
    q_nope, q_rope = q[..., :a.qk_nope], q[..., a.qk_nope:]
    q_rope = rope(q_rope, positions[:, None, :], cfg.rope_theta)
    return q_nope, q_rope


def _latent(params, x, cfg: ModelConfig, positions):
    """(B,S,d) → c_kv (B,S,kv_lora) normed, k_rope (B,S,rope) roped."""
    a = cfg.mla
    dt = cfg.compute_dtype_
    ckr = x @ params["wkv_a"].astype(dt)
    c_kv = rmsnorm(params["kv_norm"], ckr[..., :a.kv_lora], cfg.norm_eps)
    k_rope = rope(ckr[..., a.kv_lora:], positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_apply(params, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, window: int = 0,
              return_cache: bool = False):
    """Train/prefill MLA.

    Expanded form (baseline): latent up-projected to per-head K (192) / V
    (128) before attention — cross-chip K/V traffic and activation memory
    scale with H·(192+128).

    Absorbed form (``opt_mla_absorbed``): queries are pushed through the K
    up-projection, attention runs against the 576-d latent as ONE shared KV
    head (GQA group = n_heads), and V up-projection happens after the
    weighted sum. Exactly equivalent by associativity:
    q·(c@W_k) == (q@W_kᵀ)·c and p·(c@W_v) == (p·c)@W_v. This is DeepSeek's
    own inference trick applied to the training graph — K/V are never
    materialized, so sequence-parallel attention gathers 576 dims instead of
    128 heads × 320 dims."""
    a = cfg.mla
    dt = cfg.compute_dtype_
    B, S, d = x.shape
    H = cfg.n_heads
    premult, intmax = _mode(cfg)

    q_nope, q_rope = _queries(params, x, cfg, positions)
    c_kv, k_rope = _latent(params, x, cfg, positions)
    scale = (a.qk_nope + a.qk_rope) ** -0.5 * premult

    if cfg.opt_mla_absorbed:
        q_abs = jnp.einsum("bhsn,rhn->bhsr", q_nope,
                           params["wk_b"].astype(dt))   # (B,H,S,kv_lora)
        q_full = jnp.concatenate([q_abs, q_rope], axis=-1)
        q_full = q_full * jnp.asarray(scale, q_full.dtype)
        k_full = jnp.concatenate([c_kv, k_rope], axis=-1)[:, None]
        v_lat = c_kv[:, None]                           # (B,1,S,kv_lora)
        q_full = shard_act(q_full, ("batch", "act_heads", "seq", None))
        o_lat = chunked_attention(q_full, k_full, v_lat, causal=cfg.causal,
                                  intmax=intmax, window=window,
                                  chunk=cfg.attention_chunk)
        o = jnp.einsum("bhsr,rhk->bhsk", o_lat, params["wv_b"].astype(dt))
    else:
        k_nope = jnp.einsum("bsr,rhk->bhsk", c_kv, params["wk_b"].astype(dt))
        v = jnp.einsum("bsr,rhk->bhsk", c_kv, params["wv_b"].astype(dt))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, None], (B, H, S, a.qk_rope))],
            axis=-1)
        q = q * jnp.asarray(scale, q.dtype)
        q = shard_act(q, ("batch", "act_heads", "seq", "head_dim"))
        k = shard_act(k, ("batch", "act_heads", "seq", "head_dim"))
        v = shard_act(v, ("batch", "act_heads", "seq", "head_dim"))
        o = chunked_attention(q, k, v, causal=cfg.causal, intmax=intmax,
                              window=window, chunk=cfg.attention_chunk)
    y = jnp.einsum("bhsk,hkd->bsd", o, params["wo"].astype(dt))
    if return_cache:
        return y, c_kv, k_rope
    return y


def mla_prefill_cache(params, x, cfg: ModelConfig, positions):
    """Latent cache entries for the prefill tokens."""
    return _latent(params, x, cfg, positions)


def mla_decode(
    params,
    x1: jax.Array,               # (B, d)
    cfg: ModelConfig,
    *,
    cache_ckv: jax.Array,        # (B, S, kv_lora)
    cache_krope: jax.Array,      # (B, S, qk_rope)
    cache_len: jax.Array,        # (B,)
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed-form decode against the compressed latent cache."""
    a = cfg.mla
    dt = cfg.compute_dtype_
    B = x1.shape[0]
    pos1 = cache_len[:, None]                       # (B,1) current position

    q_nope, q_rope = _queries(params, x1[:, None, :], cfg, pos1)
    q_nope, q_rope = q_nope[:, :, 0], q_rope[:, :, 0]   # (B,H,·)
    c1, kr1 = _latent(params, x1[:, None, :], cfg, pos1)

    S = cache_ckv.shape[1]
    if cfg.opt_dus_cache:
        pos = cache_len[0]
        cache_ckv = jax.lax.dynamic_update_slice(
            cache_ckv, c1.astype(cache_ckv.dtype), (0, pos, 0))
        cache_krope = jax.lax.dynamic_update_slice(
            cache_krope, kr1.astype(cache_krope.dtype), (0, pos, 0))
    else:
        onehot = (jnp.arange(S)[None, :] == cache_len[:, None]).astype(dt)
        cache_ckv = cache_ckv + onehot[..., None] * c1
        cache_krope = cache_krope + onehot[..., None] * kr1
    new_len = cache_len + 1

    # absorb q through the k up-projection: scores live in latent space
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope, params["wk_b"].astype(dt))
    s = (jnp.einsum("bhr,bsr->bhs", q_abs, cache_ckv) +
         jnp.einsum("bhk,bsk->bhs", q_rope, cache_krope)
         ).astype(jnp.float32)
    scale = (a.qk_nope + a.qk_rope) ** -0.5
    premult, intmax = _mode(cfg)
    s = s * (scale * premult)
    live = jnp.arange(S)[None, None, :] < new_len[:, None, None]
    s = jnp.where(live, s, NEG_INF)
    m = jnp.max(jnp.ceil(s) if intmax else s, axis=-1, keepdims=True)
    p = jnp.exp2(s - m)
    den = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(den > 0, p / jnp.where(den > 0, den, 1.0), 0.0)
    o_lat = jnp.einsum("bhs,bsr->bhr", p.astype(dt), cache_ckv)
    o = jnp.einsum("bhr,rhk->bhk", o_lat, params["wv_b"].astype(dt))
    y1 = jnp.einsum("bhk,hkd->bd", o, params["wo"].astype(dt))
    return y1, cache_ckv, cache_krope
