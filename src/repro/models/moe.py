"""Mixture-of-Experts FFN with sort-based capacity dispatch (TPU-native).

Routing pipeline (per layer, tokens flattened to T = B·S):

1. Router logits → probabilities. **Beyond-paper extension**: the router
   softmax also runs through Softermax (base-2) — the paper only touches
   attention, but every softmax in the network benefits from the same
   hardware-friendly form (``cfg.moe.router_softmax``).
2. top-k experts per token, weights renormalized over the selected k.
3. Capacity-bounded dispatch: assignments are sorted by expert id; each
   assignment's rank within its expert is its capacity slot; overflow
   (rank ≥ C) is dropped (standard Switch semantics). The gathered
   ``(E, C, d)`` buffer is *expert-sharded* over the model axis — the
   token-sharded → expert-sharded handoff lowers to an all-to-all under
   pjit, which is the EP communication pattern.
4. Per-expert gated MLP via batched einsum with ``(E, d, ff)`` weights.
5. Combine back with routing weights; add shared experts (DeepSeek) when
   configured.

Aux losses: switch load-balance loss + router z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.softermax import attention_softmax
from repro.models.layers import _activate, mlp, mlp_schema
from repro.models.schema import ParamSpec
from repro.parallel.sharding import current_mesh, shard_act
from repro.parallel.compat import shard_map


def moe_schema(cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    s = {
        "router": ParamSpec((d, m.n_experts), ("embed", "experts"), std=0.02),
        "wi": ParamSpec((m.n_experts, d, m.d_expert),
                        ("experts", "embed", "expert_mlp")),
        "wg": ParamSpec((m.n_experts, d, m.d_expert),
                        ("experts", "embed", "expert_mlp")),
        "wo": ParamSpec((m.n_experts, m.d_expert, d),
                        ("experts", "expert_mlp", "embed")),
    }
    if m.n_shared > 0:
        s["shared"] = mlp_schema(d, m.n_shared * (m.d_shared or m.d_expert))
    return s


def moe_apply(params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, aux_loss). Dispatches to the shard_map EP path
    when enabled and applicable (see moe_apply_shard_map)."""
    mesh = current_mesh()
    if (cfg.opt_moe_shard_map and mesh is not None
            and "model" in mesh.shape and mesh.shape["model"] > 1
            and x.shape[1] % mesh.shape["model"] == 0
            and cfg.moe.n_experts % mesh.shape["model"] == 0):
        return moe_apply_shard_map(params, x, cfg, mesh)
    return _moe_apply_global(params, x, cfg)


def _moe_apply_global(params, x: jax.Array, cfg: ModelConfig
                      ) -> Tuple[jax.Array, jax.Array]:
    """Global (pjit-only) dispatch — the §Roofline baseline. The scatter
    into the expert-sharded buffer costs a full-buffer all-reduce under
    SPMD; kept as the fallback for decode (S=1) and tiny meshes."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    dt = x.dtype
    xf = x.reshape(-1, d)                                     # (T, d)
    T = xf.shape[0]

    # --- router (fp32 logits; softermax probabilities) ---
    rl = (xf @ params["router"].astype(dt)).astype(jnp.float32)   # (T, E)
    probs = attention_softmax(rl, impl=m.router_softmax, axis=-1)
    weights, sel = jax.lax.top_k(probs, k)                    # (T, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)

    # --- aux losses ---
    # load-balance: E * sum_e mean_prob_e * frac_dispatched_e
    me = jnp.mean(probs, axis=0)                              # (E,)
    one_hot_sel = jax.nn.one_hot(sel, E, dtype=jnp.float32)   # (T, k, E)
    ce = jnp.mean(jnp.sum(one_hot_sel, axis=1), axis=0) / k   # (E,)
    aux = E * jnp.sum(me * ce) * m.aux_loss_weight
    aux = aux + 1e-4 * jnp.mean(jax.nn.logsumexp(rl, axis=-1) ** 2)

    # --- capacity-bounded sort dispatch ---
    C = int(max(8, -(-T * k // E) * m.capacity_factor))       # slots/expert
    C = -(-C // 8) * 8
    flat_e = sel.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))        # (E,)
    rank_sorted = jnp.arange(T * k) - starts[sorted_e]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    keep = rank < C                                           # (T*k,)
    slot = jnp.where(keep, flat_e * C + rank, E * C)          # overflow→dummy
    tok = jnp.arange(T * k) // k

    buf = jnp.zeros((E * C + 1, d), dt).at[slot].add(
        xf[tok] * keep[:, None].astype(dt))
    h = buf[:-1].reshape(E, C, d)
    h = shard_act(h, ("experts", None, "act_embed"))

    # --- expert gated MLP (batched einsum; E sharded over model axis) ---
    wi = params["wi"].astype(dt)
    wg = params["wg"].astype(dt)
    wo = params["wo"].astype(dt)
    a = _activate(jnp.einsum("ecd,edf->ecf", h, wi), cfg.activation)
    a = a * jnp.einsum("ecd,edf->ecf", h, wg)
    y_buf = jnp.einsum("ecf,efd->ecd", a, wo)
    y_buf = shard_act(y_buf, ("experts", None, "act_embed"))

    # --- combine ---
    y_flat = y_buf.reshape(E * C, d)
    safe_slot = jnp.minimum(slot, E * C - 1)
    y_tok = y_flat[safe_slot] * (keep[:, None] * weights.reshape(-1)[:, None]
                                 ).astype(dt)
    y = jnp.sum(y_tok.reshape(T, k, d), axis=1)

    if m.n_shared > 0:
        y = y + mlp(params["shared"], xf, cfg.activation)

    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# shard_map expert-parallel dispatch (opt_moe_shard_map)
# ---------------------------------------------------------------------------
#
# The global path's scatter into the expert-sharded (E·C, d) buffer lowers to
# a full-buffer all-reduce under SPMD (measured: 8.9 TB/chip wire for the
# deepseek train cell — EXPERIMENTS.md §Perf). This path instead:
#
#   1. enters shard_map over (batch→data, seq→model): T_loc tokens per chip;
#   2. routes + capacity-dispatches LOCALLY into (E, C_loc, d);
#   3. all_to_all over "model" sends each expert block to its owner
#      (payload ≈ tokens·k·d — the EP-minimal wire);
#   4. expert FFN with explicitly all-gathered (bf16) weight shards;
#   5. all_to_all back + local combine.
#
# Routing decisions are identical to the global path per token; capacity is
# enforced per (token-shard × expert) instead of globally — the standard EP
# approximation (local capacity C_loc = C_global / n_shards).


def _local_dispatch(xf, probs, k, E, C, dt):
    """Sort-based capacity dispatch on LOCAL tokens.

    xf: (T, d); probs: (T, E). Returns (buf (E, C, d), slot (T*k,),
    keep (T*k,), weights (T, k))."""
    T = xf.shape[0]
    weights, sel = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    flat_e = sel.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank_sorted = jnp.arange(T * k) - starts[sorted_e]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)
    tok = jnp.arange(T * k) // k
    buf = jnp.zeros((E * C + 1, xf.shape[1]), dt).at[slot].add(
        xf[tok] * keep[:, None].astype(dt))
    return buf[:-1].reshape(E, C, xf.shape[1]), slot, keep, weights, sel


def moe_apply_shard_map(params, x: jax.Array, cfg: ModelConfig, mesh
                        ) -> Tuple[jax.Array, jax.Array]:
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    n_model = mesh.shape["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_data = 1
    for a in batch_axes:
        n_data *= mesh.shape[a]
    b_loc = B // n_data if B % n_data == 0 else B
    T_loc = b_loc * (S // n_model)
    C = int(max(4, -(-T_loc * k // E) * m.capacity_factor))
    C = -(-C // 4) * 4
    E_loc = E // n_model
    dt = x.dtype

    def _inner(x_l, router, wi, wg, wo):
        # x_l: (b_loc, S_loc, d); wi/wg: (E_loc, d_shard, ff); wo transposed
        T = x_l.shape[0] * x_l.shape[1]
        xf = x_l.reshape(T, d)
        rl = (xf @ router.astype(dt)).astype(jnp.float32)
        probs = attention_softmax(rl, impl=m.router_softmax, axis=-1)
        buf, slot, keep, weights, sel = _local_dispatch(
            xf, probs, k, E, C, dt)

        # aux losses from local statistics (pmean over shards)
        me = jnp.mean(probs, axis=0)
        ce_frac = jnp.mean(
            jnp.sum(jax.nn.one_hot(sel, E, dtype=jnp.float32), axis=1),
            axis=0) / k
        aux = E * jnp.sum(me * ce_frac) * m.aux_loss_weight
        aux = aux + 1e-4 * jnp.mean(jax.nn.logsumexp(rl, axis=-1) ** 2)
        aux = jax.lax.pmean(jax.lax.pmean(aux, "model"),
                            batch_axes) if batch_axes else \
            jax.lax.pmean(aux, "model")

        # ship expert blocks to their owners: (n_model, E_loc·C, d)
        send = buf.reshape(n_model, E_loc * C, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: (n_model, E_loc·C, d) — rows from every sender for MY experts
        h = recv.reshape(n_model, E_loc, C, d).transpose(1, 0, 2, 3) \
            .reshape(E_loc, n_model * C, d)

        # FSDP: gather the expert weights' d-shard (bf16 when opt_bf16)
        wi_g = jax.lax.all_gather(wi, "data", axis=1, tiled=True) \
            if "data" in mesh.shape else wi
        wg_g = jax.lax.all_gather(wg, "data", axis=1, tiled=True) \
            if "data" in mesh.shape else wg
        wo_g = jax.lax.all_gather(wo, "data", axis=2, tiled=True) \
            if "data" in mesh.shape else wo

        a = _activate(jnp.einsum("ecd,edf->ecf", h, wi_g.astype(dt)),
                      cfg.activation)
        a = a * jnp.einsum("ecd,edf->ecf", h, wg_g.astype(dt))
        y_h = jnp.einsum("ecf,efd->ecd", a, wo_g.astype(dt))

        # return to senders
        back = y_h.reshape(E_loc, n_model, C, d).transpose(1, 0, 2, 3) \
            .reshape(n_model, E_loc * C, d)
        y_buf = jax.lax.all_to_all(back, "model", split_axis=0,
                                   concat_axis=0, tiled=False)
        y_flat = y_buf.reshape(E * C, d)
        safe_slot = jnp.minimum(slot, E * C - 1)
        y_tok = y_flat[safe_slot] * (
            keep[:, None] * weights.reshape(-1)[:, None]).astype(dt)
        y = jnp.sum(y_tok.reshape(T, k, d), axis=1)
        return y.reshape(x_l.shape), aux

    x_spec = P(batch_axes if B % n_data == 0 else None, "model", None)
    out = shard_map(
        _inner, mesh=mesh,
        in_specs=(x_spec,
                  P(None, None),                    # router replicated
                  P("model", "data" if "data" in mesh.shape else None, None),
                  P("model", "data" if "data" in mesh.shape else None, None),
                  P("model", None, "data" if "data" in mesh.shape else None)),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, params["router"], params["wi"], params["wg"], params["wo"])
    y, aux = out

    if m.n_shared > 0:
        y = y + mlp(params["shared"], x, cfg.activation)
    return y, aux
