"""Attention with Softermax as a first-class feature.

Three interchangeable implementations (``cfg.attention_impl``):

* ``chunked`` — XLA-level flash: ``lax.scan`` over KV chunks carrying the
  Softermax online state (running IntMax, running denominator, accumulator).
  This is the paper's online normalization expressed as a compile-time
  program transform — memory-linear in sequence length, differentiable, and
  what the multi-pod dry-runs lower. Every float softmax variant runs through
  ``exp2``: the e-base ablation folds log2(e) into the Q scale (base
  replacement as software).
* ``flash``   — the Pallas TPU kernel (kernels/flash_attention).
* ``naive``   — full score matrix through ``core.attention_softmax``; the only
  mode supporting ``softermax_fixed`` (bit-faithful QAT finetuning).

GQA, RoPE, per-head QK-norm (qwen3) and sliding windows (hymba long-context)
are supported in all paths. Decode attends a single token against a KV cache
(Pallas ``flash_decode`` or a masked jnp reduction).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.numerics import LOG2_E, NEG_INF
from repro.core.softermax import attention_softmax
from repro.kernels.flash_attention import flash_attention_op
from repro.kernels.flash_decode import flash_decode_op
from repro.models.layers import rmsnorm, rope
from repro.models.schema import ParamSpec
from repro.parallel.sharding import shard_act


def attention_schema(cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    dh = cfg.head_dim_
    s = {
        "wq": ParamSpec((d, cfg.n_heads, dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, cfg.n_kv_heads, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, cfg.n_kv_heads, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((cfg.n_heads, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = {"scale": ParamSpec((dh,), ("head_dim",), init="ones")}
        s["k_norm"] = {"scale": ParamSpec((dh,), ("head_dim",), init="ones")}
    return s


def _ring_applicable(cfg: ModelConfig, q, k, window, x_kv) -> bool:
    """Ring attention engages for SP self-attention: seq sharded over
    "model", equal q/kv lengths divisible by the ring size, no window."""
    if not cfg.opt_ring_attention or window or x_kv is not None:
        return False
    from repro.parallel.sharding import current_mesh, current_rules
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape or mesh.shape["model"] < 2:
        return False
    if "model" not in current_rules().get("seq"):
        return False
    n = mesh.shape["model"]
    return (q.shape[2] == k.shape[2] and q.shape[2] % n == 0)


def _mode(cfg: ModelConfig) -> Tuple[float, bool]:
    """(premultiplier, intmax) so that exp2 realizes the configured softmax."""
    impl = cfg.softmax_impl
    if impl == "softermax":
        return 1.0, True
    if impl == "base2":
        return 1.0, False
    if impl in ("softmax", "base2_folded"):
        return LOG2_E, False
    if impl == "softermax_fixed":
        return 1.0, True
    raise ValueError(impl)


def _project_qkv(params, x, cfg: ModelConfig, positions):
    """Q/K/V projections + qk-norm + RoPE. x: (B, S, d)."""
    dt = cfg.compute_dtype_
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bhsk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bhsk", x, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if cfg.rope_theta > 0:
        pos = positions[:, None, :]  # (B, 1, S) broadcast over heads
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def _out_proj(params, o, cfg: ModelConfig):
    """o: (B, H, S, Dh) -> (B, S, d)."""
    o = shard_act(o, ("batch", "act_heads", "seq", "head_dim"))
    return jnp.einsum("bhsk,hkd->bsd", o, params["wo"].astype(cfg.compute_dtype_))


# ---------------------------------------------------------------------------
# Chunked online-softermax attention (XLA-level flash)
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jax.Array,  # (B, Hq, Sq, D) — pre-scaled
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool,
    intmax: bool,
    window: int = 0,
    chunk: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    Dv = v.shape[-1]  # may differ from D (MLA: qk dim 192, v dim 128)
    group = Hq // Hkv
    chunk = min(chunk, Sk)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = (Sk + pad) // chunk
    qg = q.reshape(B, Hkv, group, Sq, D)
    kc = jnp.moveaxis(k.reshape(B, Hkv, n_chunks, chunk, D), 2, 0)
    vc = jnp.moveaxis(v.reshape(B, Hkv, n_chunks, chunk, Dv), 2, 0)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inputs):
        m, d, acc = carry
        k_c, v_c, c_idx = inputs
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_c,
                       preferred_element_type=jnp.float32)
        k_pos = c_idx * chunk + jnp.arange(chunk)
        valid = k_pos[None, :] < Sk
        if causal:
            valid = valid & (q_pos[:, None] >= k_pos[None, :])
        if window > 0:
            valid = valid & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(valid, s, NEG_INF)
        sl = jnp.ceil(s) if intmax else s
        m_new = jnp.maximum(m, jnp.max(sl, axis=-1, keepdims=True))
        alpha = jnp.exp2(m - m_new)
        p = jnp.exp2(s - m_new)
        acc = acc * alpha + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(v_c.dtype), v_c,
            preferred_element_type=jnp.float32)
        d = d * alpha + jnp.sum(p, axis=-1, keepdims=True)
        return (m_new, d, acc), None

    init = (
        jnp.full((B, Hkv, group, Sq, 1), NEG_INF, jnp.float32),
        jnp.zeros((B, Hkv, group, Sq, 1), jnp.float32),
        jnp.zeros((B, Hkv, group, Sq, Dv), jnp.float32),
    )
    (m, d, acc), _ = jax.lax.scan(
        jax.checkpoint(body),
        init, (kc, vc, jnp.arange(n_chunks)))
    o = jnp.where(d > 0, acc / jnp.where(d > 0, d, 1.0), 0.0)
    return o.reshape(B, Hq, Sq, Dv).astype(q.dtype)


def _naive_attention(q, k, v, cfg: ModelConfig, *, causal, window, q_offset):
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    valid = jnp.ones((Sq, Sk), bool)
    if causal:
        valid = valid & (q_pos[:, None] >= k_pos[None, :])
    if window > 0:
        valid = valid & (q_pos[:, None] - k_pos[None, :] < window)
    s = jnp.where(valid, s, NEG_INF)
    p = attention_softmax(s, impl=cfg.softmax_impl, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def attention_apply(
    params,
    x: jax.Array,                # (B, S, d)
    cfg: ModelConfig,
    *,
    positions: jax.Array,        # (B, S) int32
    causal: bool = True,
    window: int = 0,
    x_kv: Optional[jax.Array] = None,        # cross-attention source
    kv_positions: Optional[jax.Array] = None,
    return_kv: bool = False,                 # also return cacheable (k, v)
):
    """Self (or cross) attention for train/prefill."""
    dt = cfg.compute_dtype_
    dh = cfg.head_dim_
    premult, intmax = _mode(cfg)

    if x_kv is None:
        q, k, v = _project_qkv(params, x, cfg, positions)
    else:
        # cross-attention: q from x, k/v from x_kv
        q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bhsk", x_kv, params["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bhsk", x_kv, params["wv"].astype(dt))
        if cfg.qk_norm:
            q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
            k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
        if cfg.rope_theta > 0 and kv_positions is not None:
            q = rope(q, positions[:, None, :], cfg.rope_theta)
            k = rope(k, kv_positions[:, None, :], cfg.rope_theta)
        causal = False

    q = q * jnp.asarray(premult * dh ** -0.5, q.dtype)
    q = shard_act(q, ("batch", "act_heads", "seq", "head_dim"))
    k = shard_act(k, ("batch", "act_heads", "seq", "head_dim"))
    v = shard_act(v, ("batch", "act_heads", "seq", "head_dim"))

    impl = cfg.attention_impl
    if cfg.softmax_impl == "softermax_fixed":
        impl = "naive"  # QAT mode materializes scores (finetuning only)
    if impl == "chunked" and _ring_applicable(cfg, q, k, window, x_kv):
        from repro.parallel.ring_attention import ring_attention
        from repro.parallel.sharding import current_mesh
        o = ring_attention(q, k, v, current_mesh(), causal=causal,
                           intmax=intmax)
    elif impl == "chunked":
        o = chunked_attention(q, k, v, causal=causal, intmax=intmax,
                              window=window, chunk=cfg.attention_chunk)
    elif impl == "flash":
        o = flash_attention_op(q, k, v, causal, intmax, 128, 128,
                               cfg.interpret_kernels)
    elif impl == "naive":
        o = _naive_attention(q, k, v, cfg, causal=causal, window=window,
                             q_offset=0)
    else:
        raise ValueError(impl)
    y = _out_proj(params, o, cfg)
    if return_kv:
        return y, k, v
    return y


# ---------------------------------------------------------------------------
# Decode (single token against a KV cache)
# ---------------------------------------------------------------------------


INT8_KV_MAX = 127.0


def quantize_kv(t: jax.Array):
    """Symmetric int8 per-(…,row) quantization over the last axis.
    t: (..., D) → (int8 values, f32 scales (...,))."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / INT8_KV_MAX
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -INT8_KV_MAX, INT8_KV_MAX).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


def attention_decode(
    params,
    x1: jax.Array,               # (B, d) current-token activations
    cfg: ModelConfig,
    *,
    cache_k: jax.Array,          # (B, Hkv, S, Dh)  (int8 when opt_int8_kv)
    cache_v: jax.Array,
    cache_len: jax.Array,        # (B,) tokens generated so far
    window: int = 0,
    ring: bool = False,          # cache is a ring buffer of size = window
    cache_k_scale: Optional[jax.Array] = None,   # (B, Hkv, S) f32
    cache_v_scale: Optional[jax.Array] = None,
):
    """Returns (y1 (B, d), new_cache_k, new_cache_v[, new scales]).

    ``ring=True`` stores position p at slot ``p % S_cache`` — the sliding
    window lives in a window-sized buffer (hymba long-context decode).
    RoPE is applied before caching, so absolute positions are preserved."""
    dt = cfg.compute_dtype_
    dh = cfg.head_dim_
    premult, intmax = _mode(cfg)
    B = x1.shape[0]

    q = jnp.einsum("bd,dhk->bhk", x1, params["wq"].astype(dt))
    k = jnp.einsum("bd,dhk->bhk", x1, params["wk"].astype(dt))
    v = jnp.einsum("bd,dhk->bhk", x1, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if cfg.rope_theta > 0:
        pos = cache_len[:, None]  # (B, 1): next position
        q = rope(q[:, :, None, :], pos[:, :, None], cfg.rope_theta)[:, :, 0]
        k = rope(k[:, :, None, :], pos[:, :, None], cfg.rope_theta)[:, :, 0]

    # int8 cache: quantize the new row; attention dequantizes on read.
    int8_kv = cache_k_scale is not None
    if int8_kv:
        k, k_sc = quantize_kv(k)        # (B,Hkv,Dh) int8, (B,Hkv)
        v, v_sc = quantize_kv(v)

    # Write new K/V at the current position (ring: slot p % S; linear: p).
    S = cache_k.shape[2]
    if cfg.opt_dus_cache:
        # opt: all sequences share the position (uniform-prefill engine) —
        # dynamic-update-slice touches one (B,Hkv,1,D) row instead of
        # select-rewriting the whole cache.
        pos = jnp.mod(cache_len[0], S) if ring else cache_len[0]
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k[:, :, None, :].astype(cache_k.dtype), (0, 0, pos, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v[:, :, None, :].astype(cache_v.dtype), (0, 0, pos, 0))
        if int8_kv:
            cache_k_scale = jax.lax.dynamic_update_slice(
                cache_k_scale, k_sc[:, :, None], (0, 0, pos))
            cache_v_scale = jax.lax.dynamic_update_slice(
                cache_v_scale, v_sc[:, :, None], (0, 0, pos))
    else:
        slot = jnp.mod(cache_len, S) if ring else cache_len
        onehot = (jnp.arange(S)[None, :] == slot[:, None])  # (B, S)
        sel = onehot[:, None, :, None]
        cache_k = jnp.where(sel, k[:, :, None, :].astype(cache_k.dtype),
                            cache_k)
        cache_v = jnp.where(sel, v[:, :, None, :].astype(cache_v.dtype),
                            cache_v)
        if int8_kv:
            cache_k_scale = jnp.where(onehot[:, None, :], k_sc[:, :, None],
                                      cache_k_scale)
            cache_v_scale = jnp.where(onehot[:, None, :], v_sc[:, :, None],
                                      cache_v_scale)
    new_len = cache_len + 1

    if int8_kv:
        att_k = dequantize_kv(cache_k, cache_k_scale, cfg.compute_dtype_)
        att_v = dequantize_kv(cache_v, cache_v_scale, cfg.compute_dtype_)
    else:
        att_k, att_v = cache_k, cache_v

    q = q * jnp.asarray(premult * dh ** -0.5, q.dtype)
    kj = jnp.arange(S)[None, :]
    if ring:
        # every written slot is live; the buffer size IS the window
        live = kj < jnp.minimum(new_len, S)[:, None]
        o = _masked_decode(q, att_k, att_v, live, intmax)
    elif window > 0 and window < S:
        # sliding window over a linear cache
        start = jnp.maximum(new_len - window, 0)
        live = (kj >= start[:, None]) & (kj < new_len[:, None])
        o = _masked_decode(q, att_k, att_v, live, intmax)
    elif cfg.interpret_kernels and not int8_kv:
        o = flash_decode_op(q, att_k, att_v, new_len, intmax=intmax,
                            interpret=True)
    else:
        live = kj < new_len[:, None]
        o = _masked_decode(q, att_k, att_v, live, intmax)

    y1 = jnp.einsum("bhk,hkd->bd", o, params["wo"].astype(dt))
    if int8_kv:
        return y1, cache_k, cache_v, cache_k_scale, cache_v_scale
    return y1, cache_k, cache_v


def _masked_decode(q, cache_k, cache_v, live, intmax):
    """jnp decode attention: q (B,Hq,D), cache (B,Hkv,S,D), live (B,S)."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = cache_k.shape
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, cache_k,
                   preferred_element_type=jnp.float32)
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    if intmax:
        m = jnp.max(jnp.ceil(s), axis=-1, keepdims=True)
    else:
        m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp2(s - m)
    d = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(d > 0, p / jnp.where(d > 0, d, 1.0), 0.0)
    o = jnp.einsum("bhgk,bhkd->bhgd", p.astype(cache_v.dtype), cache_v)
    return o.reshape(B, Hq, D)
