"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, n_frames, d_model) directly to the encoder.
The backbone is faithful: sinusoidal encoder positions, learned decoder
positions, pre-LN LayerNorm blocks, GELU MLPs, bidirectional encoder
self-attention, causal decoder self-attention + cross-attention. All three
softmax sites run through Softermax.

Decode uses a growing self-attention cache plus per-layer *static* cross
K/V computed once from the encoder output at prefill.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.layers import (cross_entropy_loss, layernorm,
                                 layernorm_schema, logits, mlp, mlp_schema,
                                 sinusoidal_positions)
from repro.models.schema import ParamSpec, stack_schema
from repro.parallel.sharding import shard_act


def _enc_block_schema(cfg: ModelConfig):
    return {
        "ln1": layernorm_schema(cfg.d_model),
        "attn": attn_mod.attention_schema(cfg),
        "ln2": layernorm_schema(cfg.d_model),
        "mlp": mlp_schema(cfg.d_model, cfg.d_ff, gated=False),
    }


def _dec_block_schema(cfg: ModelConfig):
    return {
        "ln1": layernorm_schema(cfg.d_model),
        "self_attn": attn_mod.attention_schema(cfg),
        "ln_x": layernorm_schema(cfg.d_model),
        "cross_attn": attn_mod.attention_schema(cfg),
        "ln2": layernorm_schema(cfg.d_model),
        "mlp": mlp_schema(cfg.d_model, cfg.d_ff, gated=False),
    }


def whisper_schema(cfg: ModelConfig, max_dec_positions: int = 4096):
    return {
        "embed": {
            "embedding": ParamSpec((cfg.padded_vocab, cfg.d_model),
                                   ("vocab", "embed"), init="embed", std=1.0),
            "dec_pos": ParamSpec((max_dec_positions, cfg.d_model),
                                 (None, "embed"), std=0.02),
        },
        "enc_blocks": stack_schema(_enc_block_schema(cfg), cfg.n_enc_layers),
        "enc_norm": layernorm_schema(cfg.d_model),
        "dec_blocks": stack_schema(_dec_block_schema(cfg), cfg.n_layers),
        "dec_norm": layernorm_schema(cfg.d_model),
    }


def whisper_encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, F, d) stub embeddings → encoder output (B, F, d)."""
    B, F, d = frames.shape
    x = frames.astype(cfg.compute_dtype_)
    x = x + sinusoidal_positions(F, d).astype(x.dtype)[None]
    x = shard_act(x, ("batch", "seq", "act_embed"))
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
    ecfg = cfg.replace(rope_theta=0.0)  # positions are additive, not rotary

    def body(x, bp):
        h = layernorm(bp["ln1"], x, cfg.norm_eps)
        x = x + attn_mod.attention_apply(bp["attn"], h, ecfg,
                                         positions=positions, causal=False)
        h2 = layernorm(bp["ln2"], x, cfg.norm_eps)
        x = x + mlp(bp["mlp"], h2, "gelu")
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


def whisper_forward(
    params, frames: jax.Array, tokens: jax.Array, cfg: ModelConfig,
) -> jax.Array:
    """Teacher-forced decoder logits (B, S, V)."""
    B, S = tokens.shape
    enc = whisper_encode(params, frames, cfg)
    F = enc.shape[1]
    x = params["embed"]["embedding"].astype(cfg.compute_dtype_)[tokens]
    x = x + params["embed"]["dec_pos"][:S].astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    enc_positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
    dcfg = cfg.replace(rope_theta=0.0)

    def body(x, bp):
        h = layernorm(bp["ln1"], x, cfg.norm_eps)
        x = x + attn_mod.attention_apply(bp["self_attn"], h, dcfg,
                                         positions=positions, causal=True)
        hx = layernorm(bp["ln_x"], x, cfg.norm_eps)
        x = x + attn_mod.attention_apply(bp["cross_attn"], hx, dcfg,
                                         positions=positions, causal=False,
                                         x_kv=enc,
                                         kv_positions=enc_positions)
        h2 = layernorm(bp["ln2"], x, cfg.norm_eps)
        x = x + mlp(bp["mlp"], h2, "gelu")
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    return logits(params["embed"], x, cfg.replace(tie_embeddings=True))


def whisper_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
                 z_loss: float = 1e-4):
    lg = whisper_forward(params, batch["frames"], batch["tokens"], cfg)
    ce = cross_entropy_loss(lg, batch["labels"], z_loss=z_loss,
                            vocab_size=cfg.vocab_size)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# Serving: cross-KV precomputed once; growing self cache
# ---------------------------------------------------------------------------


def whisper_cache_spec(cfg: ModelConfig, batch: int, max_len: int,
                       n_frames: int):
    dt = cfg.compute_dtype_
    dh = cfg.head_dim_
    L = cfg.n_layers
    kv = (L, batch, cfg.n_kv_heads, max_len, dh)
    xkv = (L, batch, cfg.n_kv_heads, n_frames, dh)
    ax = ("layers", "batch", "kv_heads", "seq", "head_dim")
    return {
        "k": (kv, dt, ax), "v": (kv, dt, ax),
        "xk": (xkv, dt, ax), "xv": (xkv, dt, ax),
        "len": ((batch,), jnp.int32, ("batch",)),
    }


def whisper_prefill(params, frames: jax.Array, cfg: ModelConfig,
                    batch: int, max_len: int):
    """Encode + build the static cross K/V cache (empty self cache)."""
    enc = whisper_encode(params, frames, cfg)
    dt = cfg.compute_dtype_

    def body(_, bp):
        xk = jnp.einsum("bsd,dhk->bhsk", enc, bp["cross_attn"]["wk"].astype(dt))
        xv = jnp.einsum("bsd,dhk->bhsk", enc, bp["cross_attn"]["wv"].astype(dt))
        return None, (xk, xv)

    _, (xk, xv) = jax.lax.scan(body, None, params["dec_blocks"])
    dh = cfg.head_dim_
    cache = {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len, dh), dt),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len, dh), dt),
        "xk": xk, "xv": xv,
        "len": jnp.zeros((batch,), jnp.int32),
    }
    return cache


def whisper_decode_step(params, tokens1: jax.Array, cache, cfg: ModelConfig):
    """One decoder token step. Returns (logits (B,V), cache)."""
    dt = cfg.compute_dtype_
    dh = cfg.head_dim_
    B = tokens1.shape[0]
    cache_len = cache["len"]
    x1 = params["embed"]["embedding"].astype(dt)[tokens1]
    pos_emb = jnp.take(params["embed"]["dec_pos"], cache_len, axis=0)
    x1 = x1 + pos_emb.astype(dt)
    dcfg = cfg.replace(rope_theta=0.0)

    def body(x1, xs):
        bp, k, v, xk, xv = xs
        h = layernorm(bp["ln1"], x1, cfg.norm_eps)
        y, k, v = attn_mod.attention_decode(bp["self_attn"], h, dcfg,
                                            cache_k=k, cache_v=v,
                                            cache_len=cache_len)
        x1 = x1 + y
        hx = layernorm(bp["ln_x"], x1, cfg.norm_eps)
        x1 = x1 + _cross_decode(bp["cross_attn"], hx, xk, xv, dcfg)
        h2 = layernorm(bp["ln2"], x1, cfg.norm_eps)
        x1 = x1 + mlp(bp["mlp"], h2, "gelu")
        return x1, (k, v)

    x1, (k, v) = jax.lax.scan(
        body, x1, (params["dec_blocks"], cache["k"], cache["v"],
                   cache["xk"], cache["xv"]))
    x1 = layernorm(params["dec_norm"], x1, cfg.norm_eps)
    lg = logits(params["embed"], x1[:, None, :],
                cfg.replace(tie_embeddings=True))[:, 0]
    new_cache = {**cache, "k": k, "v": v, "len": cache_len + 1}
    return lg, new_cache


def _cross_decode(ap, x1, xk, xv, cfg: ModelConfig):
    """Single-token cross attention against static encoder K/V."""
    dt = cfg.compute_dtype_
    dh = cfg.head_dim_
    q = jnp.einsum("bd,dhk->bhk", x1, ap["wq"].astype(dt))
    q = q * jnp.asarray(dh ** -0.5, q.dtype)
    from repro.models.attention import _masked_decode, _mode
    premult, intmax = _mode(cfg)
    q = q * jnp.asarray(premult, q.dtype)
    live = jnp.ones((x1.shape[0], xk.shape[2]), bool)
    o = _masked_decode(q, xk, xv, live, intmax)
    return jnp.einsum("bhk,hkd->bd", o, ap["wo"].astype(dt))
