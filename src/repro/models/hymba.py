"""Hymba hybrid mixer: parallel attention heads + Mamba (selective SSM) heads.

Each layer runs a sliding-window GQA attention branch (with Softermax — the
half of the layer where the paper's technique applies) *in parallel* with a
Mamba selective-SSM branch on the same normed input; branch outputs are
RMS-normalized and averaged (Hymba's β-weighted mean, with learnable scales
folded into the branch norms).

Documented simplifications vs the full Hymba recipe (DESIGN.md):
* all attention layers use the sliding window (the 3 full-attention layers
  are windowed too — at the 500k-token cell full attention is the part that
  cannot scale, and Hymba's long-range path is the SSM state);
* meta tokens are stubbed out (the modality/register-token frontend is not
  part of the assigned backbone).

The SSM branch is softmax-free — softermax is inapplicable there by
construction (noted in DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.layers import rmsnorm, rmsnorm_schema
from repro.models.schema import ParamSpec
from repro.parallel.sharding import shard_act


# ---------------------------------------------------------------------------
# Mamba branch (selective SSM, diagonal A)
# ---------------------------------------------------------------------------


def mamba_schema(cfg: ModelConfig):
    d = cfg.d_model
    ssm = cfg.ssm
    di = ssm.d_inner or 2 * d
    st = ssm.state
    dt_rank = max(16, d // 16)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "act_mlp")),
        "conv_w": ParamSpec((ssm.conv_width, di), ("conv", "act_mlp"),
                            std=0.2),
        "conv_b": ParamSpec((di,), ("act_mlp",), init="zeros"),
        "w_bc": ParamSpec((di, 2 * st), ("act_mlp", "state")),
        "dt_a": ParamSpec((di, dt_rank), ("act_mlp", None)),
        "dt_b": ParamSpec((dt_rank, di), (None, "act_mlp")),
        "dt_bias": ParamSpec((di,), ("act_mlp",), init="zeros"),
        "a_log": ParamSpec((di, st), ("act_mlp", "state"), init="zeros"),
        "d_skip": ParamSpec((di,), ("act_mlp",), init="ones"),
        "out_proj": ParamSpec((di, d), ("act_mlp", "embed")),
    }


def _causal_conv(u, w, b, conv_state=None):
    """Depthwise causal conv via shift-adds. u: (B,S,di); w: (cw,di).

    conv_state: (B, cw-1, di) previous raw inputs (decode continuity)."""
    B, S, di = u.shape
    cw = w.shape[0]
    prev = (jnp.zeros((B, cw - 1, di), u.dtype)
            if conv_state is None else conv_state)
    ext = jnp.concatenate([prev, u], axis=1)          # (B, S+cw-1, di)
    out = jnp.zeros_like(u)
    for i in range(cw):
        out = out + ext[:, i:i + S] * w[i]
    new_state = ext[:, -(cw - 1):] if cw > 1 else prev
    return out + b, new_state


def mamba_apply(params, x: jax.Array, cfg: ModelConfig, *,
                ssm_state=None, conv_state=None, return_state=False):
    """x: (B,S,d) → (B,S,d) [+ states]."""
    ssm = cfg.ssm
    dt_ = x.dtype
    B, S, d = x.shape
    di = ssm.d_inner or 2 * d
    st = ssm.state

    uz = x @ params["in_proj"].astype(dt_)
    u, z = uz[..., :di], uz[..., di:]
    u_conv, new_conv = _causal_conv(u, params["conv_w"].astype(dt_),
                                    params["conv_b"].astype(dt_), conv_state)
    u_act = jax.nn.silu(u_conv)
    u_act = shard_act(u_act, ("batch", "seq", "act_mlp"))

    bc = u_act @ params["w_bc"].astype(dt_)
    B_, C_ = bc[..., :st], bc[..., st:]
    dt = jax.nn.softplus(
        (jnp.tanh(u_act @ params["dt_a"].astype(dt_))
         @ params["dt_b"].astype(dt_))
        + params["dt_bias"].astype(dt_)).astype(jnp.float32)  # (B,S,di)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))          # (di,st) < 0

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp          # (B,di),(B,di),(B,st),(B,st)
        decay = jnp.exp(dt_t[..., None] * A[None])             # (B,di,st)
        h = h * decay + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    h0 = (jnp.zeros((B, di, st), jnp.float32)
          if ssm_state is None else ssm_state)
    xs = (jnp.moveaxis(u_act.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B_.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C_.astype(jnp.float32), 1, 0))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(dt_)                     # (B,S,di)
    y = y + u_act * params["d_skip"].astype(dt_)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dt_)
    if return_state:
        return out, h_fin, new_conv
    return out


# ---------------------------------------------------------------------------
# Hymba mixer = parallel(attention, mamba)
# ---------------------------------------------------------------------------


def hymba_mixer_schema(cfg: ModelConfig):
    return {
        "attn": attn_mod.attention_schema(cfg),
        "mamba": mamba_schema(cfg),
        "attn_norm": rmsnorm_schema(cfg.d_model),
        "mamba_norm": rmsnorm_schema(cfg.d_model),
    }


def hymba_mixer_apply(params, x, cfg: ModelConfig, *, positions):
    a = attn_mod.attention_apply(params["attn"], x, cfg, positions=positions,
                                 causal=True, window=cfg.window)
    m = mamba_apply(params["mamba"], x, cfg)
    return 0.5 * (rmsnorm(params["attn_norm"], a, cfg.norm_eps) +
                  rmsnorm(params["mamba_norm"], m, cfg.norm_eps))


def hymba_mixer_decode(params, x1, cfg: ModelConfig, *, cache_k, cache_v,
                       cache_len, ssm_state, conv_state):
    """Single-token hybrid decode. Attention uses a ring-buffer window cache."""
    a1, new_k, new_v = attn_mod.attention_decode(
        params["attn"], x1, cfg, cache_k=cache_k, cache_v=cache_v,
        cache_len=cache_len, window=cfg.window, ring=True)
    m1, new_h, new_conv = mamba_apply(
        params["mamba"], x1[:, None, :], cfg,
        ssm_state=ssm_state, conv_state=conv_state, return_state=True)
    y1 = 0.5 * (rmsnorm(params["attn_norm"], a1, cfg.norm_eps) +
                rmsnorm(params["mamba_norm"], m1[:, 0], cfg.norm_eps))
    return y1, new_k, new_v, new_h, new_conv
