"""Model zoo: one functional definition per family, assembled by lm.py
(decoder-only) and whisper.py (enc-dec); registry.py dispatches by arch id."""
