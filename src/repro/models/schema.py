"""Schema-driven parameters: one definition → init + sharding specs.

Each module defines a nested dict of ``ParamSpec`` (shape, logical axes,
initializer). From that single schema we derive:

* ``init_params``   — materialized (optionally sharded) parameter pytree
* ``logical_specs`` — same-structured tree of logical-axis tuples, consumed
                      by the sharding rules engine to build PartitionSpecs
* ``abstract_params`` — ShapeDtypeStructs for dry-run lowering (no memory)

Layer stacks for ``lax.scan`` are built with ``stack_schema`` which prepends
a "layers" dimension to every leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | embed
    std: Optional[float] = None  # default: 1/sqrt(fan_in = shape[-2])

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


Schema = Dict[str, Any]  # nested dict with ParamSpec leaves


def stack_schema(schema: Schema, n_layers: int) -> Schema:
    """Prepend an (n_layers,) scan dimension to every leaf."""

    def _stack(ps: ParamSpec) -> ParamSpec:
        return ParamSpec((n_layers,) + ps.shape, ("layers",) + ps.logical,
                         ps.init, ps.std)

    return jax.tree_util.tree_map(
        _stack, schema, is_leaf=lambda x: isinstance(x, ParamSpec))


def logical_specs(schema: Schema):
    return jax.tree_util.tree_map(
        lambda ps: ps.logical, schema,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def _init_leaf(key, ps: ParamSpec, dtype) -> jax.Array:
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, dtype)
    if ps.init == "ones":
        return jnp.ones(ps.shape, dtype)
    if ps.init == "embed":
        std = ps.std if ps.std is not None else 1.0
        return (jax.random.normal(key, ps.shape) * std).astype(dtype)
    if ps.init == "normal":
        if ps.std is not None:
            std = ps.std
        else:
            # fan-in = second-to-last dim (or last for 1-D)
            fan_in = ps.shape[-2] if len(ps.shape) >= 2 else ps.shape[-1]
            std = 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, ps.shape) * std).astype(dtype)
    raise ValueError(f"unknown init {ps.init}")


def init_params(key: jax.Array, schema: Schema, dtype=jnp.float32):
    """Initialize a parameter pytree from a schema (deterministic per path)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        schema, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, ps, dtype) for k, ps in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(schema: Schema, dtype=jnp.float32):
    """ShapeDtypeStruct tree (for .lower() without allocating)."""
    return jax.tree_util.tree_map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, dtype), schema,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def num_params(schema: Schema) -> int:
    return int(sum(
        np.prod(ps.shape) for ps in jax.tree_util.tree_leaves(
            schema, is_leaf=lambda x: isinstance(x, ParamSpec))))
