"""RWKV6 "Finch" time-mix / channel-mix (attention-free SSM family).

Softermax applicability note (DESIGN.md §Arch-applicability): RWKV6 contains
**no softmax anywhere** in its token-mixing path — the paper's technique is
inapplicable by construction and this architecture runs without it (the
serve-time logits softmax still uses softermax). It is included in the zoo
per the assignment and exercises the framework's support for recurrent-state
models (O(1) decode state, long_500k shape).

Structure per layer (faithful to Finch, with documented simplifications):

* token shift with data-dependent lerp: five mixing coefficients (r,k,v,w,g),
  each ``mu_i + tanh(xx @ A_i) @ B_i`` (LoRA rank ``mix_lora``).
* WKV6 recurrence per head (state n×n): ``y_t = r_t·(S + u⊙k_t⊗v_t)``,
  ``S ← diag(w_t)·S + k_t⊗v_t`` with data-dependent decay
  ``w_t = exp(-exp(w0 + tanh(z_w @ Aw) @ Bw))``.
* per-head RMS normalization of the output, silu gate, output projection
  (simplification: RMS instead of LayerNorm-with-bias group norm).
* channel mix: static-shift lerp, ``sigmoid(r') * (relu(k')**2 @ Wv')``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.schema import ParamSpec
from repro.parallel.sharding import shard_act

_MIX = 5  # r, k, v, w, g


def rwkv_time_schema(cfg: ModelConfig):
    d = cfg.d_model
    ssm = cfg.ssm
    n = ssm.head_size
    H = d // n
    r = ssm.mix_lora
    rd = ssm.decay_lora
    return {
        "mu": ParamSpec((_MIX, d), (None, "embed"), init="zeros"),
        "mix_a": ParamSpec((_MIX, d, r), (None, "embed", None), std=0.02),
        "mix_b": ParamSpec((_MIX, r, d), (None, None, "embed"), std=0.02),
        "wr": ParamSpec((d, H, n), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, H, n), ("embed", "heads", "head_dim")),
        "wv": ParamSpec((d, H, n), ("embed", "heads", "head_dim")),
        "wg": ParamSpec((d, d), ("embed", "act_embed")),
        "w0": ParamSpec((d,), ("embed",), init="zeros"),
        "decay_a": ParamSpec((d, rd), ("embed", None), std=0.02),
        "decay_b": ParamSpec((rd, d), (None, "embed"), std=0.02),
        "u": ParamSpec((H, n), ("heads", "head_dim"), init="zeros"),
        "out_norm": ParamSpec((H, n), ("heads", "head_dim"), init="ones"),
        "wo": ParamSpec((d, d), ("embed", "act_embed")),
    }


def rwkv_channel_schema(cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamSpec((d,), ("embed",), init="zeros"),
        "mu_r": ParamSpec((d,), ("embed",), init="zeros"),
        "wk": ParamSpec((d, ff), ("embed", "mlp")),
        "wv": ParamSpec((ff, d), ("mlp", "embed")),
        "wr": ParamSpec((d, d), ("embed", "act_embed")),
    }


def _ddlerp(params, x, xx):
    """Data-dependent lerp for the five mix targets. x,xx: (B,S,d)."""
    dt = x.dtype
    mu = params["mu"].astype(dt)                       # (5, d)
    a = params["mix_a"].astype(dt)                     # (5, d, r)
    b = params["mix_b"].astype(dt)                     # (5, r, d)
    lo = jnp.einsum("bsd,mdr->mbsr", xx, a)
    lo = jnp.einsum("mbsr,mrd->mbsd", jnp.tanh(lo), b)
    return x[None] + xx[None] * (mu[:, None, None, :] + lo)  # (5,B,S,d)


def _decay(params, zw):
    """w_t in (0,1): exp(-exp(w0 + tanh(zw@Aw)@Bw))."""
    dt = zw.dtype
    lo = jnp.tanh(zw @ params["decay_a"].astype(dt)) @ params["decay_b"].astype(dt)
    return jnp.exp(-jnp.exp(
        (params["w0"].astype(jnp.float32) + lo.astype(jnp.float32))))


def _head_norm(params, y, eps):
    """Per-head RMS norm. y: (B,S,H,n)."""
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * params["out_norm"].astype(jnp.float32)).astype(y.dtype)


def _wkv_scan(r, k, v, w, u, state0):
    """WKV6 recurrence. r,k,v: (B,S,H,n); w: (B,S,H,n) decays in (0,1);
    u: (H,n); state0: (B,H,n,n). Returns y (B,S,H,n), final state."""

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,n)
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), state


def rwkv_time_apply(
    params, x: jax.Array, cfg: ModelConfig,
    *, shift_state: jax.Array = None, wkv_state: jax.Array = None,
    return_state: bool = False,
):
    """Time mix over a full sequence. x: (B,S,d)."""
    B, S, d = x.shape
    ssm = cfg.ssm
    n = ssm.head_size
    H = d // n
    dt = x.dtype

    prev = jnp.zeros((B, 1, d), dt) if shift_state is None else shift_state[:, None]
    x_prev = jnp.concatenate([prev, x[:, :-1]], axis=1)
    xx = x_prev - x
    zr, zk, zv, zw, zg = _ddlerp(params, x, xx)

    r = jnp.einsum("bsd,dhn->bshn", zr, params["wr"].astype(dt))
    k = jnp.einsum("bsd,dhn->bshn", zk, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhn->bshn", zv, params["wv"].astype(dt))
    g = jax.nn.silu(zg @ params["wg"].astype(dt))
    w = _decay(params, zw).reshape(B, S, H, n).astype(jnp.float32)

    state0 = (jnp.zeros((B, H, n, n), jnp.float32)
              if wkv_state is None else wkv_state)
    y, state = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), w,
                         params["u"].astype(jnp.float32), state0)
    y = _head_norm(params, y.astype(dt), cfg.norm_eps)
    y = shard_act(y, ("batch", "seq", "act_heads", "head_dim"))
    out = (y.reshape(B, S, d) * g) @ params["wo"].astype(dt)
    if return_state:
        return out, x[:, -1], state
    return out


def rwkv_time_decode(params, x1, cfg: ModelConfig, *, shift_state, wkv_state):
    """Single-token time mix. x1: (B,d); states carried."""
    out, new_shift, new_state = rwkv_time_apply(
        params, x1[:, None, :], cfg,
        shift_state=shift_state, wkv_state=wkv_state, return_state=True)
    return out[:, 0], new_shift, new_state


def rwkv_channel_apply(params, x: jax.Array, cfg: ModelConfig,
                       *, shift_state=None, return_state: bool = False):
    B, S, d = x.shape
    dt = x.dtype
    prev = jnp.zeros((B, 1, d), dt) if shift_state is None else shift_state[:, None]
    x_prev = jnp.concatenate([prev, x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * params["mu_k"].astype(dt)
    xr = x + xx * params["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ params["wk"].astype(dt)))
    kk = shard_act(kk, ("batch", "seq", "act_mlp"))
    out = jax.nn.sigmoid(xr @ params["wr"].astype(dt)) * (
        kk @ params["wv"].astype(dt))
    if return_state:
        return out, x[:, -1]
    return out


def rwkv_channel_decode(params, x1, cfg: ModelConfig, *, shift_state):
    out, new_shift = rwkv_channel_apply(
        params, x1[:, None, :], cfg, shift_state=shift_state,
        return_state=True)
    return out[:, 0], new_shift
