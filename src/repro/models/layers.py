"""Shared neural-net layers: norms, MLPs, embeddings, rotary embeddings.

All layers are functional: ``*_schema`` returns ParamSpecs, ``*_apply`` takes
the materialized params. Compute runs in ``cfg.compute_dtype`` (bf16 on TPU)
with fp32 norms/softmax; params stay fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.schema import ParamSpec
from repro.parallel.sharding import shard_act

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_schema(dim: int, logical: str = "embed"):
    return {"scale": ParamSpec((dim,), (logical,), init="ones")}


def rmsnorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_schema(dim: int, logical: str = "embed"):
    return {
        "scale": ParamSpec((dim,), (logical,), init="ones"),
        "bias": ParamSpec((dim,), (logical,), init="zeros"),
    }


def layernorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# MLP (gated / plain) with selectable activation
# ---------------------------------------------------------------------------


def mlp_schema(d_model: int, d_ff: int, gated: bool = True):
    s = {
        "wi": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "wo": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }
    if gated:
        s["wg"] = ParamSpec((d_model, d_ff), ("embed", "mlp"))
    return s


def _activate(h: jax.Array, activation: str) -> jax.Array:
    if activation == "silu":
        return jax.nn.silu(h)
    if activation == "gelu":
        return jax.nn.gelu(h)
    if activation == "relu2":  # nemotron squared-ReLU
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(activation)


def mlp(params, x: jax.Array, activation: str = "silu") -> jax.Array:
    dt = x.dtype
    h = x @ params["wi"].astype(dt)
    h = _activate(h, activation)
    if "wg" in params:
        h = h * (x @ params["wg"].astype(dt))
    # rank 3 = (batch, seq, ff): keep the seq shard under seq-parallel rules
    logical = (("batch", "seq", "act_mlp") if h.ndim == 3 else
               ("batch",) + (None,) * (h.ndim - 2) + ("act_mlp",))
    h = shard_act(h, logical)
    return h @ params["wo"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding + logits head
# ---------------------------------------------------------------------------


def embedding_schema(cfg: ModelConfig):
    s = {"embedding": ParamSpec((cfg.padded_vocab, cfg.d_model),
                                ("vocab", "embed"), init="embed",
                                std=1.0)}
    if not cfg.tie_embeddings:
        s["unembed"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                 ("embed", "vocab"))
    return s


def embed(params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = params["embedding"].astype(cfg.compute_dtype_)[tokens]
    return shard_act(x, ("batch", "seq", "act_embed"))


def logits(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Final projection, fp32 output (softmax/loss numerics)."""
    if cfg.tie_embeddings:
        w = params["embedding"].astype(cfg.compute_dtype_).T
    else:
        w = params["unembed"].astype(cfg.compute_dtype_)
    out = (x @ w).astype(jnp.float32)
    return shard_act(out, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Apply RoPE over the last axis. x: (..., seq, d); positions: (..., seq)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Fixed sinusoidal table (whisper encoder)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy_loss(lg: jax.Array, labels: jax.Array,
                       z_loss: float = 0.0,
                       vocab_size: Optional[int] = None):
    """Token-mean cross entropy with optional z-loss; ignores labels < 0.

    Padded vocab entries are excluded by masking logits above vocab_size.
    """
    if vocab_size is not None and vocab_size < lg.shape[-1]:
        neg = jnp.asarray(-1e9, lg.dtype)
        mask = jnp.arange(lg.shape[-1]) < vocab_size
        lg = jnp.where(mask, lg, neg)
    valid = labels >= 0
    labels_c = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels_c[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * lse ** 2
    nll = jnp.where(valid, nll, 0.0)
    denom = jnp.maximum(valid.sum(), 1)
    return nll.sum() / denom
