"""Architecture registry: ``--arch <id>`` → config, model functions, input specs.

``model_fns(cfg)`` returns a uniform interface over the two model assemblies
(decoder-only ``lm`` and encoder-decoder ``whisper``):

    schema / init / forward / loss / prefill / decode_step / cache_spec

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for every input
of the lowered step — weak-type-correct, shardable, zero allocation — used by
the multi-pod dry-run and the roofline harness.

``reduce_config(cfg)`` derives the CPU smoke-test sibling: same family and
code paths, tiny dimensions.
"""
from __future__ import annotations

import dataclasses
import importlib
from types import SimpleNamespace
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ALL_SHAPES, MLAConfig, ModelConfig, MoEConfig,
                                ShapeConfig, SSMConfig)
from repro.models import lm as lm_mod
from repro.models import whisper as whisper_mod
from repro.models.schema import abstract_params, init_params, logical_specs

ARCH_IDS = (
    "moonshot-v1-16b-a3b",
    "deepseek-v2-236b",
    "qwen3-4b",
    "granite-3-8b",
    "nemotron-4-15b",
    "llama3.2-3b",
    "hymba-1.5b",
    "whisper-base",
    "rwkv6-7b",
    "pixtral-12b",
    "bert-base",
    "bert-large",
)

_MODULES = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen3-4b": "qwen3_4b",
    "granite-3-8b": "granite_3_8b",
    "nemotron-4-15b": "nemotron_4_15b",
    "llama3.2-3b": "llama3_2_3b",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-base": "whisper_base",
    "rwkv6-7b": "rwkv6_7b",
    "pixtral-12b": "pixtral_12b",
    "bert-base": "bert_base",
    "bert-large": "bert_large",
}

# The 10 assigned archs forming the 40-cell grid (bert_* are paper-eval only).
GRID_ARCHS = ARCH_IDS[:10]

# long_500k runs only for sub-quadratic context archs; decode shapes are
# skipped for encoder-only archs (none assigned — whisper has a decoder).
SUBQUADRATIC = {"hymba-1.5b", "rwkv6-7b"}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def cell_supported(arch: str, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the documented skip reason."""
    if shape.name == "long_500k" and arch not in SUBQUADRATIC:
        return ("full-attention arch: 524k-token dense decode is the "
                "regime DESIGN.md documents as skipped (sub-quadratic only)")
    return None


# ---------------------------------------------------------------------------
# Reduced (smoke-test) configs
# ---------------------------------------------------------------------------


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny sibling of the same family for CPU smoke tests."""
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        vocab_pad_to=64,
        attention_chunk=32,
        compute_dtype="float32",
        remat="none",
    )
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, enc_positions=16)
    if cfg.window:
        kw.update(window=16)
    if cfg.moe.n_experts:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=2, d_expert=32,
            d_shared=32 if cfg.moe.n_shared else 0,
            d_ff_dense=64 if cfg.moe.first_dense else 0)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora=32 if cfg.mla.q_lora else 0, kv_lora=24,
                              qk_nope=16, qk_rope=8, v_head=16)
        kw["head_dim"] = 0
    if cfg.family == "hybrid":
        kw["ssm"] = SSMConfig(state=8, d_inner=128, conv_width=4)
    if cfg.family == "rwkv":
        kw["ssm"] = SSMConfig(head_size=16, decay_lora=8, mix_lora=8)
        kw.update(n_heads=4, n_kv_heads=4)
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# Uniform model interface
# ---------------------------------------------------------------------------


def model_fns(cfg: ModelConfig) -> SimpleNamespace:
    if cfg.family == "encdec":
        max_dec = 33024  # covers decode_32k + train_4k decoder positions
        schema = whisper_mod.whisper_schema(cfg, max_dec_positions=max_dec)
        return SimpleNamespace(
            schema=schema,
            specs=logical_specs(schema),
            init=lambda key: init_params(key, schema, cfg.param_dtype_),
            abstract=lambda: abstract_params(schema, cfg.param_dtype_),
            forward=lambda p, batch: whisper_mod.whisper_forward(
                p, batch["frames"], batch["tokens"], cfg),
            loss=lambda p, batch: whisper_mod.whisper_loss(p, batch, cfg),
            prefill=lambda p, batch, max_len: (
                None,
                whisper_mod.whisper_prefill(
                    p, batch["frames"], cfg,
                    batch["frames"].shape[0], max_len)),
            decode_step=lambda p, tok1, cache: whisper_mod.whisper_decode_step(
                p, tok1, cache, cfg),
            cache_spec=lambda batch, max_len: whisper_mod.whisper_cache_spec(
                cfg, batch, max_len, cfg.enc_positions),
        )
    schema = lm_mod.lm_schema(cfg)
    return SimpleNamespace(
        schema=schema,
        specs=logical_specs(schema),
        init=lambda key: init_params(key, schema, cfg.param_dtype_),
        abstract=lambda: abstract_params(schema, cfg.param_dtype_),
        forward=lambda p, batch: lm_mod.lm_forward(p, batch["tokens"], cfg)[0],
        loss=lambda p, batch: lm_mod.lm_loss(p, batch, cfg),
        prefill=lambda p, batch, max_len: lm_mod.lm_prefill(
            p, batch["tokens"], cfg, max_len),
        decode_step=lambda p, tok1, cache: lm_mod.lm_decode_step(
            p, tok1, cache, cfg),
        cache_spec=lambda batch, max_len: lm_mod.cache_spec(
            cfg, batch, max_len),
    )


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, object]:
    """Abstract inputs for the step lowered by this (arch, shape) cell.

    train/prefill: token batches (whisper adds stub frame embeddings).
    decode: one token per sequence + the cache tree at seq_len.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(shape_):
        return jax.ShapeDtypeStruct(shape_, i32)

    if cfg.family == "encdec":
        frames = jax.ShapeDtypeStruct(
            (B, cfg.enc_positions, cfg.d_model), jnp.float32)
        if shape.kind == "train":
            return {"frames": frames, "tokens": tok((B, S)),
                    "labels": tok((B, S))}
        if shape.kind == "prefill":
            return {"frames": frames, "tokens": tok((B, S))}
        fns = model_fns(cfg)
        cache = {k: jax.ShapeDtypeStruct(sh, dt)
                 for k, (sh, dt, _) in fns.cache_spec(B, S).items()}
        return {"tokens1": tok((B,)), "cache": cache}

    if shape.kind == "train":
        return {"tokens": tok((B, S)), "labels": tok((B, S))}
    if shape.kind == "prefill":
        return {"tokens": tok((B, S))}
    # decode: cache of seq_len, one new token
    cache = {k: jax.ShapeDtypeStruct(sh, dt)
             for k, (sh, dt, _) in lm_mod.cache_spec(cfg, B, S).items()}
    return {"tokens1": tok((B,)), "cache": cache}
