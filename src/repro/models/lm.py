"""Decoder-only LM assembly, generic over all assigned families.

One definition serves dense (qwen3/granite/nemotron/llama3.2/pixtral),
MoE (moonshot), MoE+MLA (deepseek-v2), RWKV6 and Hymba — the per-layer mixer
and FFN are selected by ``cfg``, and layers are stacked with ``lax.scan``
(compile-time: one layer body regardless of depth; remat policy wraps the
body).

Entry points:
  * ``lm_forward``      — full-sequence logits (+ MoE aux loss): train_4k /
                          prefill lowering target.
  * ``lm_loss``         — next-token CE + z-loss + aux.
  * ``init_cache``      — decode-state tree (zeros or ShapeDtypeStructs).
  * ``lm_prefill``      — forward + cache construction for serving.
  * ``lm_decode_step``  — one token in, one token's logits out, cache updated.

Cache trees per family (all leading-dim L for scan):
  attention:  {k,v: (L,B,Hkv,S,Dh)}          + shared "len" (B,)
  mla:        {ckv: (L,B,S,kv_lora), krope: (L,B,S,qk_rope)}
  rwkv:       {shift_t, shift_c: (L,B,d), wkv: (L,B,H,n,n)}
  hybrid:     {k,v: (L,B,Hkv,W,Dh) ring, ssm: (L,B,di,st), conv: (L,B,cw-1,di)}
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import hymba as hymba_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import (cross_entropy_loss, embed, embedding_schema,
                                 logits, mlp, mlp_schema, rmsnorm,
                                 rmsnorm_schema)
from repro.models.schema import ParamSpec, stack_schema
from repro.parallel.sharding import shard_act

# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------


def _mixer_schema(cfg: ModelConfig):
    if cfg.family == "rwkv":
        return rwkv_mod.rwkv_time_schema(cfg)
    if cfg.family == "hybrid":
        return hymba_mod.hymba_mixer_schema(cfg)
    if cfg.mla is not None:
        return mla_mod.mla_schema(cfg)
    return attn_mod.attention_schema(cfg)


def _ffn_schema(cfg: ModelConfig, dense: bool = False):
    if cfg.family == "rwkv":
        return rwkv_mod.rwkv_channel_schema(cfg)
    if cfg.family == "moe" and not dense:
        return moe_mod.moe_schema(cfg)
    d_ff = cfg.moe.d_ff_dense if (dense and cfg.moe.d_ff_dense) else cfg.d_ff
    gated = cfg.activation != "relu2"
    return mlp_schema(cfg.d_model, d_ff, gated=gated)


def block_schema(cfg: ModelConfig, dense_ffn: bool = False):
    return {
        "ln1": rmsnorm_schema(cfg.d_model),
        "mixer": _mixer_schema(cfg),
        "ln2": rmsnorm_schema(cfg.d_model),
        "ffn": _ffn_schema(cfg, dense=dense_ffn),
    }


def lm_schema(cfg: ModelConfig):
    n_head = cfg.moe.first_dense if cfg.family == "moe" else 0
    s: Dict[str, Any] = {
        "embed": embedding_schema(cfg),
        "final_norm": rmsnorm_schema(cfg.d_model),
        "blocks": stack_schema(block_schema(cfg), cfg.n_layers - n_head),
    }
    if n_head:
        s["head_blocks"] = stack_schema(block_schema(cfg, dense_ffn=True),
                                        n_head)
    return s


# ---------------------------------------------------------------------------
# Forward (train / prefill lowering)
# ---------------------------------------------------------------------------


def maybe_cast_params(params, cfg: ModelConfig):
    """opt_bf16_params: cast matrix params to compute dtype ONCE, before the
    layer scan — FSDP weight all-gathers and grad reduce-scatters then move
    bf16 instead of f32 (halves those collective bytes). 1-D params (norms)
    stay f32; the optimizer still holds the f32 master copy."""
    if not cfg.opt_bf16_params:
        return params
    dt = cfg.compute_dtype_
    return jax.tree_util.tree_map(
        lambda a: a.astype(dt)
        if (hasattr(a, "ndim") and a.ndim >= 2 and
            jnp.issubdtype(a.dtype, jnp.floating)) else a,
        params)


def _block_apply(bp, x, cfg: ModelConfig, positions, dense_ffn: bool):
    """One layer. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
    if cfg.family == "rwkv":
        x = x + rwkv_mod.rwkv_time_apply(bp["mixer"], h, cfg)
        h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        x = x + rwkv_mod.rwkv_channel_apply(bp["ffn"], h2, cfg)
        return x, aux
    if cfg.family == "hybrid":
        mix = hymba_mod.hymba_mixer_apply(bp["mixer"], h, cfg,
                                          positions=positions)
    elif cfg.mla is not None:
        mix = mla_mod.mla_apply(bp["mixer"], h, cfg, positions=positions,
                                window=cfg.window)
    else:
        mix = attn_mod.attention_apply(bp["mixer"], h, cfg,
                                       positions=positions,
                                       causal=cfg.causal, window=cfg.window)
    x = x + mix
    h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe" and not dense_ffn:
        f, aux = moe_mod.moe_apply(bp["ffn"], h2, cfg)
    else:
        f = mlp(bp["ffn"], h2, cfg.activation)
    return x + f, aux


def _scan_blocks(blocks, x, cfg: ModelConfig, positions, dense_ffn=False):
    def body(carry, bp):
        x, aux = carry
        x, a = _block_apply(bp, x, cfg, positions, dense_ffn)
        x = shard_act(x, ("batch", "seq", "act_embed"))
        return (x, aux + a), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux


def lm_forward(
    params,
    tokens: jax.Array,            # (B, S) int32
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array] = None,
    prefix_embeds: Optional[jax.Array] = None,   # (B, P, d) vlm stub
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V_padded) fp32, aux_loss scalar)."""
    B, S = tokens.shape
    params = maybe_cast_params(params, cfg)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = embed(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x[:, P:]], axis=1)
    aux = jnp.zeros((), jnp.float32)
    if "head_blocks" in params:
        x, a = _scan_blocks(params["head_blocks"], x, cfg, positions,
                            dense_ffn=True)
        aux = aux + a
    x, a = _scan_blocks(params["blocks"], x, cfg, positions)
    aux = aux + a
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits(params["embed"], x, cfg), aux


def lm_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            z_loss: float = 1e-4) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    lg, aux = lm_forward(params, batch["tokens"], cfg)
    ce = cross_entropy_loss(lg, batch["labels"], z_loss=z_loss,
                            vocab_size=cfg.vocab_size)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """Shapes/dtypes + logical axes of the decode cache. Returns
    {name: (shape, dtype, logical_axes)} with the layer dim first."""
    L = cfg.n_layers - (cfg.moe.first_dense if cfg.family == "moe" else 0)
    Lh = cfg.n_layers - L
    dt = cfg.compute_dtype_
    d = cfg.d_model

    # opt_cache_seq_shard: the cache sequence dim gets its own logical axis
    # mapped to "model" — kv_heads (often 8 < 16) can't use the model axis,
    # so without this the cache is REPLICATED model-axis-wide. Sharding seq
    # turns decode attention into a distributed online softmax: each model
    # rank reduces its seq shard, cross-shard combine is the softermax
    # power-of-two renormalization.
    seq_ax = "kv_seq" if cfg.opt_cache_seq_shard else "seq"

    def attn_entries(n_layers, S):
        dh = cfg.head_dim_
        sh = (n_layers, batch, cfg.n_kv_heads, S, dh)
        ax = ("layers", "batch", "kv_heads", seq_ax, "head_dim")
        kv_dt = jnp.int8 if (cfg.opt_int8_kv and cfg.family != "hybrid") \
            else dt
        ent = {"k": (sh, kv_dt, ax), "v": (sh, kv_dt, ax)}
        if kv_dt == jnp.int8:
            ssh = (n_layers, batch, cfg.n_kv_heads, S)
            sax = ("layers", "batch", "kv_heads", seq_ax)
            ent["k_scale"] = (ssh, jnp.float32, sax)
            ent["v_scale"] = (ssh, jnp.float32, sax)
        return ent

    out: Dict[str, Any] = {}
    if cfg.family == "rwkv":
        ssm = cfg.ssm
        H = d // ssm.head_size
        n = ssm.head_size
        out["shift_t"] = ((L, batch, d), dt, ("layers", "batch", "act_embed"))
        out["shift_c"] = ((L, batch, d), dt, ("layers", "batch", "act_embed"))
        out["wkv"] = ((L, batch, H, n, n), jnp.float32,
                      ("layers", "batch", "heads", "head_dim", None))
    elif cfg.family == "hybrid":
        ssm = cfg.ssm
        di = ssm.d_inner or 2 * d
        W = min(cfg.window or max_len, max_len)
        out.update(attn_entries(L, W))
        out["ssm"] = ((L, batch, di, ssm.state), jnp.float32,
                      ("layers", "batch", "act_mlp", "state"))
        out["conv"] = ((L, batch, ssm.conv_width - 1, di), dt,
                       ("layers", "batch", None, "act_mlp"))
    elif cfg.mla is not None:
        a = cfg.mla
        out["ckv"] = ((L, batch, max_len, a.kv_lora), dt,
                      ("layers", "batch", seq_ax, "kv_lora"))
        out["krope"] = ((L, batch, max_len, a.qk_rope), dt,
                        ("layers", "batch", seq_ax, None))
        if Lh:
            out["head_ckv"] = ((Lh, batch, max_len, a.kv_lora), dt,
                               ("layers", "batch", seq_ax, "kv_lora"))
            out["head_krope"] = ((Lh, batch, max_len, a.qk_rope), dt,
                                 ("layers", "batch", seq_ax, None))
    else:
        out.update(attn_entries(L, max_len))
    out["len"] = ((batch,), jnp.int32, ("batch",))
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return {name: jnp.zeros(sh, dtype)
            for name, (sh, dtype, _) in cache_spec(cfg, batch, max_len).items()}


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def _mixer_decode(bp, x1, cfg: ModelConfig, layer_cache, cache_len):
    """One layer's mixer on one token. Returns (y1, new_layer_cache)."""
    if cfg.family == "rwkv":
        y1, shift, wkv = rwkv_mod.rwkv_time_decode(
            bp["mixer"], x1, cfg, shift_state=layer_cache["shift_t"],
            wkv_state=layer_cache["wkv"])
        return y1, {**layer_cache, "shift_t": shift, "wkv": wkv}
    if cfg.family == "hybrid":
        y1, k, v, h, conv = hymba_mod.hymba_mixer_decode(
            bp["mixer"], x1, cfg, cache_k=layer_cache["k"],
            cache_v=layer_cache["v"], cache_len=cache_len,
            ssm_state=layer_cache["ssm"], conv_state=layer_cache["conv"])
        return y1, {"k": k, "v": v, "ssm": h, "conv": conv}
    if cfg.mla is not None:
        y1, ckv, krope = mla_mod.mla_decode(
            bp["mixer"], x1, cfg, cache_ckv=layer_cache["ckv"],
            cache_krope=layer_cache["krope"], cache_len=cache_len)
        return y1, {"ckv": ckv, "krope": krope}
    if "k_scale" in layer_cache:
        y1, k, v, ks, vs = attn_mod.attention_decode(
            bp["mixer"], x1, cfg, cache_k=layer_cache["k"],
            cache_v=layer_cache["v"], cache_len=cache_len,
            window=cfg.window, cache_k_scale=layer_cache["k_scale"],
            cache_v_scale=layer_cache["v_scale"])
        return y1, {"k": k, "v": v, "k_scale": ks, "v_scale": vs}
    y1, k, v = attn_mod.attention_decode(
        bp["mixer"], x1, cfg, cache_k=layer_cache["k"],
        cache_v=layer_cache["v"], cache_len=cache_len, window=cfg.window)
    return y1, {"k": k, "v": v}


def _ffn_decode(bp, x1, cfg: ModelConfig, layer_cache, dense_ffn):
    if cfg.family == "rwkv":
        y1, shift = rwkv_mod.rwkv_channel_decode(
            bp["ffn"], x1, cfg, shift_state=layer_cache["shift_c"])
        return y1, {**layer_cache, "shift_c": shift}
    if cfg.family == "moe" and not dense_ffn:
        y, _ = moe_mod.moe_apply(bp["ffn"], x1[:, None, :], cfg)
        return y[:, 0], layer_cache
    return mlp(bp["ffn"], x1, cfg.activation), layer_cache


def _block_decode(bp, x1, cfg, layer_cache, cache_len, dense_ffn=False):
    h = rmsnorm(bp["ln1"], x1, cfg.norm_eps)
    y, layer_cache = _mixer_decode(bp, h, cfg, layer_cache, cache_len)
    x1 = x1 + y
    h2 = rmsnorm(bp["ln2"], x1, cfg.norm_eps)
    f, layer_cache = _ffn_decode(bp, h2, cfg, layer_cache, dense_ffn)
    return x1 + f, layer_cache


_HEAD_KEYS = {"head_ckv": "ckv", "head_krope": "krope"}


def _split_cache(cache):
    body = {k: v for k, v in cache.items()
            if k != "len" and not k.startswith("head_")}
    head = {tgt: cache[src] for src, tgt in _HEAD_KEYS.items()
            if src in cache}
    return body, head


def lm_decode_step(
    params,
    tokens1: jax.Array,          # (B,) current token ids
    cache: Dict[str, jax.Array],
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step: logits for the next token + updated cache."""
    params = maybe_cast_params(params, cfg)
    cache_len = cache["len"]
    table = params["embed"]["embedding"].astype(cfg.compute_dtype_)
    if cfg.opt_onehot_embed and tokens1.shape[0] >= 8:
        # one-hot matmul consumes the vocab-sharded table in place (the
        # contraction is over the sharded vocab dim → tiny (B,d) psum)
        # instead of replicating the table for a row gather. At tiny batch
        # the full-table read costs more than the gather — gated on B.
        oh = jax.nn.one_hot(tokens1, table.shape[0], dtype=table.dtype)
        x1 = oh @ table
    else:
        x1 = table[tokens1]
    x1 = shard_act(x1, ("batch", "act_embed"))

    body_cache, head_cache = _split_cache(cache)
    new_cache: Dict[str, jax.Array] = {}

    if "head_blocks" in params:
        def head_body(x1, xs):
            bp, lc = xs
            x1, lc = _block_decode(bp, x1, cfg, lc, cache_len, dense_ffn=True)
            return x1, lc
        x1, new_head = jax.lax.scan(head_body, x1,
                                    (params["head_blocks"], head_cache))
        for src, tgt in _HEAD_KEYS.items():
            if tgt in new_head:
                new_cache[src] = new_head[tgt]

    def body(x1, xs):
        bp, lc = xs
        x1, lc = _block_decode(bp, x1, cfg, lc, cache_len)
        return x1, lc

    x1, new_body = jax.lax.scan(body, x1, (params["blocks"], body_cache))
    new_cache.update(new_body)
    new_cache["len"] = cache_len + 1

    x1 = rmsnorm(params["final_norm"], x1, cfg.norm_eps)
    lg = logits(params["embed"], x1[:, None, :], cfg)[:, 0]
    return lg, new_cache


# ---------------------------------------------------------------------------
# Prefill (forward + cache construction); assumes full-length prompts
# ---------------------------------------------------------------------------


def _ring_place(k_seq: jax.Array, W: int):
    """Place the last ≤W positions of (B,H,S,D) into a ring buffer (B,H,W,D)."""
    S = k_seq.shape[2]
    slots = jnp.arange(W)
    p = S - 1 - jnp.mod(S - 1 - slots, W)          # source pos per slot
    valid = p >= 0
    gathered = jnp.take(k_seq, jnp.clip(p, 0, S - 1), axis=2)
    return jnp.where(valid[None, None, :, None], gathered, 0)


def lm_prefill(
    params,
    tokens: jax.Array,           # (B, S) full prompts
    cfg: ModelConfig,
    max_len: int,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (last-token logits (B,V), cache ready for decode)."""
    B, S = tokens.shape
    params = maybe_cast_params(params, cfg)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = embed(params["embed"], tokens, cfg)

    def pad_to(c, target_len):
        pad = target_len - c.shape[2]
        if pad <= 0:
            return c[:, :, :target_len]
        return jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0)))

    def layer_fwd(x, bp, dense_ffn):
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        entries = {}
        if cfg.family == "rwkv":
            y, shift, wkv = rwkv_mod.rwkv_time_apply(
                bp["mixer"], h, cfg, return_state=True)
            entries.update(shift_t=shift, wkv=wkv)
        elif cfg.family == "hybrid":
            a, k, v = attn_mod.attention_apply(
                bp["mixer"]["attn"], h, cfg, positions=positions,
                causal=True, window=cfg.window, return_kv=True)
            m, ssm_h, conv = hymba_mod.mamba_apply(
                bp["mixer"]["mamba"], h, cfg, return_state=True)
            y = 0.5 * (rmsnorm(bp["mixer"]["attn_norm"], a, cfg.norm_eps) +
                       rmsnorm(bp["mixer"]["mamba_norm"], m, cfg.norm_eps))
            W = min(cfg.window or max_len, max_len)
            entries.update(k=_ring_place(k, W), v=_ring_place(v, W),
                           ssm=ssm_h, conv=conv)
        elif cfg.mla is not None:
            y, ckv, krope = mla_mod.mla_apply(
                bp["mixer"], h, cfg, positions=positions, window=cfg.window,
                return_cache=True)
            entries.update(ckv=_pad_seq(ckv, max_len),
                           krope=_pad_seq(krope, max_len))
        else:
            y, k, v = attn_mod.attention_apply(
                bp["mixer"], h, cfg, positions=positions, causal=cfg.causal,
                window=cfg.window, return_kv=True)
            if cfg.opt_int8_kv:
                kq, ks = attn_mod.quantize_kv(pad_to(k, max_len))
                vq, vs = attn_mod.quantize_kv(pad_to(v, max_len))
                entries.update(k=kq, v=vq, k_scale=ks, v_scale=vs)
            else:
                entries.update(k=pad_to(k, max_len), v=pad_to(v, max_len))
        x = x + y
        h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        if cfg.family == "rwkv":
            f, shift_c = rwkv_mod.rwkv_channel_apply(
                bp["ffn"], h2, cfg, return_state=True)
            entries["shift_c"] = shift_c
        elif cfg.family == "moe" and not dense_ffn:
            f, _ = moe_mod.moe_apply(bp["ffn"], h2, cfg)
        else:
            f = mlp(bp["ffn"], h2, cfg.activation)
        return x + f, entries

    cache: Dict[str, jax.Array] = {}
    if "head_blocks" in params:
        def hbody(x, bp):
            x, e = layer_fwd(x, bp, dense_ffn=True)
            return x, e
        x, head_entries = jax.lax.scan(hbody, x, params["head_blocks"])
        for src, tgt in _HEAD_KEYS.items():
            if tgt in head_entries:
                cache[src] = head_entries[tgt]

    def bbody(x, bp):
        x, e = layer_fwd(x, bp, dense_ffn=False)
        return x, e

    x, entries = jax.lax.scan(bbody, x, params["blocks"])
    cache.update(entries)
    cache["len"] = jnp.full((B,), S, jnp.int32)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    lg = logits(params["embed"], x[:, -1:, :], cfg)[:, 0]
    return lg, cache


def _pad_seq(c: jax.Array, target_len: int) -> jax.Array:
    """Pad (B, S, D) to (B, target_len, D)."""
    pad = target_len - c.shape[1]
    if pad <= 0:
        return c[:, :target_len]
    return jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
