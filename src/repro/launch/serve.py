"""Production serving launcher: batched generation with softermax decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.registry import (GRID_ARCHS, get_config, model_fns,
                                   reduce_config)
from repro.parallel.sharding import SERVE_RULES, sharding_context
from repro.serve import ServeEngine
from repro.utils.logging import get_logger

log = get_logger("launch.serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(GRID_ARCHS), default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--optimized", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if args.optimized:
        cfg = cfg.with_opts(True)

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    with sharding_context(mesh, SERVE_RULES):
        fns = model_fns(cfg)
        params = fns.init(jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, params,
                          max_len=args.prompt_len + args.max_new)
        rng = np.random.default_rng(0)
        prompts = rng.integers(1, cfg.vocab_size,
                               (args.batch, args.prompt_len)).astype(np.int32)
        t0 = time.time()
        res = eng.generate(prompts, args.max_new,
                           temperature=args.temperature)
        dt = time.time() - t0
    toks = args.batch * args.max_new
    log.info("%s: %d tokens in %.2fs (%.1f tok/s incl. compile)",
             cfg.name, toks, dt, toks / dt)
    for i, row in enumerate(res.tokens[:2]):
        log.info("seq%d: %s", i, row.tolist())


if __name__ == "__main__":
    main()
