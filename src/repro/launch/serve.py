"""Production serving launcher: static-slot or continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --engine paged --batch 8 --prompt-len 32 --max-new 16 \
        --block-size 16 --num-blocks 128

Observability (paged engine): ``--metrics-out metrics.prom`` (or
``.jsonl``) exports the metric registry, ``--trace-out trace.json`` writes
the Perfetto-loadable step timeline, ``--numerics-every N`` turns on the
int8 numerics monitor; any of these implies ``--telemetry``. See
serve/README.md "Observability" for the metric glossary.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.registry import (GRID_ARCHS, get_config, model_fns,
                                   reduce_config)
from repro.parallel.sharding import SERVE_RULES, sharding_context
from repro.serve import ContinuousEngine, ServeEngine
from repro.utils.logging import get_logger

log = get_logger("launch.serve")


def _serve_fleet(args, cfg, params, prompts, t0):
    """Serve the workload through a FleetSupervisor over N replicas:
    prefix-affinity (or round-robin) placement, step-watchdog
    supervision, journaled failover, fleet-aggregated metrics."""
    from repro.serve import (EngineGuard, FaultInjector, FaultPlan,
                             FleetSupervisor, Journal, Router, Telemetry,
                             canned_fleet_plan)
    want_tel = bool(args.telemetry or args.metrics_out)

    def engine_factory():
        eng = ContinuousEngine(
            cfg, params, block_size=args.block_size,
            num_blocks=args.num_blocks, max_batch=args.batch,
            max_len=args.prompt_len + args.max_new,
            prefix_cache=args.prefix_cache,
            evict_policy=args.evict_policy,
            prefill_chunk=args.prefill_chunk,
            prefill_budget=args.prefill_budget,
            kv_dtype=None if args.kv_dtype == "auto" else args.kv_dtype,
            kv_tile_blocks=args.kv_tile_blocks,
            decode_split_k=args.decode_split_k,
            telemetry=Telemetry() if want_tel else None,
            guard=EngineGuard() if args.guard else None)
        eng.warmup()
        return eng

    faults = None
    if args.fleet_fault_plan:
        plan = (canned_fleet_plan() if args.fleet_fault_plan == "canned"
                else FaultPlan.load(args.fleet_fault_plan))
        faults = FaultInjector(plan)
        log.info("fleet fault injector attached: %d specs, seed %d",
                 len(plan.specs), plan.seed)
    journal = Journal(path=args.journal_out, fsync=args.journal_fsync)
    if args.resume:
        # crash recovery: snapshot warm-restore per replica, then adopt
        # every journaled request (terminal ones resolve immediately;
        # in-flight ones resubmit via the recompute contract)
        sup = FleetSupervisor.resume(
            engine_factory, args.replicas, args.resume,
            snapshot_dir=args.snapshot_dir, journal=journal,
            router=Router(args.router), faults=faults,
            step_parallel=True, snapshot_every=args.snapshot_every)
        for info in sup.restore_info:
            log.info("replica %d restore: %s (%s)", info["replica"],
                     info["mode"], info["reason"])
        log.info("resume: %d requests adopted (%d already terminal), "
                 "%d torn-tail records lost",
                 int(sup.tracker.c_recovered.value),
                 sum(1 for t in sup.tracker.requests.values()
                     if t.result is not None),
                 int(sup.tracker.c_tail_lost.value))
    else:
        engines = [engine_factory() for _ in range(args.replicas)]
        sup = FleetSupervisor(engines, router=Router(args.router),
                              journal=journal, faults=faults,
                              step_parallel=True,
                              snapshot_dir=args.snapshot_dir,
                              snapshot_every=args.snapshot_every)
    treqs = [sup.submit(p, args.max_new, temperature=args.temperature,
                        deadline_s=args.deadline_ms / 1e3 or None,
                        ttft_budget_s=args.ttft_budget_ms / 1e3 or None)
             for p in prompts]
    sup.run_until_drained()
    dt = time.time() - t0
    tr = sup.tracker
    log.info("fleet[%dx %s, %s router]: %d completed, %d failed, "
             "%d failovers, %d placement retries in %d ticks",
             args.replicas, cfg.name, args.router,
             int(tr.c_completed.value), int(tr.c_failed.value),
             int(tr.c_failovers.value), int(tr.c_retries.value), sup.ticks)
    log.info("fleet health: crashed=%d hung=%d alive=%d",
             int(sup.c_crashed.value), int(sup.c_hung.value),
             int(sup.g_alive.value))
    for name, h in (("ttft", tr.h_ttft), ("e2e", tr.h_e2e)):
        if h.count:
            log.info("fleet %s: p50 %.1fms p99 %.1fms (n=%d)", name,
                     h.quantile(0.5) * 1e3, h.quantile(0.99) * 1e3,
                     h.count)
    events = journal.replay().replica_events
    if events:
        log.info("fleet replica events: %s",
                 [(e["event"], e["replica"], e["tick"]) for e in events])
    if args.metrics_out:
        agg = sup.collect_metrics()
        with open(args.metrics_out, "w") as f:
            f.write(agg.prometheus_text())
        log.info("fleet-aggregated metrics -> %s", args.metrics_out)
    if args.snapshot_dir:
        # final snapshot at quiescence: the next process warm-restarts
        # with the full radix tree even after a clean shutdown
        sup.save_snapshots()
        log.info("durable snapshots (%d written this run) -> %s",
                 int(sup.c_snapshots.value), args.snapshot_dir)
    if args.journal_out:
        log.info("write-ahead journal (%d records, fsync=%s) -> %s",
                 len(journal.records), args.journal_fsync,
                 args.journal_out)
    sup.close()
    rows = [list(t.result.tokens) for t in treqs]
    return rows, dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(GRID_ARCHS), default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--optimized", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--engine", choices=("static", "paged"),
                    default="static")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged engine: tokens per physical KV block")
    ap.add_argument("--num-blocks", type=int, default=128,
                    help="paged engine: physical blocks in the pool")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="paged engine: radix-tree prompt-prefix reuse on "
                         "the block pool (--no-prefix-cache disables)")
    ap.add_argument("--evict-policy", choices=("lru", "fifo"), default="lru",
                    help="prefix cache: order in which unreferenced cached "
                         "blocks are reclaimed")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="paged engine: prefill long prompts this many "
                         "tokens per step through the flash-prefill kernel "
                         "(rounded up to a block multiple; chunks "
                         "interleave with decode steps so long prompts "
                         "don't stall running requests; 0 = one-shot "
                         "prefill)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="paged engine: cap the TOTAL prefill chunk tokens "
                         "dealt per step across all requests (the oldest "
                         "prefilling request always advances), so many "
                         "concurrent long prompts can't starve decodes; "
                         "0 = one chunk per prefilling request per step")
    ap.add_argument("--kv-tile-blocks", type=int, default=1,
                    help="paged engine: pool blocks gathered per kv grid "
                         "step of the paged Pallas kernels (raise until "
                         "kv_tile_blocks * block_size >= 128 so decode "
                         "streams MXU-shaped KV tiles; layout-only — same "
                         "attention, same visit order, identical outputs)")
    ap.add_argument("--decode-split-k", type=int, default=1,
                    help="paged engine: partition each decode lane's KV "
                         "walk across this many parallel grid lanes, "
                         "merged by the associative Softermax combine — "
                         "cuts a long-context request's decode latency by "
                         "~the split factor on TPU (same attention; the "
                         "rescales are exact power-of-two shifts, the "
                         "partition sums reassociate within fp rounding — "
                         "a greedy flip needs an exact logit tie)")
    ap.add_argument("--autotune", choices=("off", "static", "per-step"),
                    default="off",
                    help="paged engine: grid autotuning from the analytic "
                         "kernel cost model (serve/kernel_costs.py). "
                         "'static' picks one (kv_tile_blocks, split_k) at "
                         "startup by modeled cost on the worst-case batch; "
                         "'per-step' re-plans every decode step from the "
                         "batch's lengths vector over the warmed-up "
                         "candidate grids (never compiles mid-serve). "
                         "--kv-tile-blocks/--decode-split-k bound the "
                         "candidate set; decisions are exported as "
                         "autotune_* metrics when --telemetry is on")
    ap.add_argument("--kv-dtype", choices=("auto", "bf16", "int8"),
                    default="auto",
                    help="paged engine KV pool storage: 'auto' follows "
                         "the config (int8 when --optimized sets "
                         "opt_int8_kv, compute dtype otherwise); 'int8' "
                         "stores K/V as int8 with per-row scales — half "
                         "the gather bytes, ~2x tokens at equal HBM — "
                         "dequantized inside the paged kernels")
    ap.add_argument("--telemetry", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="paged engine: per-request tracing + metric "
                         "registry + step timeline (serve/telemetry.py). "
                         "Defaults on when --metrics-out/--trace-out is "
                         "given, off otherwise (disabled hooks are free)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the final metric-registry state here: "
                         "*.jsonl appends one snapshot line (JSONL sink), "
                         "anything else gets Prometheus text exposition")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the step timeline as Chrome trace-event "
                         "JSON (load in chrome://tracing or Perfetto)")
    ap.add_argument("--numerics-every", type=int, default=0, metavar="N",
                    help="telemetry: audit every Nth prefill on an int8 "
                         "pool — lockstep full-precision vs int8 forward "
                         "publishing the live logit-error gauge plus "
                         "IntMax-overflow / scale-saturation counters "
                         "(0 = off)")
    ap.add_argument("--fault-plan", default=None, metavar="PATH|canned",
                    help="paged engine: attach the fault injector "
                         "(serve/faults.py) with this plan — a FaultPlan "
                         "JSON file, or the literal 'canned' for the "
                         "reference chaos plan. Attached after warmup so "
                         "the plan's step indices address serving steps")
    ap.add_argument("--fault-log", default=None, metavar="PATH",
                    help="write the fault-injection replay artifact "
                         "(plan + every injection that fired) here")
    ap.add_argument("--guard", action="store_true",
                    help="paged engine: enable the graceful-degradation "
                         "ladder (serve/guard.py) — sheds admissions, "
                         "shrinks prefill budgets, and quarantines "
                         "corrupted-KV requests as pool/numerics/queue "
                         "pressure crosses thresholds; recovers "
                         "automatically")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="paged engine: per-request end-to-end deadline; "
                         "overdue requests are cancelled (reason "
                         "'deadline'). 0 = no deadline")
    ap.add_argument("--ttft-budget-ms", type=float, default=0.0,
                    help="paged engine: per-request time-to-first-token "
                         "budget; requests that miss it are cancelled "
                         "(reason 'deadline'). 0 = no budget")
    ap.add_argument("--replicas", type=int, default=1,
                    help="paged engine: serve through a FleetSupervisor "
                         "over this many engine replicas (serve/"
                         "supervisor.py) — prefix-affinity routing, "
                         "step-watchdog supervision, journaled failover. "
                         "1 = the plain single-engine path")
    ap.add_argument("--router", choices=("affinity", "round-robin"),
                    default="affinity",
                    help="fleet placement policy: radix-cache prefix "
                         "affinity (load/budget fallback) or round-robin")
    ap.add_argument("--journal-out", default=None, metavar="PATH",
                    help="fleet: write the write-ahead request journal "
                         "(JSONL; serve/journal.py) — submit/placement/"
                         "token/terminal records, replayable post-mortem")
    ap.add_argument("--fleet-fault-plan", default=None,
                    metavar="PATH|canned",
                    help="fleet: attach the fleet fault injector — a "
                         "FaultPlan JSON file, or the literal 'canned' "
                         "for the reference replica-crash + hang plan "
                         "(serve/faults.py canned_fleet_plan)")
    ap.add_argument("--journal-fsync", choices=("none", "interval",
                                                "always"),
                    default="interval",
                    help="journal durability policy: 'always' fsyncs "
                         "every record (no tail loss, slowest), "
                         "'interval' flushes per record and fsyncs "
                         "periodically (default; bounded tail-loss "
                         "window), 'none' leaves records in stdio "
                         "buffers (fastest; a crash loses everything "
                         "unflushed). Dropped-tail records surface as "
                         "journal_tail_lost_total at recovery")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="fleet durability: write crash-consistent "
                         "per-replica snapshots (serve/snapshot.py — "
                         "KV pools, radix tree, scheduler queues, engine "
                         "counters; atomic tmp+rename, per-section "
                         "checksums) into this directory, plus one at "
                         "clean drain. Implies the fleet path even with "
                         "--replicas 1")
    ap.add_argument("--snapshot-every", type=int, default=0, metavar="N",
                    help="fleet durability: snapshot every N supervision "
                         "ticks (0 = only the final snapshot at drain); "
                         "each snapshot also anchors the journal so "
                         "replay cost is bounded by the suffix")
    ap.add_argument("--resume", default=None, metavar="JOURNAL",
                    help="crash recovery: rebuild the fleet from this "
                         "prior write-ahead journal (+ --snapshot-dir "
                         "snapshots when available — warm radix/pool "
                         "restore with fsck fallback to cold), adopt "
                         "every journaled request (terminal streams "
                         "resolve from the journal; in-flight ones "
                         "resubmit via the [prompt ‖ emitted] recompute "
                         "contract), then serve the new workload")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if args.optimized:
        cfg = cfg.with_opts(True)

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    with sharding_context(mesh, SERVE_RULES):
        fns = model_fns(cfg)
        params = fns.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = rng.integers(1, cfg.vocab_size,
                               (args.batch, args.prompt_len)).astype(np.int32)
        t0 = time.time()
        if args.engine == "paged" and (args.replicas > 1 or
                                       args.snapshot_dir or args.resume):
            rows, dt = _serve_fleet(args, cfg, params, prompts, t0)
        elif args.engine == "paged":
            want_tel = args.telemetry if args.telemetry is not None else \
                bool(args.metrics_out or args.trace_out
                     or args.numerics_every)
            tel = None
            if want_tel:
                from repro.serve import Telemetry
                tel = Telemetry(numerics_every=args.numerics_every)
            guard = None
            if args.guard:
                from repro.serve import EngineGuard
                guard = EngineGuard()
            eng = ContinuousEngine(
                cfg, params, block_size=args.block_size,
                num_blocks=args.num_blocks, max_batch=args.batch,
                max_len=args.prompt_len + args.max_new,
                prefix_cache=args.prefix_cache,
                evict_policy=args.evict_policy,
                prefill_chunk=args.prefill_chunk,
                prefill_budget=args.prefill_budget,
                kv_dtype=None if args.kv_dtype == "auto" else args.kv_dtype,
                kv_tile_blocks=args.kv_tile_blocks,
                decode_split_k=args.decode_split_k,
                autotune=args.autotune,
                telemetry=tel, guard=guard,
                deadline_s=args.deadline_ms / 1e3 or None,
                ttft_budget_s=args.ttft_budget_ms / 1e3 or None)
            inj = None
            if args.fault_plan:
                from repro.serve import FaultInjector, FaultPlan, canned_plan
                plan = (canned_plan() if args.fault_plan == "canned"
                        else FaultPlan.load(args.fault_plan))
                inj = FaultInjector(plan)
                # after construction, before traffic: warmup() resets the
                # injector anyway, and no synthetic warmup runs here, so
                # plan step indices address serving steps directly
                eng.attach_faults(inj)
                log.info("fault injector attached: %d specs, seed %d",
                         len(plan.specs), plan.seed)
            from repro.serve import EngineSheddingError
            handles = []
            for p in prompts:
                try:
                    handles.append(eng.submit(p, args.max_new,
                                              temperature=args.temperature))
                except EngineSheddingError as e:
                    # the guard refused the front door; its hint is the
                    # minimum clean steps before a retry can succeed
                    log.warning("submit shed by guard (%s): retry after "
                                ">= %d clean engine steps", e,
                                e.retry_after_steps)
            results = eng.run()
            dt = time.time() - t0
            rows = [results[h.req_id].tokens for h in handles
                    if h.req_id in results]
            m = eng.metrics
            if inj is not None or guard is not None or args.deadline_ms \
                    or args.ttft_budget_ms:
                log.info("resilience: %d faults injected, %d retries, "
                         "%d cancelled (%d deadline, %d quarantined), "
                         "%d shed, guard=%s",
                         m.faults_injected, m.transient_retries,
                         m.cancelled, m.deadline_misses, m.quarantined,
                         m.shed,
                         eng.guard.state if eng.guard else "off")
            if inj is not None and args.fault_log:
                inj.save_log(args.fault_log)
                log.info("fault replay artifact -> %s", args.fault_log)
            log.info("kv pool[%s]: %d-token capacity in %.2f MiB "
                     "(%d blocks x %d)", eng.pool.kv_dtype,
                     eng.pool.token_capacity,
                     eng.pool.hbm_bytes / 2 ** 20, args.num_blocks,
                     args.block_size)
            log.info("pool peak=%d blocks (%.0f%% of %d), preemptions=%d",
                     eng.metrics.peak_blocks,
                     100.0 * eng.metrics.peak_blocks / args.num_blocks,
                     args.num_blocks, eng.metrics.preemptions)
            if args.prefill_chunk:
                log.info("chunked prefill[%d]: %d chunks over %d prefills "
                         "(%d prompt tokens computed)",
                         eng.prefill_chunk, eng.metrics.prefill_chunks,
                         eng.metrics.prefills, eng.metrics.prefill_tokens)
            if eng.prefix_cache is not None:
                cs = eng.prefix_cache.stats
                log.info("prefix cache[%s]: hit %d/%d prompt tokens "
                         "(%.0f%%), %d shared-block peak, %d COW, "
                         "%d evictions, prefill savings %.2fx",
                         args.evict_policy, cs.hit_tokens, cs.lookup_tokens,
                         100.0 * cs.hit_rate,
                         eng.metrics.shared_blocks_peak,
                         eng.metrics.cow_copies, cs.evictions,
                         eng.metrics.prefill_savings)
            if tel is not None:
                for nm in ("ttft", "tpot", "e2e"):
                    q = tel.quantiles(nm)
                    log.info("%s: p50 %.1fms p90 %.1fms p99 %.1fms "
                             "(n=%d)", nm, q["p50"] * 1e3, q["p90"] * 1e3,
                             q["p99"] * 1e3, q["count"])
                err = tel.registry.get("numerics_logit_error_max")
                if err is not None:
                    log.info("numerics: max |full - int8| logit delta "
                             "%.4f over %d probes", err.value,
                             tel.c_probes.value)
                kd = tel.registry.get("kernel_dma_bytes_total")
                if kd is not None and kd.value > 0:
                    kw = tel.registry.get("kernel_waste_bytes_total")
                    kf = tel.registry.get("kernel_flops_total")
                    log.info("kernel cost: %.2f MiB gather DMA "
                             "(%.0f%% clamped waste), %.2f MFLOP",
                             kd.value / 2 ** 20,
                             100.0 * kw.value / kd.value,
                             kf.value / 1e6)
                if eng.planner is not None:
                    log.info("autotune[%s]: grid=(tile=%d, split=%d), "
                             "decisions %s", args.autotune,
                             eng.kv_tile_blocks, eng.decode_split_k,
                             eng.planner.summary() or "(static)")
                if args.metrics_out:
                    tel.save_metrics(args.metrics_out,
                                     extra={"arch": cfg.name,
                                            "engine": "paged"})
                    log.info("metrics -> %s", args.metrics_out)
                else:
                    # no sink requested: the run's metrics still surface —
                    # final Prometheus exposition straight to stdout
                    print("# final metric registry (Prometheus text "
                          "exposition; pass --metrics-out to write a file)")
                    print(tel.registry.prometheus_text(), end="")
                if args.trace_out:
                    tel.save_chrome_trace(args.trace_out,
                                          meta={"arch": cfg.name})
                    log.info("step timeline -> %s", args.trace_out)
        else:
            eng = ServeEngine(cfg, params,
                              max_len=args.prompt_len + args.max_new)
            res = eng.generate(prompts, args.max_new,
                               temperature=args.temperature)
            dt = time.time() - t0
            rows = [r.tolist() for r in res.tokens]
    toks = args.batch * args.max_new
    log.info("%s[%s]: %d tokens in %.2fs (%.1f tok/s incl. compile)",
             cfg.name, args.engine, toks, dt, toks / dt)
    for i, row in enumerate(rows[:2]):
        log.info("seq%d: %s", i, row)


if __name__ == "__main__":
    main()
