"""Production training launcher.

Composes: mesh (trivial on a dev box, production 16×16 / 2×16×16 with real
devices), sharding rules, sharded param init, fault-tolerant loop
(checkpoint/restart, straggler monitor). On this CPU container run it with a
reduced config:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 30 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import TrainConfig
from repro.data import SyntheticLMData
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.registry import (GRID_ARCHS, get_config, model_fns,
                                   reduce_config)
from repro.optim import adamw
from repro.parallel.sharding import (DEFAULT_RULES, logical_to_physical,
                                     sharding_context)
from repro.train import make_train_step, train
from repro.utils.logging import get_logger

log = get_logger("launch.train")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(GRID_ARCHS), default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU dev box)")
    ap.add_argument("--optimized", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use make_production_mesh (needs ≥256 devices)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if args.optimized:
        cfg = cfg.with_opts(True)

    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())
    log.info("mesh: %s", dict(mesh.shape))

    fns = model_fns(cfg)
    with sharding_context(mesh, DEFAULT_RULES):
        params = fns.init(jax.random.PRNGKey(0))
        from jax.sharding import NamedSharding
        sh = jax.tree_util.tree_map(
            lambda spec, a: NamedSharding(mesh, logical_to_physical(
                spec, a.shape, DEFAULT_RULES, mesh)),
            fns.specs, params,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        params = jax.device_put(params, sh)

        tc = TrainConfig(total_steps=args.steps,
                         warmup_steps=max(args.steps // 10, 1),
                         learning_rate=args.lr,
                         microbatches=args.microbatches,
                         checkpoint_every=max(args.steps // 3, 1))
        data = SyntheticLMData(cfg.vocab_size, args.seq, args.batch, seed=0)
        step = jax.jit(make_train_step(fns.loss, tc))
        out = train(train_step=step, params=params, data=data, tc=tc,
                    ckpt_dir=args.ckpt_dir,
                    log_every=max(args.steps // 20, 1))
    h = out["history"]
    log.info("done: loss %.4f -> %.4f; stragglers flagged: %d",
             h[0], h[-1], out["straggler_flags"])


if __name__ == "__main__":
    main()
