"""Production mesh factories.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — device count is locked on first jax init, and the
smoke tests must keep seeing 1 CPU device while the dry-run sees 512.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Trivial mesh over the actually-present devices (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
