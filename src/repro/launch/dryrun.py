import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). 512 placeholder host devices let jax.make_mesh build
# the production meshes; nothing is allocated — every input is a
# ShapeDtypeStruct and the deliverable is .lower().compile().

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input-shape × mesh) cell:

  1. build the production mesh — (16,16)=(data,model) single-pod or
     (2,16,16)=(pod,data,model) multi-pod;
  2. construct abstract params / optimizer / batch / cache with their
     NamedShardings from the logical-axis rules engine;
  3. ``jax.jit(step).lower(...).compile()`` — sharding mismatches, OOM at
     compile, or unsupported collectives fail here;
  4. record memory_analysis + cost_analysis + parsed collective bytes into
     ``artifacts/dryrun/<mesh>/<arch>__<shape>.json`` for §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--subprocess]
"""

import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import (ALL_SHAPES, ModelConfig, ShapeConfig,
                                TrainConfig)
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.models.registry import (GRID_ARCHS, cell_supported, get_config,
                                   input_specs, model_fns)
from repro.optim import adamw
from repro.parallel.sharding import (DEFAULT_RULES, LONG_CONTEXT_RULES,
                                     SEQ_PARALLEL_RULES, logical_to_physical,
                                     sharding_context)
from repro.train.step import make_train_step
from repro.utils.logging import get_logger

log = get_logger("dryrun")

SHAPES: Dict[str, ShapeConfig] = {s.name: s for s in ALL_SHAPES}


def _sharding_tree(specs_tree, abstract_tree, rules, mesh):
    """logical-axes tree + ShapeDtypeStruct tree -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda spec, a: NamedSharding(
            mesh, logical_to_physical(spec, a.shape, rules, mesh)),
        specs_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def _cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` returns a dict on recent jax but a
    one-element list of dicts on older versions — normalize to a dict."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _memory_analysis_dict(compiled) -> Dict[str, Optional[float]]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if not out and ma is not None:
        out["repr"] = str(ma)[:2000]
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               cfg_override=None, rules_override=None):
    """Build + lower + compile one cell. Returns (compiled, report dict)."""
    cfg: ModelConfig = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    # batch=1 long-context cells shard the sequence/cache over "data" instead
    if rules_override is not None:
        rules = rules_override
    elif shape.global_batch < 8:
        rules = LONG_CONTEXT_RULES
    elif (cfg.opt_seq_parallel and shape.kind in ("train", "prefill")
          and shape.seq_len % mesh.shape["model"] == 0
          # recurrent time-mixers (rwkv/mamba) and the enc-dec (frames not
          # seq-divisible) need full sequences locally — SP regresses them
          # (measured: hymba train 0.64x); attention families only.
          and cfg.family in ("dense", "moe")):
        rules = SEQ_PARALLEL_RULES
    elif cfg.opt_serve_resident and shape.kind == "decode":
        from repro.parallel.sharding import SERVE_RULES
        rules = SERVE_RULES
    else:
        rules = DEFAULT_RULES

    fns = model_fns(cfg)
    with sharding_context(mesh, rules):
        abs_params = fns.abstract()
        param_sh = _sharding_tree(fns.specs, abs_params, rules, mesh)
        specs = input_specs(cfg, shape)

        def batch_sharding(tree):
            def logical_for(a):
                if len(a.shape) >= 2:
                    return (("batch", "seq")
                            + (None,) * (len(a.shape) - 2))
                return ("batch",) + (None,) * (len(a.shape) - 1)
            return jax.tree_util.tree_map(
                lambda a: NamedSharding(mesh, logical_to_physical(
                    logical_for(a), a.shape, rules, mesh)), tree)

        t0 = time.time()
        if shape.kind == "train":
            tc = TrainConfig(microbatches=1)
            step = make_train_step(fns.loss, tc)
            abs_opt = adamw.AdamWState(
                m=abs_params, v=abs_params,
                step=jax.ShapeDtypeStruct((), jnp.int32))
            opt_sh = adamw.AdamWState(
                m=param_sh, v=param_sh,
                step=NamedSharding(mesh, PartitionSpec()))
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sharding(specs)),
            ).lower(abs_params, abs_opt, specs)
        elif shape.kind == "prefill":
            max_len = shape.seq_len
            lowered = jax.jit(
                lambda p, b: fns.prefill(p, b, max_len),
                in_shardings=(param_sh, batch_sharding(specs)),
            ).lower(abs_params, specs)
        else:  # decode
            if cfg.opt_bf16_params:
                # serving holds params pre-cast (the engine casts once);
                # lower with bf16 matrix params so the in-step cast is an
                # identity — not a per-token full-model copy.
                dt16 = cfg.compute_dtype_
                abs_params = jax.tree_util.tree_map(
                    lambda a: jax.ShapeDtypeStruct(
                        a.shape, dt16 if len(a.shape) >= 2 else a.dtype),
                    abs_params)
            cache_sp = fns.cache_spec(shape.global_batch, shape.seq_len)
            cache_abs = {k: jax.ShapeDtypeStruct(sh, dt)
                         for k, (sh, dt, _) in cache_sp.items()}
            cache_sh = {k: NamedSharding(mesh, logical_to_physical(
                ax, sh, rules, mesh))
                for k, (sh, dt, ax) in cache_sp.items()}
            tok_sh = batch_sharding(specs["tokens1"])
            lowered = jax.jit(
                fns.decode_step,
                in_shardings=(param_sh, tok_sh, cache_sh),
            ).lower(abs_params, specs["tokens1"], cache_abs)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    from repro.launch.analytic import analytic_flops
    roof = rf.from_compiled(compiled, chips, rf.model_flops(cfg, shape),
                            analytic_flops=analytic_flops(cfg, shape))
    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": _memory_analysis_dict(compiled),
        "cost_analysis": {k: float(v) for k, v in
                          _cost_analysis_dict(compiled).items()
                          if isinstance(v, (int, float))},
        "roofline": roof.to_dict(),
    }
    return compiled, report


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             optimized: bool = False) -> Dict:
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    skip = cell_supported(arch, SHAPES[shape_name])
    path = os.path.join(out_dir, mesh_tag)
    os.makedirs(path, exist_ok=True)
    fname = os.path.join(path, f"{arch}__{shape_name}.json")
    if skip:
        report = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                  "skipped": skip}
        with open(fname, "w") as f:
            json.dump(report, f, indent=1)
        log.info("SKIP %s %s: %s", arch, shape_name, skip)
        return report
    log.info("lowering %s × %s on %s%s ...", arch, shape_name, mesh_tag,
             " [optimized]" if optimized else "")
    cfg = get_config(arch).with_opts(True) if optimized else None
    compiled, report = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                  cfg_override=cfg)
    report["optimized"] = optimized
    print(f"--- {arch} × {shape_name} × {mesh_tag} ---")
    print("memory_analysis:", report["memory_analysis"])
    print("cost_analysis:", {k: v for k, v in report["cost_analysis"].items()
                             if k in ("flops", "bytes accessed")})
    print("roofline:", {k: report["roofline"][k] for k in
                        ("compute_s", "memory_s", "collective_s",
                         "dominant", "roofline_fraction")})
    with open(fname, "w") as f:
        json.dump(report, f, indent=1)
    del compiled
    return report


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(GRID_ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell for the chosen mesh")
    ap.add_argument("--subprocess", action="store_true",
                    help="isolate each cell in a child process")
    ap.add_argument("--optimized", action="store_true",
                    help="enable the beyond-paper perf optimizations "
                         "(cfg.with_opts); default is the §Roofline baseline")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in GRID_ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        if args.subprocess:
            import subprocess
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", args.out]
            if args.multi_pod:
                cmd.append("--multi-pod")
            if args.optimized:
                cmd.append("--optimized")
            r = subprocess.run(cmd, env={**os.environ})
            if r.returncode != 0:
                failures.append((arch, shape))
            continue
        try:
            run_cell(arch, shape, args.multi_pod, args.out,
                     optimized=args.optimized)
        except Exception:
            traceback.print_exc()
            failures.append((arch, shape))
    if failures:
        log.error("FAILED cells: %s", failures)
        return 1
    log.info("all %d cells OK", len(cells))
    return 0


if __name__ == "__main__":
    sys.exit(main())
