"""First-principles FLOP counts per (arch × shape) cell.

Why this exists: XLA's CPU ``cost_analysis`` has two systematic artifacts on
our graphs — (a) FLOPs inside *nested* while loops (chunked-attention scan
inside the layer scan) are not multiplied by the inner trip count, and
(b) "bytes accessed" charges the full while-loop carry (the stacked KV
cache) once per iteration, which a real TPU does not pay. The collective
parser (launch/roofline.py) is trip-count-aware and unaffected.

So the roofline's *compute* term uses these analytic FLOPs (exact for the
model definitions in this repo — formulas below mirror models/*.py
structurally), while HLO flops/bytes are retained in the artifacts as a
cross-check. See EXPERIMENTS.md §Roofline "methodology".

Conventions: 1 MAC = 2 FLOPs; backward = 2× forward (train = 3× fwd);
causal attention halves the score/AV work; remat recompute is NOT counted
(roofline counts useful work).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig


def _attn_flops_per_layer(cfg: ModelConfig, B: int, Sq: int, Sk: int,
                          causal: bool) -> float:
    """Score (QK^T) + weighted sum (AV) FLOPs for one layer, forward."""
    if cfg.family == "rwkv":
        return 0.0
    if cfg.mla is not None:
        a = cfg.mla
        if cfg.opt_mla_absorbed and Sq > 1:
            # latent-space attention: scores over kv_lora+rope, AV over
            # kv_lora (cheaper wire/memory, more score FLOPs)
            dqk, dv = a.kv_lora + a.qk_rope, a.kv_lora
        else:
            dqk, dv = a.qk_nope + a.qk_rope, a.v_head
        pairs = B * cfg.n_heads * Sq * Sk * (0.5 if causal and Sq == Sk else 1)
        return 2 * pairs * (dqk + dv)
    H, dh = cfg.n_heads, cfg.head_dim_
    eff_k = min(Sk, cfg.window) if cfg.window else Sk
    if causal and Sq == Sk:
        pairs = B * H * Sq * eff_k * (0.5 if not cfg.window else 1.0)
        if cfg.window and Sq > cfg.window:
            pairs = B * H * Sq * cfg.window  # banded
        elif cfg.window:
            pairs = B * H * Sq * eff_k * 0.5
    else:
        pairs = B * H * Sq * eff_k
    return 2 * pairs * 2 * dh


def _recurrence_flops_per_layer(cfg: ModelConfig, B: int, S: int) -> float:
    """State-update FLOPs (RWKV wkv / Mamba SSM scan), forward."""
    if cfg.family == "rwkv":
        n = cfg.ssm.head_size
        H = cfg.d_model // n
        return 5.0 * B * S * H * n * n
    if cfg.family == "hybrid":
        di = cfg.ssm.d_inner or 2 * cfg.d_model
        return 6.0 * B * S * di * cfg.ssm.state
    return 0.0


def matmul_param_count(cfg: ModelConfig) -> int:
    """Parameters that multiply every token (active experts for MoE;
    embedding lookup excluded; logits matmul included once)."""
    from repro.models.registry import model_fns
    from repro.models.schema import ParamSpec
    import jax
    import numpy as np

    fns = model_fns(cfg)
    leaves = jax.tree_util.tree_flatten_with_path(
        fns.schema, is_leaf=lambda x: isinstance(x, ParamSpec))[0]
    total = 0
    for path, ps in leaves:
        keys = [str(getattr(p, "key", p)) for p in path]
        name = "/".join(keys)
        if len(ps.shape) < 2:
            continue
        if "embedding" in name or "dec_pos" in name:
            continue
        total += int(np.prod(ps.shape))
    # logits projection: tied embeddings reuse the table as a matmul
    if cfg.tie_embeddings:
        total += cfg.padded_vocab * cfg.d_model
    if cfg.moe.n_experts:
        m = cfg.moe
        L = cfg.n_layers - m.first_dense
        per_expert = 3 * cfg.d_model * m.d_expert
        total -= L * m.n_experts * per_expert
        total += L * m.top_k * per_expert
    return total


def analytic_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Total useful FLOPs (global, per step) for this cell."""
    B, S = shape.global_batch, shape.seq_len
    n_mm = matmul_param_count(cfg)
    mult = 3.0 if shape.kind == "train" else 1.0

    if shape.kind == "decode":
        tokens = B
        mm = 2.0 * n_mm * tokens
        if cfg.family == "encdec":
            attn = cfg.n_layers * (
                _attn_flops_per_layer(cfg, B, 1, S, False) +
                _attn_flops_per_layer(cfg, B, 1, cfg.enc_positions, False))
        else:
            attn = cfg.n_layers * _attn_flops_per_layer(cfg, B, 1, S, False)
        rec = cfg.n_layers * _recurrence_flops_per_layer(cfg, B, 1)
        return mm + attn + rec

    tokens = B * S
    mm = 2.0 * n_mm * tokens
    if cfg.family == "encdec":
        F = cfg.enc_positions
        attn = (cfg.n_enc_layers * _attn_flops_per_layer(cfg, B, F, F, False)
                + cfg.n_layers * (_attn_flops_per_layer(cfg, B, S, S, True)
                                  + _attn_flops_per_layer(cfg, B, S, F,
                                                          False)))
        # encoder matmuls already inside n_mm·tokens is approximate for
        # enc-dec (enc runs F tokens, dec S tokens); correct the ratio:
        mm = 2.0 * n_mm * tokens  # dominated by decoder at S >> F
    else:
        attn = cfg.n_layers * _attn_flops_per_layer(cfg, B, S, S, cfg.causal)
    rec = cfg.n_layers * _recurrence_flops_per_layer(cfg, B, S)
    return (mm + attn + rec) * mult
