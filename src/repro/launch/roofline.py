"""Roofline term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs_per_chip / peak_FLOPs          (197 TF/s bf16)
    memory     = HLO_bytes_per_chip / HBM_bw              (819 GB/s)
    collective = collective_bytes_per_chip / link_bw      (~50 GB/s/link ICI)

``compiled.cost_analysis()`` yields per-chip FLOPs/bytes (the post-SPMD
module is the per-device program). Collective bytes are NOT in
cost_analysis: we parse the optimized HLO and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

MODEL_FLOPS uses 6·N·D (train) or 2·N·D (inference), with N = *active*
params for MoE; the ratio MODEL_FLOPS / (chips · HLO_FLOPs_per_chip)
exposes remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e-class)
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# "%name = <output types> <kind>(" — operands are %refs in optimized HLO, so
# sizes come from the OUTPUT shape(s) + the replica group size.
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*)?\{\s*$")
_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_REF_RE = re.compile(r"body=%?([\w\.\-]+)")
_BRANCH_REF_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CALLS_REF_RE = re.compile(r"\bcalls=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _out_bytes(shape_str: str) -> int:
    return sum(shape_bytes(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(shape_str))


def _wire_bytes(kind: str, out_b: int, group: int) -> float:
    """Ring-model bytes on the wire per chip for one execution."""
    n = max(group, 2)
    if kind == "all-gather":
        return out_b * (n - 1) / n
    if kind == "all-reduce":
        return out_b * 2 * (n - 1) / n
    if kind == "reduce-scatter":
        return out_b * (n - 1)          # out is the scattered shard
    if kind == "all-to-all":
        return out_b * (n - 1) / n
    return float(out_b)                  # collective-permute


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip collective wire bytes by kind, from optimized HLO text.

    Computation-graph aware: collectives inside ``while`` bodies (lax.scan
    over layers / KV chunks) are multiplied by the loop's known_trip_count;
    conditional branches and async wrapper computations count once.
    """
    # 1. split into computations
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        h = _HEADER_RE.match(line.strip()) if line and not line.startswith(" ") else None
        if h and line.rstrip().endswith("{"):
            cur = h.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)

    own: Dict[str, Dict[str, float]] = {}
    children: Dict[str, list] = {}
    for name, lines in comps.items():
        acc = {k: 0.0 for k in _COLLECTIVES}
        kids = []
        for line in lines:
            m = _COLL_RE.search(line)
            if m and m.group(3) != "-done":   # count start, skip done
                out_b = _out_bytes(m.group(1))
                g = _GROUP_RE.search(line)
                group = int(g.group(2)) if g else 2
                acc[m.group(2)] += _wire_bytes(m.group(2), out_b, group)
            if _WHILE_RE.search(line):
                b = _BODY_REF_RE.search(line)
                t = _TRIP_RE.search(line)
                trip = int(t.group(1)) if t else 1
                if b:
                    kids.append((b.group(1), trip))
            for br in _BRANCH_REF_RE.finditer(line):
                for ref in br.group(1).split(","):
                    kids.append((ref.strip().lstrip("%"), 1))
            c = _CALLS_REF_RE.search(line)
            if c:
                kids.append((c.group(1), 1))
        own[name] = acc
        children[name] = kids

    memo: Dict[str, Dict[str, float]] = {}

    def total(name: str, stack=()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name not in own or name in stack:
            return {k: 0.0 for k in _COLLECTIVES}
        acc = dict(own[name])
        for kid, mult in children[name]:
            sub = total(kid, stack + (name,))
            for k in _COLLECTIVES:
                acc[k] += sub[k] * mult
        memo[name] = acc
        return acc

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            h = _HEADER_RE.match(line.strip())
            if h:
                entry = h.group(1)
            break
    if entry is None or entry not in own:
        # fall back: sum everything once
        out = {k: 0.0 for k in _COLLECTIVES}
        for acc in own.values():
            for k in _COLLECTIVES:
                out[k] += acc[k]
        return out
    return total(entry)


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float         # HLO cost_analysis (cross-check only)
    bytes_per_chip: float
    collective_per_chip: float
    chips: int
    model_flops: float            # global useful FLOPs (6ND / 2ND)
    collective_breakdown: Dict[str, float]
    analytic_flops: float = 0.0   # launch/analytic.py first-principles count

    @property
    def compute_s(self) -> float:
        """Analytic FLOPs are primary (see launch/analytic.py docstring);
        HLO flops retained as a cross-check."""
        if self.analytic_flops > 0:
            return self.analytic_flops / self.chips / PEAK_FLOPS
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_per_chip / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """6ND-useful over total structural FLOPs — exposes how much compute
        is attention/recurrence beyond the parameter matmuls."""
        total = (self.analytic_flops if self.analytic_flops > 0
                 else self.flops_per_chip * self.chips)
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step runs at the
        dominant-term bound: (useful compute time) / (bound time)."""
        useful_s = self.model_flops / (self.chips * PEAK_FLOPS)
        return useful_s / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "collective_per_chip": self.collective_per_chip,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "analytic_flops": self.analytic_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_breakdown": self.collective_breakdown,
        }


def from_compiled(compiled, chips: int, model_flops: float,
                  analytic_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(
        flops_per_chip=flops,
        bytes_per_chip=byts,
        collective_per_chip=float(sum(coll.values())),
        chips=chips,
        model_flops=model_flops,
        collective_breakdown=coll,
        analytic_flops=analytic_flops,
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS helpers
# ---------------------------------------------------------------------------


def active_params(cfg) -> int:
    """Active parameter count (MoE: top_k of n_experts + shared)."""
    from repro.models.registry import model_fns
    from repro.models.schema import num_params
    total = num_params(model_fns(cfg).schema)
    if cfg.moe.n_experts:
        m = cfg.moe
        L = cfg.n_layers - m.first_dense
        per_expert = 3 * cfg.d_model * m.d_expert
        expert_total = L * m.n_experts * per_expert
        expert_active = L * m.top_k * per_expert
        total = total - expert_total + expert_active
    return int(total)


def model_flops(cfg, shape, n_params: Optional[int] = None) -> float:
    """6·N·D train; 2·N·D inference (D = tokens processed per step)."""
    n = n_params if n_params is not None else active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
