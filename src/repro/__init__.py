"""repro: production-grade JAX framework implementing Softermax
(Stevens et al., 2021) — hardware/software co-designed softmax for
Transformers — as a first-class feature of a multi-pod training/serving
stack."""

__version__ = "1.0.0"
