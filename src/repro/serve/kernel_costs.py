"""Analytic per-launch cost model for the paged Pallas kernels — the
kernel cost observatory's measurement core.

Softermax's whole argument is a hardware cost model (energy/area per
softmax op); this module is the serving-side equivalent for our kernels:
closed-form accounting of what one ``flash_decode_paged`` /
``flash_prefill_paged`` launch *moves and computes*, as a pure function of
the launch geometry — ``(lengths, table_width, heads, block_size,
kv_tile_blocks, split_k, kv_dtype)``. Nothing here touches a device: the
numbers are derived from the same ``split_layout`` geometry the kernel
wrappers use, and they are pinned against the ref layer's *measuring*
oracles (``flash_decode_paged.ref.decode_gather_oracle`` /
``flash_prefill_paged.ref.prefill_gather_oracle``, which build the actual
gathered arrays and count bytes) by ``tests/test_kernel_costs.py``.

What is counted, and why it is exact:

* **Gather-DMA bytes.** The kernels' KV BlockSpec index maps gather one
  pool block per (tile slot, grid step) — unconditionally; ``@pl.when``
  skips *compute* on tiles past a row's length, not the DMA. The table is
  padded to ``Wp = S * spl * T`` blocks (``split_layout``), so per layer a
  decode launch moves exactly ``B * Hkv * Wp`` K-blocks + as many
  V-blocks (the once-per-KV-head gather contract pinned in PR 5's ref
  docstring), each ``BS * D * itemsize`` bytes, plus the int8 pools' scale
  siblings (``BS * 4`` bytes per block, K and V). Prefill re-streams the
  walk once per query tile (``nq`` of them).
* **Clamped / block-0 waste bytes.** Table entries at or past a row's
  real block count (``ceil(len / BS)``; the engine's pow2 bucketing, the
  wrapper's tile padding, and dead preallocated tail blocks all produce
  them) are gathered and then fully masked — pure DMA waste. Waste is 0
  exactly when every row's blocks fill the padded table (no block-0
  padding anywhere), which the property tests pin.
* **MXU FLOPs.** Per *computed* kv tile (``k_start < kv_len``, resp. the
  prefill diagonal check) the QK and AV dots each run their full tile
  shape regardless of masking: ``2 * rows * D * (T * BS)`` FLOPs apiece.
  Masked columns inside a computed tile still cost FLOPs (that is how the
  kernel runs) — only whole skipped tiles don't.
* **VMEM working set / grid steps / lanes.** The per-step tile residency
  and the grid decomposition, for roofline-style latency estimates.

``estimate_seconds`` turns a ``LaunchCost`` into a scalar latency proxy
under a ``CostParams`` machine model (HBM bandwidth, MXU rate, per-step
overhead, parallel cores). It is a *planning* model — monotone, smooth,
deliberately simple — used by ``serve/autotune.py`` to rank grid
candidates; absolute seconds are not the point, the argmin is.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Sequence

from repro.kernels.flash_decode_paged.ref import split_layout

# storage itemsizes by resolved pool dtype name (np.dtype("bfloat16")
# does not exist, so a mapping instead of np.dtype().itemsize)
KV_ITEMSIZE: Dict[str, int] = {"float32": 4, "bfloat16": 2,
                               "float16": 2, "int8": 1}
SCALE_BYTES = 4          # f32 per-row scale siblings of an int8 pool
ACC_BYTES = 4            # kernels accumulate in f32


def _itemsize(kv_dtype: str) -> int:
    try:
        return KV_ITEMSIZE[kv_dtype]
    except KeyError:
        raise ValueError(f"unknown kv_dtype {kv_dtype!r}; "
                         f"expected one of {sorted(KV_ITEMSIZE)}")


@dataclasses.dataclass(frozen=True)
class LaunchCost:
    """Per-launch (= per-layer) cost of one paged kernel invocation.

    Extensive fields (bytes / FLOPs / steps) are for ONE launch; the
    engine runs the kernel once per layer inside the scan, so callers
    scale by ``n_layers`` (``scaled``) when accounting a whole model step.
    """

    kind: str                # "decode" | "prefill"
    grid_steps: int          # total grid iterations of the launch
    lanes: int               # parallel grid extent (B*Hkv*S / B*Hkv*nq)
    steps_per_lane: int      # sequential kv iterations per lane (spl / nk)
    gather_bytes: int        # KV (+scale) HBM->VMEM bytes the gather moves
    waste_bytes: int         # subset of gather_bytes that is masked junk
    #                          (clamped block-0 / pad / dead tail entries)
    io_bytes: int            # non-gather operand traffic (q in, out/partials)
    flops: int               # MXU matmul FLOPs actually executed (QK + AV)
    merge_flops: int         # second-stage softermax_merge work (split-K)
    tile_bytes: int          # KV (+scale) bytes of ONE kv tile
    vmem_bytes: int          # per-step VMEM working set (tiles + scratch)

    @property
    def useful_bytes(self) -> int:
        return self.gather_bytes - self.waste_bytes

    def scaled(self, n: int) -> "LaunchCost":
        """The extensive fields times ``n`` (e.g. launches per model
        step = n_layers); per-step intensities (tile/vmem) unchanged."""
        return dataclasses.replace(
            self, grid_steps=self.grid_steps * n,
            gather_bytes=self.gather_bytes * n,
            waste_bytes=self.waste_bytes * n, io_bytes=self.io_bytes * n,
            flops=self.flops * n, merge_flops=self.merge_flops * n)

    def to_dict(self) -> Dict[str, int]:
        d = dataclasses.asdict(self)
        d["useful_bytes"] = self.useful_bytes
        return d


def _block_bytes(block_size: int, head_dim: int, kv_dtype: str) -> int:
    """Bytes one gathered pool block moves: K + V values, plus the f32
    scale rows when the pool is int8 (scales ride the same gather)."""
    b = 2 * block_size * head_dim * _itemsize(kv_dtype)
    if kv_dtype == "int8":
        b += 2 * block_size * SCALE_BYTES
    return b


def decode_launch_cost(
    lengths: Sequence[int],   # (B,) kv lengths the kernel attends (new_len)
    table_width: int,         # W — table width as passed to the kernel
    *,
    n_q_heads: int,
    n_kv_heads: int,
    head_dim: int,
    block_size: int,
    kv_tile_blocks: int = 1,
    split_k: int = 1,
    kv_dtype: str = "float32",
) -> LaunchCost:
    """Cost of one ``flash_decode_paged`` launch (one layer).

    Mirrors the kernel wrapper exactly: ``split_layout`` clamps/pads the
    grid, every (lane, kv step) DMAs its T blocks unconditionally, and
    compute runs on tiles with ``k_start < kv_len`` only.
    """
    B = len(lengths)
    W, BS, D = table_width, block_size, head_dim
    Hq, Hkv = n_q_heads, n_kv_heads
    G = Hq // Hkv
    T, S, spl, Wp = split_layout(W, kv_tile_blocks, split_k)
    bb = _block_bytes(BS, D, kv_dtype)

    gather = B * Hkv * Wp * bb
    useful_blocks = sum(min(-(-int(ln) // BS), Wp) for ln in lengths)
    waste = (B * Wp - useful_blocks) * Hkv * bb

    # computed kv tiles per row: tile jj runs iff jj*T*BS < len
    tiles = sum(min(-(-int(ln) // (T * BS)), S * spl) for ln in lengths)
    flops = tiles * Hkv * 4 * G * D * T * BS          # QK + AV full tiles
    merge = B * Hq * D * 8 * S if S > 1 else 0        # jnp merge stage

    q_in = B * Hq * D * ACC_BYTES
    part_out = B * Hkv * S * (G * D + 2 * G) * ACC_BYTES
    vmem = (G * D * ACC_BYTES                         # q tile (f32 in-kernel)
            + T * bb                                  # K+V (+scale) tiles
            + (G * D + 2 * G) * ACC_BYTES             # acc/m/d scratch
            + (G * D + 2 * G) * ACC_BYTES)            # partial outputs
    return LaunchCost(kind="decode", grid_steps=B * Hkv * S * spl,
                      lanes=B * Hkv * S, steps_per_lane=spl,
                      gather_bytes=gather, waste_bytes=waste,
                      io_bytes=q_in + part_out, flops=flops,
                      merge_flops=merge, tile_bytes=T * bb,
                      vmem_bytes=vmem)


def prefill_launch_cost(
    q_len: int,               # Sq — chunk length as passed (incl. padding)
    q_pos0: Sequence[int],    # (B,) absolute position of each row's q[0]
    cover_blocks: Sequence[int],   # (B,) REAL table entries per row (the
    #                                rest of the width is block-0 padding)
    table_width: int,         # W — table width as passed to the kernel
    *,
    n_q_heads: int,
    n_kv_heads: int,
    head_dim: int,
    block_size: int,
    kv_tile_blocks: int = 1,
    block_q: int = 128,
    kv_dtype: str = "float32",
) -> LaunchCost:
    """Cost of one ``flash_prefill_paged`` launch (one layer).

    The kv walk re-streams once per query tile (grid ``(B*Hkv, nq, nk)``),
    compute is skipped for tiles entirely above the causal diagonal
    (``k_start <= q_start + BQ - 1``), and table entries at or past a
    row's real cover are clamped block-0 waste.
    """
    B = len(q_pos0)
    if len(cover_blocks) != B:
        raise ValueError("q_pos0 and cover_blocks must align per row")
    W, BS, D = table_width, block_size, head_dim
    Hq, Hkv = n_q_heads, n_kv_heads
    G = Hq // Hkv
    T, _, nk, Wp = split_layout(W, kv_tile_blocks, 1)
    BQ = min(block_q, q_len)
    Sqp = -(-q_len // BQ) * BQ
    nq = Sqp // BQ
    bb = _block_bytes(BS, D, kv_dtype)

    gather = B * Hkv * nq * Wp * bb
    waste = sum(Hkv * nq * (Wp - min(int(c), Wp)) * bb
                for c in cover_blocks)

    flops = 0
    for p0 in q_pos0:
        for i in range(nq):
            q_end = int(p0) + i * BQ + BQ - 1
            ct = min(q_end // (T * BS) + 1, nk)        # diagonal check
            flops += ct * Hkv * 4 * G * BQ * D * T * BS
    q_in = B * Hq * Sqp * D * ACC_BYTES
    out = B * Hq * Sqp * D * ACC_BYTES
    vmem = (G * BQ * D * ACC_BYTES + T * bb
            + (G * BQ * D + 2 * G * BQ) * ACC_BYTES
            + G * BQ * D * ACC_BYTES)
    return LaunchCost(kind="prefill", grid_steps=B * Hkv * nq * nk,
                      lanes=B * Hkv * nq, steps_per_lane=nk,
                      gather_bytes=gather, waste_bytes=waste,
                      io_bytes=q_in + out, flops=flops, merge_flops=0,
                      tile_bytes=T * bb, vmem_bytes=vmem)


# ---------------------------------------------------------------------------
# Latency proxy (the planner's objective)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Machine model for ``estimate_seconds``. The defaults are
    TPU-shaped round numbers (HBM ~0.8 TB/s, MXU ~20 f32 TFLOP/s,
    megacore = 2 parallel cores); they are planning weights, not
    measurements — the planner only consumes the argmin over candidates,
    which is robust to the absolute scale. Raise ``cores`` on parts with
    more parallel lanes (it is what makes split-K pay for its padding)."""

    hbm_bytes_per_s: float = 8.0e11
    flops_per_s: float = 2.0e13
    grid_step_overhead_s: float = 2e-6   # per sequential grid iteration
    launch_overhead_s: float = 1e-5
    cores: int = 2                       # parallel lanes executed at once


DEFAULT_COST_PARAMS = CostParams()


def estimate_seconds(cost: LaunchCost,
                     params: CostParams = DEFAULT_COST_PARAMS) -> float:
    """Scalar latency proxy for one launch: fixed launch overhead, the
    sequential grid-iteration wall (lanes spread over ``cores``, each
    walking its ``steps_per_lane`` kv steps), and the throughput floor —
    whichever of HBM streaming or MXU compute binds — plus the split
    merge. Monotone in every extensive cost, which is all the planner's
    argmin needs."""
    wall_steps = math.ceil(cost.lanes / params.cores) * cost.steps_per_lane
    t_overhead = (params.launch_overhead_s
                  + wall_steps * params.grid_step_overhead_s)
    t_stream = max((cost.gather_bytes + cost.io_bytes)
                   / params.hbm_bytes_per_s,
                   cost.flops / params.flops_per_s)
    t_merge = cost.merge_flops / params.flops_per_s
    return t_overhead + t_stream + t_merge
