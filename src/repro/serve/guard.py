"""Graceful-degradation ladder for the continuous serving engine.

``EngineGuard`` is a three-state machine driven once per engine step by a
``GuardSignals`` snapshot assembled from the PR 6/7 observability signals
(pool utilization, audited logit error, queue wait, step-time watchdog):

    HEALTHY ──signals degrade──► DEGRADED ──signals degrade──► SHEDDING
        ▲                            │  ▲                          │
        └──── recover_steps clean ───┘  └──── recover_steps clean ─┘

* **HEALTHY** — no intervention.
* **DEGRADED** — the engine shrinks its per-step prefill budget and
  admission cap (``prefill_budget_factor`` / ``max_admit_factor``), easing
  pool and step-time pressure while existing requests keep full service.
* **SHEDDING** — new submissions are refused (``EngineSheddingError``,
  counted in ``requests_shed_total``) and admission pauses entirely;
  running requests drain, freeing the resources that tripped the ladder.

Escalation is immediate (the observed severity wins the step); recovery is
hysteretic — the guard steps DOWN one level only after ``recover_steps``
consecutive observations strictly below the current level, so a flapping
signal can't oscillate the engine.

**Quarantine** is the per-request arm of the same policy: a request whose
audited logit error exceeds ``quarantine_error`` (the engine's
scatter-readback audit compares re-read pool KV against the just-computed
prefill logits — silent KV corruption shows up as a huge delta, ordinary
int8 quantization error stays under the PR 4 bound) is cancelled and its
published radix-tree nodes purged, so poisoned KV can never serve a later
prefix hit. See ``ContinuousEngine._quarantine`` / ``RadixCache.purge``.

All host-side, O(1) per step; the guard owns no engine state — the engine
*asks* it for effective knob values, keeping policy and mechanism apart.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

HEALTHY, DEGRADED, SHEDDING = "healthy", "degraded", "shedding"
GUARD_STATES = (HEALTHY, DEGRADED, SHEDDING)


class EngineSheddingError(RuntimeError):
    """submit() refused: the guard is in SHEDDING state. Back off and
    retry; the guard recovers automatically once signals clear.
    ``retry_after_steps`` is the machine-readable hint (PR 9): the number
    of clean engine steps still required before the guard can step down
    out of SHEDDING and the front door reopens — a router/front-end should
    wait at least that many steps before re-offering work."""

    def __init__(self, msg: str, retry_after_steps: int = 1):
        super().__init__(msg)
        self.retry_after_steps = retry_after_steps


@dataclasses.dataclass
class GuardConfig:
    """Thresholds and knobs of the degradation ladder. The defaults suit
    the reduced-config CPU benches; production tunes them per deployment.
    A ``None`` threshold disables that signal."""

    # pool utilization (0..1) above which the ladder escalates
    pool_util_degraded: float = 0.88
    pool_util_shedding: float = 0.97
    # audited logit error (readback audit / numerics probe / injected
    # spike) above which the step counts as degraded
    logit_error_degraded: float = 0.25
    # per-request quarantine bound: cancel + purge when a request's own
    # readback audit exceeds this (>> the PR 4 quantization bound of 0.1,
    # << any real corruption)
    quarantine_error: float = 0.5
    # queue wait (seconds, oldest waiting request) thresholds
    queue_wait_degraded: Optional[float] = None
    queue_wait_shedding: Optional[float] = None
    # step-time watchdog: a step slower than this counts as hung
    step_time_hung_s: Optional[float] = None
    # consecutive clean observations required to step DOWN one level
    recover_steps: int = 3
    # knob shrink factors applied while DEGRADED or worse
    prefill_budget_factor: float = 0.5
    max_admit_factor: float = 0.5
    # run the scatter-readback KV-integrity audit after each completed
    # prefill (the quarantine detector; costs one 1-token suffix prefill)
    readback_audit: bool = True


@dataclasses.dataclass
class GuardSignals:
    """One step's health snapshot, assembled by the engine."""

    pool_util: float = 0.0
    logit_error: float = 0.0     # max audited/injected error this step
    queue_wait: float = 0.0      # oldest waiting request's wait (seconds)
    queue_depth: int = 0
    step_seconds: float = 0.0


_LEVEL = {HEALTHY: 0, DEGRADED: 1, SHEDDING: 2}
_STATE = {v: k for k, v in _LEVEL.items()}


class EngineGuard:
    """The HEALTHY → DEGRADED → SHEDDING state machine (module docstring).

    ``observe(signals, step)`` returns the ``(old, new, reason)``
    transition when one happened, else None. ``transitions`` keeps the
    full history for the bench/replay artifact."""

    def __init__(self, config: Optional[GuardConfig] = None):
        self.config = config or GuardConfig()
        self.state = HEALTHY
        self._clean_streak = 0
        self.transitions: List[Tuple[int, str, str, str]] = []
        self.last_reason = ""

    @property
    def level(self) -> int:
        return _LEVEL[self.state]

    # -- severity ----------------------------------------------------------

    def _severity(self, s: GuardSignals) -> Tuple[int, str]:
        """Map one signal snapshot to the ladder level it demands."""
        c = self.config
        if s.pool_util >= c.pool_util_shedding:
            return 2, f"pool_util {s.pool_util:.2f}"
        if c.queue_wait_shedding is not None and \
                s.queue_wait >= c.queue_wait_shedding:
            return 2, f"queue_wait {s.queue_wait:.3f}s"
        if s.pool_util >= c.pool_util_degraded:
            return 1, f"pool_util {s.pool_util:.2f}"
        if s.logit_error >= c.logit_error_degraded:
            return 1, f"logit_error {s.logit_error:.3f}"
        if c.queue_wait_degraded is not None and \
                s.queue_wait >= c.queue_wait_degraded:
            return 1, f"queue_wait {s.queue_wait:.3f}s"
        if c.step_time_hung_s is not None and \
                s.step_seconds >= c.step_time_hung_s:
            return 1, f"step_seconds {s.step_seconds:.3f}"
        return 0, ""

    def observe(self, signals: GuardSignals,
                step: int = -1) -> Optional[Tuple[str, str, str]]:
        """Feed one step's signals; escalate immediately, recover one
        level after ``recover_steps`` consecutive cleaner observations."""
        sev, reason = self._severity(signals)
        old = self.state
        if sev > self.level:
            self.state = _STATE[sev]
            self._clean_streak = 0
        elif sev < self.level:
            self._clean_streak += 1
            if self._clean_streak >= self.config.recover_steps:
                self.state = _STATE[self.level - 1]
                self._clean_streak = 0
                reason = f"recovered after {self.config.recover_steps} " \
                         f"clean steps"
        else:
            self._clean_streak = 0
        if self.state != old:
            self.last_reason = reason
            self.transitions.append((step, old, self.state, reason))
            return old, self.state, reason
        return None

    # -- policy queries (the engine asks; the guard never mutates it) -----

    def admit_allowed(self) -> bool:
        return self.state != SHEDDING

    def submit_allowed(self) -> bool:
        return self.state != SHEDDING

    def retry_after_steps(self) -> int:
        """Clean steps still needed before the current level can step down
        one rung (the ``recover_steps`` hysteresis minus the clean streak
        already banked). This is the ``EngineSheddingError`` backoff hint:
        while SHEDDING, submissions cannot succeed sooner."""
        return max(1, self.config.recover_steps - self._clean_streak)

    def effective_max_admit(self, base: int) -> int:
        if self.state == SHEDDING:
            return 0
        if self.state == DEGRADED:
            return max(1, int(base * self.config.max_admit_factor))
        return base

    def effective_prefill_budget(self, base: int) -> int:
        """Shrink the per-step prefill token budget while degraded. A base
        of 0 means "uncapped" — degraded mode still returns 0 (there is no
        number to shrink; the admission cap is the lever then)."""
        if base and self.state != HEALTHY:
            return max(1, int(base * self.config.prefill_budget_factor))
        return base

    def should_quarantine(self, logit_error: float) -> bool:
        return logit_error >= self.config.quarantine_error

    def reset(self) -> None:
        self.state = HEALTHY
        self._clean_streak = 0
        self.transitions.clear()
        self.last_reason = ""
