"""Deterministic, seedable fault injection for the continuous serving engine.

Resilience work needs *reproducible* failure: a fault plan is a list of
``FaultSpec`` entries — each naming a fault kind and either a fixed step
window (``step``/``duration``) or a per-step probability (``prob``) — plus
one seed. ``FaultInjector`` evaluates the plan at every engine step with an
RNG derived from ``(seed, step, spec index)``, so the same plan replays the
same faults bit-for-bit regardless of wall time or host state, and a replay
artifact (``save_log``) records exactly what fired where.

The injector threads through the serving stack behind ONE nullable hook
per component, the same pattern PR 6 used for telemetry (``faults=None``
keeps every hot path at a single ``is not None`` check):

* ``ContinuousEngine.step()``  — drives ``begin_step``; applies pool
  pressure (steals free blocks under the sentinel request id ``FAULT_REQ``
  so the *real* eviction/preemption machinery feels the squeeze), stalls
  for slow/hung steps, forces preemption storms, raises transient step
  faults (retried with backoff), corrupts the KV scatter of a completing
  prefill, and feeds injected numerics spikes to the guard.
* ``Scheduler.admit()``        — returns empty while an admission stall is
  active.
* ``PagedKVCache.append_block`` — raises ``TransientFault`` while a
  ``step_fault`` window is active (the engine's retry-with-backoff path;
  the hook fires *before* any pool state mutates, so a retry is safe).

Fault taxonomy (``FAULT_KINDS``; see serve/README.md "Failure model"):

``pool_pressure``   steal ``magnitude`` (fraction of the pool) free blocks
                    for ``duration`` steps — exercises cache eviction,
                    admission back-off and preemption under real scarcity.
``admit_stall``     scheduler admits nothing for ``duration`` steps.
``slow_step``       stall ``magnitude`` seconds at step start.
``hung_step``       like slow_step but sized to trip the guard's
                    step-time watchdog.
``preempt_storm``   force-preempt the ``magnitude`` youngest decoding
                    requests at step start.
``step_fault``      the next ``duration`` block-growth attempts raise
                    ``TransientFault`` (bounded retry-with-backoff).
``kv_corrupt``      corrupt the exclusively-owned KV blocks of the next
                    prefill that completes while the window is active
                    (silent data corruption; the guard's scatter-readback
                    audit is what catches it).
``numerics_spike``  inject a logit-error reading of ``magnitude`` into the
                    guard signal for ``duration`` steps.

Fleet kinds (PR 9; consumed by ``serve/supervisor.py`` per fleet tick,
``magnitude`` names the victim replica index):

``replica_crash``   the replica dies at the window's opening tick: its
                    engine is abandoned and every in-flight request is
                    re-placed on a survivor.
``replica_hang``    the replica's device stops responding for ``duration``
                    ticks: the supervisor's step-watchdog declares it hung
                    after the heartbeat grace, fails its requests over,
                    and readmits the replica (empty) once it resumes.

All decisions happen in ``begin_step``; the per-site hooks only consume
them. Everything is host-side; the only device work a fault can cause is
the ``kv_corrupt`` block rewrite, performed by the engine.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

# Sentinel request id the injector's stolen pool-pressure blocks are
# allocated under. Negative so it can never collide with a real request.
FAULT_REQ = -1

# Engine-level kinds are consumed inside ContinuousEngine.step(); fleet
# kinds are consumed by the FleetSupervisor's per-tick poll (the engine
# never sees them — a whole replica crashing or hanging is not something
# the replica itself can observe).
ENGINE_FAULT_KINDS = ("pool_pressure", "admit_stall", "slow_step",
                      "hung_step", "preempt_storm", "step_fault",
                      "kv_corrupt", "numerics_spike")
FLEET_FAULT_KINDS = ("replica_crash", "replica_hang")
FAULT_KINDS = ENGINE_FAULT_KINDS + FLEET_FAULT_KINDS


class TransientFault(RuntimeError):
    """A recoverable injected failure: the operation is expected to
    succeed if retried (the engine wraps the affected sites in bounded
    retry-with-backoff). Deliberately NOT a ``PoolExhausted`` subclass —
    exhaustion handling (evict/preempt) is the wrong response to a
    transient glitch."""


@dataclasses.dataclass
class FaultSpec:
    """One fault source. Fires at step ``step`` (for ``duration`` steps)
    when ``step`` is set; otherwise fires each step with probability
    ``prob`` (windows of ``duration`` steps, non-overlapping per spec).
    ``magnitude`` is kind-specific: pool fraction (pool_pressure), seconds
    (slow/hung_step), request count (preempt_storm), injected logit error
    (numerics_spike); unused otherwise."""

    kind: str
    step: Optional[int] = None
    prob: float = 0.0
    duration: int = 1
    magnitude: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.step is None and self.prob <= 0.0:
            raise ValueError(f"{self.kind}: need step index or prob > 0")
        if self.duration < 1:
            raise ValueError(f"{self.kind}: duration must be >= 1")


@dataclasses.dataclass
class FaultPlan:
    """A seed plus the fault specs. JSON round-trips for --fault-plan
    files and the CI replay artifact."""

    seed: int = 0
    specs: List[FaultSpec] = dataclasses.field(default_factory=list)

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "specs": [dataclasses.asdict(s)
                                     for s in self.specs]},
                          indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        return cls(seed=int(d.get("seed", 0)),
                   specs=[FaultSpec(**s) for s in d.get("specs", [])])

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())


def canned_plan(seed: int = 7) -> FaultPlan:
    """The reference fault plan the resilience benchmark and the CI chaos
    smoke run: one of every ENGINE kind, step-indexed so the guarded and
    the unguarded runs face the *identical* storm (fleet kinds live in
    ``canned_fleet_plan`` — an engine cannot injure its own replica)."""
    return FaultPlan(seed=seed, specs=[
        FaultSpec("kv_corrupt", step=2, duration=2),
        FaultSpec("admit_stall", step=5, duration=2),
        FaultSpec("pool_pressure", step=8, duration=3, magnitude=0.5),
        FaultSpec("step_fault", step=12, duration=2),
        FaultSpec("slow_step", step=14, duration=1, magnitude=0.005),
        FaultSpec("preempt_storm", step=17, duration=1, magnitude=2),
        FaultSpec("numerics_spike", step=20, duration=2, magnitude=0.75),
        FaultSpec("hung_step", step=24, duration=1, magnitude=0.02),
    ])


def canned_fleet_plan(seed: int = 11, crash_tick: int = 10,
                      crash_replica: int = 0,
                      hang_tick: Optional[int] = 22, hang_ticks: int = 4,
                      hang_replica: int = 1) -> FaultPlan:
    """The reference FLEET fault plan (fleet bench + CI fleet chaos
    smoke): replica ``crash_replica`` dies at tick ``crash_tick``;
    optionally replica ``hang_replica`` goes unresponsive for
    ``hang_ticks`` ticks starting at ``hang_tick`` (None disables the
    hang). Tick indices are fleet supervision ticks, not engine steps."""
    specs = [FaultSpec("replica_crash", step=crash_tick,
                       magnitude=crash_replica)]
    if hang_tick is not None:
        specs.append(FaultSpec("replica_hang", step=hang_tick,
                               duration=hang_ticks,
                               magnitude=hang_replica))
    return FaultPlan(seed=seed, specs=specs)


class FaultInjector:
    """Evaluates a ``FaultPlan`` step by step. Deterministic: every
    probabilistic decision draws from an RNG seeded with
    ``(plan.seed, step, spec index)``, so two runs over the same plan and
    step sequence inject identically. ``log`` records every injection
    (the replay artifact); the engine appends per-fault details (e.g. the
    req_id/blocks a kv_corrupt hit)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.log: List[Dict] = []
        self.faults_injected = 0
        self.reset()

    def reset(self) -> None:
        """Forget all window/consumption state (new serving run)."""
        self.step_idx = -1
        self._fired: Dict[str, FaultSpec] = {}
        # spec index -> first step of the currently-active window
        self._windows: Dict[int, int] = {}
        self._step_fault_raises = 0   # TransientFaults left to raise
        self._kv_corrupt_armed = False
        self._crash_pending: List[int] = []   # replica idx, until consumed
        self._hung_replicas: set = set()      # replica idx, this tick
        self.log.clear()
        self.faults_injected = 0

    # -- per-step evaluation ----------------------------------------------

    def _active(self, idx: int, spec: FaultSpec, step: int) -> bool:
        """Is ``spec`` active at ``step``? Fixed-step specs are active on
        [step, step+duration); probabilistic specs open a ``duration``-step
        window when their per-step coin lands (windows don't overlap)."""
        if spec.step is not None:
            return spec.step <= step < spec.step + spec.duration
        w0 = self._windows.get(idx)
        if w0 is not None and step < w0 + spec.duration:
            return True
        rng = np.random.default_rng(
            (self.plan.seed, step, idx))          # deterministic per-site
        if rng.random() < spec.prob:
            self._windows[idx] = step
            return True
        return False

    def begin_step(self, step: int, telemetry=None) -> None:
        """Evaluate every spec for this step; called by the engine at the
        top of ``step()``. New firings are logged and counted (and
        reported to telemetry's ``fault_injected_total`` when attached)."""
        self.step_idx = step
        self._fired: Dict[str, FaultSpec] = {}
        self._hung_replicas = set()
        for idx, spec in enumerate(self.plan.specs):
            if not self._active(idx, spec, step):
                continue
            self._fired[spec.kind] = spec
            if spec.kind == "replica_hang":
                self._hung_replicas.add(int(spec.magnitude))
            opening = (spec.step == step if spec.step is not None
                       else self._windows.get(idx) == step)
            if opening:
                if spec.kind == "step_fault":
                    self._step_fault_raises = spec.duration
                if spec.kind == "kv_corrupt":
                    self._kv_corrupt_armed = True
                if spec.kind == "replica_crash":
                    self._crash_pending.append(int(spec.magnitude))
                self.record(spec.kind, step=step,
                            duration=spec.duration,
                            magnitude=spec.magnitude)
                if telemetry is not None:
                    telemetry.on_fault(spec.kind, step,
                                       magnitude=spec.magnitude)

    def record(self, kind: str, **details) -> None:
        """Append one replay-log entry (the engine adds per-fault details
        like kv_corrupt victims through this too)."""
        self.log.append(dict(kind=kind, **details))
        self.faults_injected += 1

    # -- consumption hooks (engine / scheduler / pool) --------------------

    def pool_pressure_target(self, num_blocks: int) -> int:
        """Blocks the injector wants held hostage right now (0 = release
        any currently held)."""
        spec = self._fired.get("pool_pressure")
        if spec is None:
            return 0
        return max(1, int(spec.magnitude * num_blocks))

    def admission_stalled(self) -> bool:
        return "admit_stall" in self._fired

    def stall_seconds(self) -> float:
        s = self._fired.get("slow_step")
        h = self._fired.get("hung_step")
        return (s.magnitude if s else 0.0) + (h.magnitude if h else 0.0)

    def hung(self) -> bool:
        return "hung_step" in self._fired

    def preempt_storm_count(self) -> int:
        spec = self._fired.get("preempt_storm")
        return int(spec.magnitude) if spec is not None else 0

    def check_step_fault(self) -> None:
        """Raise ``TransientFault`` while raises remain in the active
        step_fault window; each call consumes one raise, so bounded retry
        eventually succeeds."""
        if self._step_fault_raises > 0 and "step_fault" in self._fired:
            self._step_fault_raises -= 1
            raise TransientFault(
                f"injected step fault at step {self.step_idx} "
                f"({self._step_fault_raises} raises left)")

    def on_append_block(self, req_id: int) -> None:
        """PagedKVCache.append_block hook: same transient-fault budget as
        the step-level probe, surfaced at the block-growth site (fires
        BEFORE the pool mutates, so the engine's retry is safe)."""
        self.check_step_fault()

    def take_kv_corrupt(self) -> bool:
        """True exactly once per kv_corrupt window: the engine corrupts
        the prefill that completes next."""
        if self._kv_corrupt_armed and "kv_corrupt" in self._fired:
            self._kv_corrupt_armed = False
            return True
        return False

    def numerics_spike(self) -> float:
        spec = self._fired.get("numerics_spike")
        return spec.magnitude if spec is not None else 0.0

    # -- consumption hooks (fleet supervisor) -----------------------------

    def take_replica_crashes(self) -> List[int]:
        """Replica indices whose crash window opened since the last call
        (consumed once: a replica only dies one time)."""
        out, self._crash_pending = self._crash_pending, []
        return out

    def replica_hang_targets(self) -> "set":
        """Replica indices whose device is unresponsive this tick (the
        supervisor's drive loop skips stepping them; detection is the
        step-watchdog's job, not this hook's)."""
        return set(self._hung_replicas)

    # -- replay artifact ---------------------------------------------------

    def save_log(self, path: str) -> None:
        """Write the replay artifact: the plan plus every injection that
        fired, as one JSON document."""
        with open(path, "w") as f:
            json.dump({"plan": json.loads(self.plan.to_json()),
                       "injections": self.log}, f, indent=2)
            f.write("\n")

    def corrupted_req_ids(self) -> List[int]:
        """Request ids whose KV the engine corrupted (from the log)."""
        return [e["req_id"] for e in self.log
                if e["kind"] == "kv_corrupt" and "req_id" in e]
