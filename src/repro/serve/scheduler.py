"""Continuous-batching scheduler: FIFO admission, join, eviction, preemption.

Request lifecycle (see serve/README.md):

    QUEUED --admit--> PREFILL --join--> DECODING --evict--> FINISHED
                          ^                 |
                          '---- preempt ----'

``admit`` pops the FIFO while the pool can hold the prompt's blocks and a
decode slot is free; admitted requests prefill and join the running batch at
the *next* step boundary (continuous batching — no waiting for the batch to
drain). ``ensure_decode_blocks`` grows tables when a sequence crosses a block
boundary; if the pool is exhausted it first evicts unreferenced prefix-cache
blocks, then preempts the *youngest* running request (recompute-on-readmit
policy: its blocks are released, its generated tokens are discarded, and it
rejoins the head of the queue), guaranteeing the oldest requests always make
progress.

With a ``RadixCache`` attached, admission charges a request only for the
*uncached* part of its trajectory — the matched prefix is spliced out of the
tree by reference — and cache-evictable blocks count toward the admission
budget. On finish/preempt the request's prompt blocks are released back to
the tree (they were published to it right after prefill) instead of being
freed outright; on finish the *generated* tokens whose values the engine
has drained are published too, so a follow-up turn that extends the whole
conversation (prompt + reply) readmits as a near-full cache hit.

**Chunked prefill.** When the engine runs with a prefill chunk size, an
admitted request stays in PREFILL across several steps: ``next_chunk``
deals out fixed-size chunks of the uncached prompt remainder (the last one
ragged), the engine computes/scatters one chunk per request per step, and
``prefilling`` lists the requests mid-prefill. Block accounting is
unchanged — admission already allocated the whole prompt's blocks — but
``ensure_decode_blocks`` must not grow tables for requests that are still
prefilling (their ``n_cached`` counts scattered prompt rows, not decode
growth).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, TYPE_CHECKING

import numpy as np

from repro.serve.kv_pool import PagedKVCache, PoolExhausted

if TYPE_CHECKING:   # import cycle: radix_cache uses kv_pool
    from repro.serve.radix_cache import RadixCache

QUEUED, PREFILL, DECODING, FINISHED = "queued", "prefill", "decoding", \
    "finished"

# terminal reasons a request can leave the engine with (Request.finish_reason)
FINISH_LENGTH = "length"              # generated max_new tokens (normal)
FINISH_CANCELLED = "cancelled"        # client called engine.cancel()
FINISH_DEADLINE = "deadline"          # per-request deadline / TTFT budget
FINISH_QUARANTINED = "quarantined"    # audited logit error over the bound
FINISH_FAILOVER = "failover"          # revoked from a hung replica after
#                                       its requests were re-placed on a
#                                       survivor (fleet-internal: never a
#                                       client-visible terminal state)


class SubmitError(ValueError):
    """A request was rejected at submission. Subclasses name the reason;
    all stay ``ValueError`` for backward compatibility."""


class EmptyPromptError(SubmitError):
    """Prompt has zero tokens."""


class DuplicateRequestError(SubmitError):
    """The request id is already queued, running, or finished."""


class CapacityExceededError(SubmitError):
    """The trajectory cannot fit this engine: prompt + max_new exceeds
    ``max_len``, or needs more blocks than the whole pool
    (``token_capacity``)."""


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray               # (S,) int32
    max_new: int
    temperature: float = 0.0
    state: str = QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    n_generated: int = 0             # tokens sampled (≥ len(tokens): the
                                     # engine materializes values lazily)
    n_cached: int = 0                # tokens resident in the paged cache
    n_prefix_hit: int = 0            # prompt tokens reused from the radix
                                     # tree at this admission (prefill skips
                                     # them)
    n_prefilled: int = 0             # prompt tokens resident in the pool
                                     # (cache hit + chunks computed so far;
                                     # == prompt_len once prefill completes)
    epoch: int = 0                   # bumped on preemption: stale in-flight
                                     # token vectors are discarded by epoch
    n_preemptions: int = 0
    # lifecycle stamps from the owning scheduler/engine clock (monotonic by
    # default; injectable for deterministic telemetry tests)
    t_submit: float = 0.0
    t_admit: float = 0.0             # latest admission (re-stamped on readmit)
    t_first_token: float = 0.0
    t_last_token: float = 0.0        # latest decode-token dispatch (TPOT)
    t_finish: float = 0.0
    # lifecycle hardening (PR 8): why the request reached FINISHED, and its
    # optional per-request latency budgets (seconds from t_submit; the
    # engine cancels on breach and counts deadline_misses_total)
    finish_reason: str = ""          # FINISH_* once state == FINISHED
    deadline_s: Optional[float] = None       # whole-request deadline
    ttft_budget_s: Optional[float] = None    # first-token deadline
    # fleet migration (PR 9): a request re-placed on a survivor replica
    # after a crash/hang arrives with its ORIGINAL submit stamp (so
    # deadlines and E2E keep measuring from the client's submit) and with
    # ttft_observed=True when its first token already streamed from the
    # dead replica (telemetry must not observe a second fleet TTFT sample)
    ttft_observed: bool = False

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return self.n_generated >= self.max_new

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit

    @property
    def latency(self) -> float:
        return self.t_finish - self.t_submit


class Scheduler:
    """Owns the admission queue and the running set; mutates pool metadata.

    The engine calls, per step: ``admit()`` → prefill the returned requests →
    ``ensure_decode_blocks()`` → run the fused decode step over
    ``running``.
    """

    def __init__(self, pool: PagedKVCache, max_batch: int,
                 max_len: int, cache: Optional["RadixCache"] = None,
                 clock=time.monotonic):
        self.pool = pool
        self.cache = cache
        self._clock = clock          # request lifecycle timestamps
        # nullable fault-injection hook (serve/faults.py), same pattern as
        # the engine's telemetry: None keeps admit() at one extra check
        self.faults = None
        self.max_batch = max_batch
        self.max_len = max_len
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._next_id = 0
        self._reserved: Dict[int, int] = {}   # future growth blocks held
        self.n_preemptions = 0
        self.tokens_discarded = 0     # generated tokens thrown away by
        #                               preemption (recomputed on readmit)

    def _outstanding(self) -> int:
        return sum(self._reserved.values())

    # -- submission -------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int,
               temperature: float = 0.0,
               req_id: Optional[int] = None,
               deadline_s: Optional[float] = None,
               ttft_budget_s: Optional[float] = None,
               t_submit: Optional[float] = None,
               ttft_observed: bool = False) -> Request:
        """Validate + enqueue. Rejections raise typed ``SubmitError``
        subclasses (all ``ValueError``s) at the front door instead of
        failing late and untyped deep in admission. ``t_submit`` overrides
        the submit stamp (fleet failover: the survivor measures deadlines
        and E2E from the client's original submit, not the re-placement);
        ``ttft_observed`` marks the fleet-wide first token as already
        delivered (telemetry skips the TTFT sample)."""
        rid = req_id if req_id is not None else self._next_id
        if isinstance(rid, int):
            self._next_id = max(self._next_id, rid + 1)  # no auto collision
        if max_new < 1:
            raise SubmitError(f"request {rid}: max_new must be >= 1")
        if prompt.ndim != 1:
            raise SubmitError(
                f"request {rid}: prompt must be 1-D, got shape "
                f"{tuple(prompt.shape)}")
        if prompt.shape[0] < 1:
            raise EmptyPromptError(f"request {rid}: empty prompt")
        if rid in self.finished or \
                any(r.req_id == rid for r in self.waiting) or \
                any(r.req_id == rid for r in self.running):
            raise DuplicateRequestError(f"request id {rid} already in use")
        if prompt.shape[0] + max_new > self.max_len:
            raise CapacityExceededError(
                f"request {rid}: prompt {prompt.shape[0]} + max_new "
                f"{max_new} exceeds engine max_len {self.max_len}")
        total = self.pool.blocks_for(prompt.shape[0] + max_new - 1)
        if total > self.pool.num_blocks:
            raise CapacityExceededError(
                f"request {rid}: trajectory needs {total} blocks "
                f"({prompt.shape[0] + max_new - 1} cached tokens) but the "
                f"pool holds {self.pool.num_blocks} blocks "
                f"({self.pool.token_capacity} tokens) — raise num_blocks")
        if deadline_s is not None and deadline_s <= 0:
            raise SubmitError(f"request {rid}: deadline_s must be > 0")
        if ttft_budget_s is not None and ttft_budget_s <= 0:
            raise SubmitError(f"request {rid}: ttft_budget_s must be > 0")
        req = Request(rid, np.asarray(prompt, np.int32), max_new,
                      temperature,
                      t_submit=(t_submit if t_submit is not None
                                else self._clock()),
                      deadline_s=deadline_s, ttft_budget_s=ttft_budget_s,
                      ttft_observed=ttft_observed)
        self.waiting.append(req)
        return req

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- admission --------------------------------------------------------

    def admit(self, max_n: Optional[int] = None) -> List[Request]:
        """FIFO admission: pop while a slot is free and the pool can hold the
        request's whole trajectory (prompt blocks now + reserved growth for
        its max_new decode tokens). Reserving the trajectory keeps admission
        from over-committing the pool, so preemption is a safety net rather
        than the steady state. ``max_n`` caps admissions per call so prefill
        bursts interleave with decode steps instead of stalling them.

        With a prefix cache, a request is charged only for the blocks its
        matched prefix does NOT cover, and cache-evictable blocks count as
        free (``admit`` evicts them on the spot)."""
        if self.faults is not None and self.faults.admission_stalled():
            return []                # injected admission stall: admit later
        admitted: List[Request] = []
        while self.waiting and len(self.running) < self.max_batch and \
                (max_n is None or len(admitted) < max_n):
            nxt = self.waiting[0]
            plen = nxt.prompt_len
            need = self.pool.blocks_for(plen)
            total = max(need, self.pool.blocks_for(plen + nxt.max_new - 1))
            if self.cache is not None:
                cplan = self.cache.plan(nxt.prompt)
                fresh = total - cplan.n_shared
                budget = self.pool.num_free + cplan.evictable
            else:
                cplan, fresh, budget = None, total, self.pool.num_free
            if budget - self._outstanding() < fresh:
                break        # strict FIFO: don't let short requests overtake
            self.waiting.popleft()
            hit = 0
            if cplan is not None:
                try:
                    hit = self.cache.admit(
                        nxt.req_id, nxt.prompt,
                        ensure_free=fresh + self._outstanding(),
                        plan=cplan)
                except PoolExhausted:     # plan/admit races can't happen in
                    self.waiting.appendleft(nxt)   # this loop; stay safe
                    break
            spliced = self.pool.n_blocks_of(nxt.req_id)   # shared + COW
            if need > spliced:
                self.pool.alloc(nxt.req_id, need - spliced)
            self._reserved[nxt.req_id] = total - need
            nxt.state = PREFILL
            nxt.t_admit = self._clock()
            nxt.n_prefix_hit = hit
            nxt.n_prefilled = hit
            nxt.n_cached = plen
            admitted.append(nxt)
            self.running.append(nxt)
        return admitted

    # -- chunked prefill --------------------------------------------------

    @property
    def prefilling(self) -> List[Request]:
        """Running requests still mid-prefill (chunked mode), oldest
        first."""
        return [r for r in self.running if r.state == PREFILL]

    def chunk_schedule(self, chunk_tokens: int,
                       budget: int = 0) -> List[Request]:
        """The prefilling requests to advance this step, oldest first,
        under a total per-step chunk-token ``budget`` (0 = uncapped; the
        engine's ``prefill_budget``). Without a budget every prefilling
        request deals one chunk per step — fine for a few long prompts,
        but a herd of them can make every step mostly prefill. The budget
        caps the *sum* of chunk tokens dealt per step; the oldest
        prefilling request is always scheduled even when its chunk alone
        exceeds the budget, so prefill always makes progress."""
        out: List[Request] = []
        spent = 0
        for req in self.prefilling:
            n = min(chunk_tokens, req.prompt_len - req.n_prefilled)
            if out and budget > 0 and spent + n > budget:
                break
            out.append(req)
            spent += n
        return out

    def next_chunk(self, req: Request, chunk_tokens: int):
        """Deal the next prefill chunk of ``req``: returns ``(start, n)``
        token coordinates into the prompt (``start`` = first uncached,
        not-yet-computed position; ``n <= chunk_tokens``, ragged only for
        the final chunk). The caller computes + scatters the chunk and
        then advances ``req.n_prefilled`` by ``n``. A PREFILL-state
        request always has uncached tokens left (cache hits are capped at
        ``prompt_len - 1`` and completion flips the state), so ``n >= 1``
        — asserted rather than signalled."""
        start = req.n_prefilled
        n = min(chunk_tokens, req.prompt_len - start)
        assert n > 0, f"request {req.req_id}: no prompt left to prefill"
        return start, n

    # -- decode-time block growth / preemption ----------------------------

    def ensure_decode_blocks(self) -> List[Request]:
        """Grow block tables for sequences at a block boundary, preempting
        the youngest running requests when the pool runs dry. Returns the
        requests preempted this step."""
        preempted: List[Request] = []
        for req in list(self.running):   # admission order = oldest first
            if req not in self.running:
                continue                 # already preempted below
            if req.state != DECODING:
                continue                 # mid-chunked-prefill: the prompt's
                #                          blocks were allocated at admission
            bs = self.pool.block_size
            if req.n_cached % bs != 0:
                continue                 # room in the last block
            if self.pool.n_blocks_of(req.req_id) * bs > req.n_cached:
                continue                 # table already covers the next
                #                          token: a retried call after a
                #                          transient fault must not grow a
                #                          request twice (idempotence)
            while True:
                try:
                    self.pool.append_block(req.req_id)
                    held = self._reserved.get(req.req_id, 0)
                    if held:
                        self._reserved[req.req_id] = held - 1
                    break
                except PoolExhausted:
                    # shed unreferenced cached blocks before sacrificing
                    # running work (cheapest memory in the system)
                    if self.cache is not None and \
                            self.cache.evict_until_free(1):
                        continue
                    if len(self.running) == 1:
                        raise RuntimeError(
                            "pool exhausted and nothing to preempt: "
                            "num_blocks too small for a single request")
                    victim = self.running[-1]   # youngest — may be req
                    self._preempt(victim)
                    preempted.append(victim)
                    if victim is req:
                        break            # req itself went back to the queue
        return preempted

    def _release(self, req: Request) -> int:
        """Give a leaving request's blocks back: through the cache when one
        is attached (prompt prefix stays resident in the tree), straight to
        the pool otherwise."""
        if self.cache is not None:
            return self.cache.release(req.req_id)
        return self.pool.free(req.req_id)

    def _preempt(self, req: Request) -> None:
        """Recompute-on-readmit: the request's generated tokens are
        discarded and its stream restarts from the first token after it is
        readmitted (identical for greedy; may differ for sampled requests).
        Streaming consumers observe the restart; a stream-reset event is a
        follow-up for the features that make preemption reachable. With a
        prefix cache the blocks are released to the tree, so readmission
        usually re-prefills only the last partial block."""
        self._release(req)
        self._reserved.pop(req.req_id, None)
        self.running.remove(req)
        req.state = QUEUED
        self.tokens_discarded += req.n_generated
        req.tokens = []                         # recompute on readmission
        req.n_generated = 0
        req.n_cached = 0
        req.n_prefix_hit = 0
        req.n_prefilled = 0
        req.t_last_token = 0.0       # readmission restarts the TPOT chain
        req.epoch += 1
        req.n_preemptions += 1
        self.n_preemptions += 1
        self.waiting.appendleft(req)

    def force_preempt(self, n: int) -> List[Request]:
        """Preempt the ``n`` youngest decoding requests regardless of pool
        pressure (fault injection's preemption storm; exercises exactly the
        organic preemption path)."""
        out: List[Request] = []
        for _ in range(n):
            victims = [r for r in self.running if r.state == DECODING]
            if not victims:
                break
            self._preempt(victims[-1])
            out.append(victims[-1])
        return out

    # -- cancellation -----------------------------------------------------

    def cancel(self, req_id: int,
               reason: str = FINISH_CANCELLED) -> Optional[Request]:
        """Terminate a queued or running request: release its blocks and
        radix pins, drop its reservation, and move it to ``finished`` with
        ``finish_reason=reason``. The epoch bump makes any in-flight
        sampled-token vector for it stale (the engine's drain discards by
        epoch), so cancellation is safe mid-prefill and mid-decode.
        Returns the request, or None when the id is not queued/running
        (already finished, or unknown) — cancel is idempotent."""
        for req in self.waiting:
            if req.req_id == req_id:
                self.waiting.remove(req)
                self._finish_with(req, reason)
                return req
        for req in self.running:
            if req.req_id == req_id:
                self._release(req)
                self._reserved.pop(req.req_id, None)
                self.running.remove(req)
                req.epoch += 1           # stale pending vectors discarded
                self._finish_with(req, reason)
                return req
        return None

    def _finish_with(self, req: Request, reason: str) -> None:
        req.state = FINISHED
        req.finish_reason = reason
        req.t_finish = self._clock()
        self.finished[req.req_id] = req

    # -- completion -------------------------------------------------------

    def evict_finished(self) -> List[Request]:
        done = [r for r in self.running if r.done]
        for req in done:
            self._publish_generated(req)
            self._release(req)
            self._reserved.pop(req.req_id, None)
            self.running.remove(req)
            self._finish_with(req, FINISH_LENGTH)
        return done

    def _publish_generated(self, req: Request) -> None:
        """Multi-turn reuse: before a finished request's blocks go back,
        publish its *generated* tokens to the tree too (the prompt was
        already published at prefill). The KV rows for the first
        ``n_cached - prompt_len`` generated tokens are pool-resident (the
        final sampled token was never fed back), so a follow-up prompt that
        extends [prompt ‖ reply] readmits as a near-full cache hit. Needs
        the token *values*: the engine drains the async pipeline before
        evicting finished requests whenever a cache is attached; if values
        are missing anyway (direct scheduler use), only the already-
        published prompt stays cached."""
        if self.cache is None or req.n_cached <= req.prompt_len:
            return
        n_gen_cached = req.n_cached - req.prompt_len
        if len(req.tokens) < n_gen_cached:
            return                       # values not materialized — skip
        self.cache.insert(req.req_id, np.concatenate(
            [req.prompt, np.asarray(req.tokens[:n_gen_cached], np.int32)]))
