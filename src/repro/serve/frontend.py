"""Asyncio front-end for the replica fleet: per-request token streams,
typed terminal results, and the fleet-level request tracker.

Shape follows the async-engine pattern ColossalAI popularized (an
``AsyncStream`` per request fed by a background engine loop, owned by a
``RequestTracker``), adapted to this repo's synchronous, deterministic
engines: the tracker itself is plain synchronous state (so the fleet is
drivable tick-by-tick from tests and benches with a ManualClock), and
``AsyncFrontend`` is the thin asyncio skin that drives supervision ticks
cooperatively and lets clients ``async for`` tokens.

The tracker is also where the PR 9 satellite fix for cross-replica
migration lives: the FLEET lifecycle stamps (``t_submit``,
``t_first_token``, ``t_finish``) belong to the tracked request, not to
any replica's telemetry, so TTFT is observed exactly once fleet-wide and
E2E always measures from the client's original submit — no matter how
many replicas a request visited. Per-replica telemetry keeps its own
(engine-local) view; the engine-side half of the same fix is the
``ttft_observed`` migration stamp threaded through submit.
"""
from __future__ import annotations

import asyncio
import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.serve.metrics import MetricRegistry
from repro.serve.scheduler import FINISH_LENGTH, Request

# fleet-level request states
PENDING, PLACED, DONE = "pending", "placed", "done"


@dataclasses.dataclass
class RequestResult:
    """Typed terminal result of one fleet request (what ``AsyncStream``
    resolves to). ``finish_reason`` uses the scheduler's FINISH_* values
    plus "rejected" (the fleet gave up placing it)."""

    req_id: int
    tokens: List[int]
    finish_reason: str
    n_failovers: int = 0
    replicas: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_finish: float = 0.0

    @property
    def ok(self) -> bool:
        return self.finish_reason == FINISH_LENGTH

    @property
    def e2e(self) -> float:
        return self.t_finish - self.t_submit


class AsyncStream:
    """Per-request token stream. The supervisor feeds it synchronously
    (``put``/``close``); clients consume either asynchronously
    (``async for token in stream`` then ``stream.result()``) or
    synchronously (``drain_nowait``/``result`` after the fleet drains).
    Single-loop discipline: produced and consumed on the same thread (the
    asyncio loop), so a deque + wakeup event suffices — no locking."""

    def __init__(self, req_id: int):
        self.req_id = req_id
        self._buf: Deque[int] = deque()
        self._result: Optional[RequestResult] = None
        self._event: Optional[asyncio.Event] = None   # lazy: created in
        #                                               async context only

    # -- producer side (tracker/supervisor) --------------------------------

    def put(self, tokens: List[int]) -> None:
        self._buf.extend(tokens)
        self._wake()

    def close(self, result: RequestResult) -> None:
        self._result = result
        self._wake()

    def _wake(self) -> None:
        if self._event is not None:
            self._event.set()

    # -- consumer side -----------------------------------------------------

    @property
    def finished(self) -> bool:
        return self._result is not None

    def result(self) -> Optional[RequestResult]:
        return self._result

    def drain_nowait(self) -> List[int]:
        out = list(self._buf)
        self._buf.clear()
        return out

    def __aiter__(self) -> "AsyncStream":
        return self

    async def __anext__(self) -> int:
        while True:
            if self._buf:
                return self._buf.popleft()
            if self._result is not None:
                raise StopAsyncIteration
            if self._event is None:
                self._event = asyncio.Event()
            self._event.clear()
            await self._event.wait()


@dataclasses.dataclass
class Assignment:
    """Where a tracked request currently runs: the replica index, the
    engine-side request id/handle, and ``resume_base`` — how many fleet
    tokens had already streamed when this placement's recompute prompt
    was built (engine token i is fleet position ``resume_base + i``)."""

    replica: int
    engine_rid: int
    handle: Request
    resume_base: int


@dataclasses.dataclass
class TrackedRequest:
    """Fleet-side state of one request: the authoritative client stream
    (``tokens``), the fleet lifecycle stamps, and the current placement."""

    rid: int
    prompt: np.ndarray
    max_new: int
    temperature: float = 0.0
    deadline_s: Optional[float] = None
    ttft_budget_s: Optional[float] = None
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_finish: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    stream: AsyncStream = None
    state: str = PENDING
    assignment: Optional[Assignment] = None
    attempts: int = 0                 # placements tried (incl. rejected)
    n_failovers: int = 0
    replicas: List[int] = dataclasses.field(default_factory=list)
    next_retry_tick: int = 0          # pending-queue backoff gate
    result: Optional[RequestResult] = None

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.tokens)

    def recompute_prompt(self) -> np.ndarray:
        """The failover prompt ``[prompt ‖ tokens-emitted-so-far]``:
        greedy decode is deterministic, so a survivor prefilling this and
        generating ``remaining`` tokens continues the stream byte-
        identically to the unfailed run."""
        if not self.tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.tokens, np.int32)])


class RequestTracker:
    """Owns every fleet request: streams, fleet lifecycle stamps, and the
    fleet-level metric registry (``fleet_*`` names, so they coexist with
    per-replica ``serve_*`` metrics inside one collected registry)."""

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        import time
        self.clock = clock or time.monotonic
        self.requests: Dict[int, TrackedRequest] = {}
        self.registry = MetricRegistry()
        r = self.registry
        self.c_submitted = r.counter(
            "fleet_requests_submitted_total", "requests accepted fleet-wide")
        self.c_completed = r.counter(
            "fleet_requests_completed_total", "requests finished (length)")
        self.c_failed = r.counter(
            "fleet_requests_failed_total",
            "requests with a non-length terminal (deadline/cancel/...)")
        self.c_failovers = r.counter(
            "fleet_failovers_total",
            "request re-placements caused by replica crash/hang")
        self.c_retries = r.counter(
            "fleet_placement_retries_total",
            "placement retries after a shed or a full fleet")
        self.c_tokens = r.counter(
            "fleet_tokens_streamed_total", "tokens delivered to clients")
        self.h_ttft = r.histogram(
            "fleet_ttft_seconds",
            "submit -> first token, fleet-wide (observed once per request "
            "regardless of migrations)")
        self.h_e2e = r.histogram(
            "fleet_e2e_seconds",
            "submit -> finish from the ORIGINAL submit (completions only)")
        self.c_recovered = r.counter(
            "fleet_requests_recovered_total",
            "requests adopted from a prior process's journal at resume")
        self.c_tail_lost = r.counter(
            "journal_tail_lost_total",
            "journal records dropped during crash recovery (torn tail)")
        self._next_rid = 0

    # -- lifecycle ---------------------------------------------------------

    def create(self, prompt: np.ndarray, max_new: int,
               temperature: float = 0.0,
               deadline_s: Optional[float] = None,
               ttft_budget_s: Optional[float] = None) -> TrackedRequest:
        rid = self._next_rid
        self._next_rid += 1
        treq = TrackedRequest(rid, np.asarray(prompt, np.int32), max_new,
                              temperature, deadline_s=deadline_s,
                              ttft_budget_s=ttft_budget_s,
                              t_submit=self.clock(),
                              stream=AsyncStream(rid))
        self.requests[rid] = treq
        self.c_submitted.inc()
        return treq

    def adopt(self, rid: int, prompt: np.ndarray, max_new: int,
              tokens: List[int], finish_reason: str = "",
              n_failovers: int = 0,
              temperature: float = 0.0) -> TrackedRequest:
        """Re-create a request from a prior process's journal, keeping its
        rid.  Terminal requests (``finish_reason`` set) are resolved
        immediately with the journaled stream; in-flight ones carry their
        already-streamed tokens (``t_first_token`` pre-stamped so TTFT is
        never observed twice — monotonic stamps don't survive process
        death, so cross-process latency is not re-measured) and are ready
        for placement through the failover path."""
        if rid in self.requests:
            raise ValueError(f"request {rid} already tracked")
        treq = TrackedRequest(rid, np.asarray(prompt, np.int32), max_new,
                              temperature, t_submit=self.clock(),
                              stream=AsyncStream(rid))
        treq.tokens = list(tokens)
        treq.n_failovers = n_failovers
        if treq.tokens:
            treq.t_first_token = treq.t_submit  # suppress double TTFT
            treq.stream.put(list(treq.tokens))
        self.requests[rid] = treq
        self._next_rid = max(self._next_rid, rid + 1)
        self.c_recovered.inc()
        if finish_reason:
            treq.state = DONE
            treq.t_finish = self.clock()
            treq.result = RequestResult(
                rid, list(treq.tokens), finish_reason,
                n_failovers=n_failovers, replicas=[],
                t_submit=treq.t_submit, t_finish=treq.t_finish)
            treq.stream.close(treq.result)
        return treq

    def on_tokens(self, treq: TrackedRequest, tokens: List[int]) -> None:
        """Append freshly streamed tokens; the FIRST ever token (across
        all placements) stamps fleet TTFT exactly once."""
        if not tokens:
            return
        if not treq.t_first_token:
            treq.t_first_token = self.clock()
            self.h_ttft.observe(treq.t_first_token - treq.t_submit)
        treq.tokens.extend(tokens)
        self.c_tokens.inc(len(tokens))
        treq.stream.put(tokens)

    def on_terminal(self, treq: TrackedRequest, reason: str) -> None:
        """Resolve the request with its typed terminal result. E2E is
        observed from the ORIGINAL submit, completions only (matching the
        per-replica telemetry convention)."""
        if treq.state == DONE:
            return
        treq.state = DONE
        treq.assignment = None
        treq.t_finish = self.clock()
        if reason == FINISH_LENGTH:
            self.c_completed.inc()
            self.h_e2e.observe(treq.t_finish - treq.t_submit)
        else:
            self.c_failed.inc()
        treq.result = RequestResult(
            treq.rid, list(treq.tokens), reason,
            n_failovers=treq.n_failovers, replicas=list(treq.replicas),
            t_submit=treq.t_submit, t_finish=treq.t_finish)
        treq.stream.close(treq.result)

    # -- queries -----------------------------------------------------------

    def live(self) -> List[TrackedRequest]:
        return [t for t in self.requests.values() if t.state != DONE]

    def assigned_to(self, replica: int) -> List[TrackedRequest]:
        return [t for t in self.requests.values()
                if t.assignment is not None
                and t.assignment.replica == replica]

    def has_work(self) -> bool:
        return any(t.state != DONE for t in self.requests.values())


class AsyncFrontend:
    """The asyncio skin over a FleetSupervisor: ``submit`` returns the
    request's ``AsyncStream``; one ``run()`` task drives supervision
    ticks cooperatively (yielding to consumers between ticks) until the
    fleet drains and the front-end is closed."""

    def __init__(self, supervisor):
        self.supervisor = supervisor
        self._closed = False

    async def submit(self, prompt: np.ndarray, max_new: int,
                     temperature: float = 0.0,
                     deadline_s: Optional[float] = None,
                     ttft_budget_s: Optional[float] = None) -> AsyncStream:
        treq = self.supervisor.submit(
            prompt, max_new, temperature, deadline_s=deadline_s,
            ttft_budget_s=ttft_budget_s)
        return treq.stream

    def close(self) -> None:
        """No more submissions: run() exits once in-flight work drains."""
        self._closed = True

    async def run(self, max_ticks: int = 100_000) -> None:
        ticks = 0
        while not (self._closed and not self.supervisor.has_work()):
            if self.supervisor.has_work():
                self.supervisor.tick()
                ticks += 1
                if ticks > max_ticks:
                    raise RuntimeError(
                        f"fleet did not drain within {max_ticks} ticks")
            await asyncio.sleep(0)

    async def run_until_drained(self, max_ticks: int = 100_000) -> None:
        self.close()
        await self.run(max_ticks=max_ticks)
