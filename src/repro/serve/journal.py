"""Write-ahead request journal for the fleet front-end.

Every externally-visible state change of a fleet request is appended to
the journal BEFORE it takes effect (write-ahead), so a crash of the
front-end itself — or a post-mortem of a replica failure — can
reconstruct exactly what every client was promised and what it received.
Record kinds (one JSON object per record; ``t`` is the fleet clock):

``submit``     {rid, prompt_len, max_new, t [, prompt]} — client accepted.
``placement``  {rid, replica, engine_rid, attempt, reason, resume_base, t}
               — the request was offered to a replica. ``attempt`` counts
               placements (0 = first); ``reason`` is "submit" for the
               first, then "crash"/"hang" (failover) or "retry" (backoff
               after a shed/full fleet); ``resume_base`` is how many
               tokens had already streamed when the recompute prompt
               ``[prompt ‖ tokens-so-far]`` was built.
``token``      {rid, replica, pos, toks, t} — ``toks`` streamed to the
               client; ``pos`` is the stream position of toks[0]
               (contiguity is validated by replay()).
``terminal``   {rid, reason, n_tokens, t} — the typed terminal result.
``replica``    {replica, event: crash|hang|resume, tick, t} — fleet
               health transitions (forensics; not part of request state).

``replay()`` folds the records back into per-request terminal state and
is the crash-consistency gate: the fleet bench asserts that the replayed
tokens and terminal reasons equal the live tracker's, byte for byte.

Host-side and allocation-light: one dict per record, optional JSONL file
sink flushed per append (the write-ahead property is only as strong as
the sink's durability; tests use the in-memory list).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional

RECORD_KINDS = ("submit", "placement", "token", "terminal", "replica")


class JournalCorrupt(RuntimeError):
    """replay() found records that cannot describe any real execution
    (unknown kind, token stream with a gap, terminal/token mismatch)."""


@dataclasses.dataclass
class ReplayedRequest:
    """One request's state as reconstructed from the journal."""

    rid: int
    prompt_len: int = 0
    max_new: int = 0
    prompt: Optional[List[int]] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    finish_reason: str = ""              # "" = still in flight at the
    #                                      journal's horizon
    placements: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def n_failovers(self) -> int:
        return sum(1 for p in self.placements
                   if p["reason"] in ("crash", "hang"))


@dataclasses.dataclass
class ReplayState:
    """The fold of a journal: request states + replica event history."""

    requests: Dict[int, ReplayedRequest] = dataclasses.field(
        default_factory=dict)
    replica_events: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def terminal(self) -> Dict[int, ReplayedRequest]:
        return {rid: r for rid, r in self.requests.items()
                if r.finish_reason}


class Journal:
    """Append-only journal with an in-memory record list and an optional
    JSONL file sink. ``append`` is called by the supervisor/tracker
    BEFORE the recorded action takes effect."""

    def __init__(self, path: Optional[str] = None,
                 clock: Optional[Callable[[], float]] = None,
                 log_prompts: bool = True):
        self.path = path
        self.clock = clock or time.monotonic
        self.log_prompts = log_prompts
        self.records: List[Dict] = []
        self._sink = open(path, "w") if path else None

    def append(self, kind: str, **fields) -> Dict:
        if kind not in RECORD_KINDS:
            raise ValueError(f"unknown journal record kind {kind!r}; "
                             f"expected one of {RECORD_KINDS}")
        rec = dict(kind=kind, t=round(self.clock(), 6), **fields)
        self.records.append(rec)
        if self._sink is not None:
            self._sink.write(json.dumps(rec) + "\n")
            self._sink.flush()
        return rec

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()
            self._sink = None

    # -- persistence -------------------------------------------------------

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            for rec in self.records:
                f.write(json.dumps(rec) + "\n")

    @classmethod
    def load(cls, path: str) -> "Journal":
        j = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    j.records.append(json.loads(line))
        return j

    # -- replay ------------------------------------------------------------

    def replay(self) -> ReplayState:
        return replay(self.records)


def replay(records: List[Dict]) -> ReplayState:
    """Fold journal records into per-request terminal state, validating
    the stream invariants a real execution must satisfy: token positions
    contiguous from 0, no tokens before submit or after terminal, and the
    terminal's ``n_tokens`` equal to the stream length."""
    st = ReplayState()
    for rec in records:
        kind = rec.get("kind")
        if kind == "submit":
            rid = rec["rid"]
            if rid in st.requests:
                raise JournalCorrupt(f"request {rid} submitted twice")
            st.requests[rid] = ReplayedRequest(
                rid, prompt_len=rec["prompt_len"], max_new=rec["max_new"],
                prompt=rec.get("prompt"))
        elif kind == "placement":
            req = _live(st, rec, "placement")
            req.placements.append({k: rec[k] for k in
                                   ("replica", "engine_rid", "attempt",
                                    "reason", "resume_base")})
        elif kind == "token":
            req = _live(st, rec, "token")
            if rec["pos"] != len(req.tokens):
                raise JournalCorrupt(
                    f"request {req.rid}: token record at pos {rec['pos']} "
                    f"but stream holds {len(req.tokens)} tokens")
            req.tokens.extend(rec["toks"])
        elif kind == "terminal":
            req = _live(st, rec, "terminal")
            if rec["n_tokens"] != len(req.tokens):
                raise JournalCorrupt(
                    f"request {req.rid}: terminal claims "
                    f"{rec['n_tokens']} tokens, stream holds "
                    f"{len(req.tokens)}")
            req.finish_reason = rec["reason"]
        elif kind == "replica":
            st.replica_events.append(rec)
        else:
            raise JournalCorrupt(f"unknown record kind {kind!r}")
    return st


def _live(st: ReplayState, rec: Dict, what: str) -> ReplayedRequest:
    rid = rec.get("rid")
    req = st.requests.get(rid)
    if req is None:
        raise JournalCorrupt(f"{what} record for unknown request {rid}")
    if req.finish_reason:
        raise JournalCorrupt(
            f"{what} record for request {rid} after its terminal")
    return req
