"""Write-ahead request journal for the fleet front-end.

Every externally-visible state change of a fleet request is appended to
the journal BEFORE it takes effect (write-ahead), so a crash of the
front-end itself — or a post-mortem of a replica failure — can
reconstruct exactly what every client was promised and what it received.
Record kinds (one JSON object per record; ``t`` is the fleet clock):

``submit``     {rid, prompt_len, max_new, t [, prompt]} — client accepted.
``placement``  {rid, replica, engine_rid, attempt, reason, resume_base, t}
               — the request was offered to a replica. ``attempt`` counts
               placements (0 = first); ``reason`` is "submit" for the
               first, then "crash"/"hang" (failover), "retry" (backoff
               after a shed/full fleet), or "restore" (cross-process
               resume); ``resume_base`` is how many tokens had already
               streamed when the recompute prompt ``[prompt ‖
               tokens-so-far]`` was built.
``token``      {rid, replica, pos, toks, t} — ``toks`` streamed to the
               client; ``pos`` is the stream position of toks[0]
               (contiguity is validated by replay()).
``terminal``   {rid, reason, n_tokens, t} — the typed terminal result.
``replica``    {replica, event: crash|hang|resume, tick, t} — fleet
               health transitions (forensics; not part of request state).
``snapshot``   {digest, t [, ...]} — durability anchor: the full replay
               fold at this point, embedded.  Replay from the last anchor
               is equivalent to replay from the start (``compact()``
               exploits this to bound journal growth); a mid-stream
               anchor whose digest disagrees with the running fold is a
               corruption signal.

``replay()`` folds the records back into per-request terminal state and
is the crash-consistency gate: the fleet bench asserts that the replayed
tokens and terminal reasons equal the live tracker's, byte for byte.

Durability: every record carries a monotone ``seq`` and a ``crc`` (CRC32
of the record minus the crc field, canonical JSON).  ``load(...,
strict=False)`` recovers the valid prefix of a crash-torn file — a
truncated final line, trailing garbage, or a duplicated tail drops only
the bad suffix, counted in ``tail_lost``/``dups_dropped`` rather than
poisoning replay.  The ``fsync`` policy ("none" | "interval" | "always")
trades tail-loss window against write amplification; "interval" (the
default) flushes per record and fsyncs every ``fsync_every`` records.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import zlib
from typing import Callable, Dict, List, Optional

RECORD_KINDS = ("submit", "placement", "token", "terminal", "replica",
                "snapshot")
FSYNC_POLICIES = ("none", "interval", "always")


class JournalCorrupt(RuntimeError):
    """replay() found records that cannot describe any real execution
    (unknown kind, token stream with a gap, terminal/token mismatch), or
    strict load found a record failing its CRC/sequence check."""


def record_crc(body: Dict) -> int:
    """CRC32 of a record's canonical JSON (sans the ``crc`` field itself).
    Canonical = sorted keys, no whitespace — stable across a JSON
    round-trip, so recomputing on a parsed record matches the original."""
    blob = json.dumps(body, sort_keys=True, separators=(",", ":"))
    return zlib.crc32(blob.encode("utf-8")) & 0xFFFFFFFF


@dataclasses.dataclass
class ReplayedRequest:
    """One request's state as reconstructed from the journal."""

    rid: int
    prompt_len: int = 0
    max_new: int = 0
    prompt: Optional[List[int]] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    finish_reason: str = ""              # "" = still in flight at the
    #                                      journal's horizon
    placements: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def n_failovers(self) -> int:
        return sum(1 for p in self.placements
                   if p["reason"] in ("crash", "hang"))


@dataclasses.dataclass
class ReplayState:
    """The fold of a journal: request states + replica event history."""

    requests: Dict[int, ReplayedRequest] = dataclasses.field(
        default_factory=dict)
    replica_events: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def terminal(self) -> Dict[int, ReplayedRequest]:
        return {rid: r for rid, r in self.requests.items()
                if r.finish_reason}


def state_digest(st: ReplayState) -> Dict:
    """JSON-compatible embedding of a ReplayState for anchor records."""
    return {
        "requests": {
            str(rid): {
                "prompt_len": r.prompt_len,
                "max_new": r.max_new,
                "prompt": r.prompt,
                "tokens": list(r.tokens),
                "finish_reason": r.finish_reason,
                "placements": list(r.placements),
            }
            for rid, r in st.requests.items()
        },
        "replica_events": list(st.replica_events),
    }


def _seed_state(digest: Dict) -> ReplayState:
    st = ReplayState()
    for rid, rec in digest.get("requests", {}).items():
        st.requests[int(rid)] = ReplayedRequest(
            rid=int(rid),
            prompt_len=rec["prompt_len"],
            max_new=rec["max_new"],
            prompt=rec.get("prompt"),
            tokens=list(rec["tokens"]),
            finish_reason=rec["finish_reason"],
            placements=list(rec["placements"]),
        )
    st.replica_events = list(digest.get("replica_events", []))
    return st


def _digests_equal(a: Dict, b: Dict) -> bool:
    return (json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True))


class Journal:
    """Append-only journal with an in-memory record list and an optional
    JSONL file sink. ``append`` is called by the supervisor/tracker
    BEFORE the recorded action takes effect."""

    def __init__(self, path: Optional[str] = None,
                 clock: Optional[Callable[[], float]] = None,
                 log_prompts: bool = True,
                 fsync: str = "interval",
                 fsync_every: int = 16):
        if fsync not in FSYNC_POLICIES:
            raise ValueError(f"unknown fsync policy {fsync!r}; "
                             f"expected one of {FSYNC_POLICIES}")
        self.path = path
        self.clock = clock or time.monotonic
        self.log_prompts = log_prompts
        self.fsync = fsync
        self.fsync_every = max(1, int(fsync_every))
        self.records: List[Dict] = []
        self.tail_lost = 0        # records dropped by non-strict load
        self.dups_dropped = 0     # duplicate-seq records dropped by load
        self._since_fsync = 0
        self._sink = open(path, "w") if path else None

    def append(self, kind: str, **fields) -> Dict:
        if kind not in RECORD_KINDS:
            raise ValueError(f"unknown journal record kind {kind!r}; "
                             f"expected one of {RECORD_KINDS}")
        rec = dict(kind=kind, t=round(self.clock(), 6),
                   seq=len(self.records), **fields)
        rec["crc"] = record_crc(rec)
        self.records.append(rec)
        if self._sink is not None:
            self._sink.write(json.dumps(rec) + "\n")
            if self.fsync == "always":
                self._sink.flush()
                os.fsync(self._sink.fileno())
            elif self.fsync == "interval":
                self._sink.flush()
                self._since_fsync += 1
                if self._since_fsync >= self.fsync_every:
                    os.fsync(self._sink.fileno())
                    self._since_fsync = 0
            # "none": leave it to stdio buffering — fastest, widest
            # tail-loss window; a crash loses everything unflushed.
        return rec

    def anchor(self, **fields) -> Dict:
        """Append a snapshot-anchor record embedding the current replay
        fold.  Replaying from this record onward reconstructs the same
        state as replaying the whole journal."""
        digest = state_digest(replay(self.records))
        return self.append("snapshot", digest=digest, **fields)

    def compact(self) -> int:
        """Drop every record before the last snapshot anchor (replay cost
        becomes O(suffix)).  Rewrites the file sink in place when one is
        attached.  Returns the number of records dropped; no-op (0) when
        the journal has no anchor."""
        idx = None
        for i in range(len(self.records) - 1, -1, -1):
            if self.records[i].get("kind") == "snapshot":
                idx = i
                break
        if idx is None or idx == 0:
            return 0
        dropped = idx
        self.records = self.records[idx:]
        if self._sink is not None:
            self._sink.close()
            self.save(self.path)
            self._sink = open(self.path, "a")
            self._since_fsync = 0
        return dropped

    def close(self) -> None:
        if self._sink is not None:
            self._sink.flush()
            try:
                os.fsync(self._sink.fileno())
            except OSError:
                pass
            self._sink.close()
            self._sink = None

    # -- persistence -------------------------------------------------------

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            for rec in self.records:
                f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())

    @classmethod
    def load(cls, path: str, strict: bool = True) -> "Journal":
        """Parse a journal file.

        ``strict=True`` (default): any malformed line, CRC failure, or
        non-monotone sequence number raises JournalCorrupt.

        ``strict=False``: valid-prefix recovery for crash-torn files —
        parsing stops at the first bad line and the dropped suffix is
        counted in ``tail_lost``; duplicated records (seq at or below the
        running maximum, e.g. a tail appended twice) are skipped and
        counted in ``dups_dropped``.  CRC/seq checks only apply to
        records that carry those fields, so pre-durability journals and
        hand-built record lists stay loadable — but once a file has
        shown CRC-stamped records, a CRC-less line is corruption (torn
        garbage that happens to parse), not a format downgrade.
        """
        j = cls()
        with open(path) as f:
            lines = f.readlines()
        last_seq: Optional[int] = None
        saw_crc = False
        for i, line in enumerate(lines):
            s = line.strip()
            if not s:
                continue
            try:
                rec = json.loads(s)
                if not isinstance(rec, dict):
                    raise ValueError("record is not a JSON object")
                if "crc" in rec:
                    saw_crc = True
                    body = {k: v for k, v in rec.items() if k != "crc"}
                    if record_crc(body) != rec["crc"]:
                        raise ValueError("record CRC mismatch")
                elif saw_crc:
                    raise ValueError("record missing CRC in a CRC-stamped "
                                     "journal")
            except ValueError as e:
                if strict:
                    raise JournalCorrupt(
                        f"{path}: line {i + 1}: {e}") from None
                j.tail_lost = sum(1 for rest in lines[i:] if rest.strip())
                break
            seq = rec.get("seq")
            if seq is not None and last_seq is not None and seq <= last_seq:
                if strict:
                    raise JournalCorrupt(
                        f"{path}: line {i + 1}: duplicate/out-of-order "
                        f"seq {seq} after {last_seq}")
                j.dups_dropped += 1
                continue
            if seq is not None:
                last_seq = seq
            j.records.append(rec)
        return j

    # -- replay ------------------------------------------------------------

    def replay(self, from_anchor: bool = False) -> ReplayState:
        """Fold the records.  ``from_anchor=True`` replays only from the
        last snapshot anchor (the compaction invariant guarantees the
        same result as a full replay; the bounded-suffix path)."""
        records = self.records
        if from_anchor:
            for i in range(len(records) - 1, -1, -1):
                if records[i].get("kind") == "snapshot":
                    records = records[i:]
                    break
        return replay(records)


def replay(records: List[Dict]) -> ReplayState:
    """Fold journal records into per-request terminal state, validating
    the stream invariants a real execution must satisfy: token positions
    contiguous from 0, no tokens before submit or after terminal, and the
    terminal's ``n_tokens`` equal to the stream length.  A snapshot
    anchor at the head seeds the fold; one mid-stream must agree with the
    running fold (disagreement means the journal and the snapshot
    describe different histories)."""
    st = ReplayState()
    seeded_or_folded = False
    for rec in records:
        kind = rec.get("kind")
        if kind == "submit":
            rid = rec["rid"]
            if rid in st.requests:
                raise JournalCorrupt(f"request {rid} submitted twice")
            st.requests[rid] = ReplayedRequest(
                rid, prompt_len=rec["prompt_len"], max_new=rec["max_new"],
                prompt=rec.get("prompt"))
            seeded_or_folded = True
        elif kind == "placement":
            req = _live(st, rec, "placement")
            req.placements.append({k: rec[k] for k in
                                   ("replica", "engine_rid", "attempt",
                                    "reason", "resume_base")})
        elif kind == "token":
            req = _live(st, rec, "token")
            if rec["pos"] != len(req.tokens):
                raise JournalCorrupt(
                    f"request {req.rid}: token record at pos {rec['pos']} "
                    f"but stream holds {len(req.tokens)} tokens")
            req.tokens.extend(rec["toks"])
        elif kind == "terminal":
            req = _live(st, rec, "terminal")
            if rec["n_tokens"] != len(req.tokens):
                raise JournalCorrupt(
                    f"request {req.rid}: terminal claims "
                    f"{rec['n_tokens']} tokens, stream holds "
                    f"{len(req.tokens)}")
            req.finish_reason = rec["reason"]
        elif kind == "replica":
            st.replica_events.append(rec)
        elif kind == "snapshot":
            digest = rec.get("digest", {})
            if not seeded_or_folded and not st.requests:
                st = _seed_state(digest)
                seeded_or_folded = True
            elif not _digests_equal(digest, state_digest(st)):
                raise JournalCorrupt(
                    "snapshot anchor digest disagrees with the replayed "
                    "state at its position")
        else:
            raise JournalCorrupt(f"unknown record kind {kind!r}")
    return st


def _live(st: ReplayState, rec: Dict, what: str) -> ReplayedRequest:
    rid = rec.get("rid")
    req = st.requests.get(rid)
    if req is None:
        raise JournalCorrupt(f"{what} record for unknown request {rid}")
    if req.finish_reason:
        raise JournalCorrupt(
            f"{what} record for request {rid} after its terminal")
    return req
