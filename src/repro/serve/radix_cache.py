"""Radix-tree prefix cache over the paged softermax KV block pool.

Softermax's online-normalization decode (PAPER.md §online softmax) makes
attention a pure function of the cached KV blocks, so any prompt prefix that
is already resident in ``PagedKVCache`` can be reused bit-for-bit instead of
re-prefilled. This module indexes the pool with a radix tree keyed on
**block-aligned token chunks**: each tree node owns exactly one physical
block and carries the ``block_size`` token ids whose K/V fill it. A node
whose key is shorter than ``block_size`` is a *partial tail* — a leaf whose
block holds valid K/V only for its first ``len(key)`` rows (rows beyond may
hold the original owner's decode junk; every reader masks by length).

Sharing protocol (SGLang-RadixAttention-style tree + vLLM-style refcounted
blocks):

* ``lookup(tokens)``   — read-only longest-prefix match, capped at
  ``len(tokens) - 1`` so prefill always recomputes at least the final prompt
  token (its logits seed decoding).
* ``admit(req_id, …)`` — pin the matched path (eviction-proof while the
  request runs), evict LRU/FIFO unreferenced blocks until the uncached part
  of the trajectory fits, splice the matched full blocks into the request's
  pool table (+1 ref each), and **copy-on-write** a matched partial tail:
  the cached block is device-copied into a fresh block owned by the request,
  which then keeps writing rows where the copy left off while the cached
  original stays intact for other matches.
* ``insert(req_id, …)`` — called right after prefill scatter: the request's
  full prompt blocks (and its partial prompt tail) are published to the
  tree immediately, so concurrent requests share with in-flight ones —
  no need to wait for the first holder to finish. Chunks already present
  keep the incumbent node; the request's duplicate block simply drops back
  to the free list when the request releases.
* ``release(req_id)``  — finish/preempt: unpin the request's path and drop
  its table references. Blocks owned by the tree stay cached (refcount
  ≥ 1) — this is what "release prefixes back to the tree instead of
  freeing" means — and become evictable once no running request pins them.
* ``evict(n)``         — walk childless unpinned nodes in LRU (or FIFO
  insertion) order, dropping their tree reference; a block leaves the pool
  only when its refcount hits zero.

All of this is host-side metadata; the only device work is the COW block
copy. Correctness invariant (checked by the hypothesis property test):

    pool.refcount(b) == #request tables containing b + (1 if a tree node
    owns b else 0)   and   a block is on the free list iff refcount == 0.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.serve.kv_pool import PagedKVCache, PoolExhausted

EVICT_POLICIES = ("lru", "fifo")


@dataclasses.dataclass
class CacheStats:
    lookup_tokens: int = 0     # prompt tokens run through lookup/admit
    hit_tokens: int = 0        # prompt tokens served from the tree
    hits: int = 0              # admissions with a non-empty match
    misses: int = 0
    inserts: int = 0           # blocks donated to the tree
    evictions: int = 0         # blocks evicted from the tree
    purged_blocks: int = 0     # nodes dropped by quarantine purges
    # (COW copies are counted once, at the source: PoolStats.cow_copies)

    @property
    def hit_rate(self) -> float:
        return self.hit_tokens / max(self.lookup_tokens, 1)


class RadixNode:
    """One cached physical block. ``key`` holds the token ids whose K/V fill
    the block (len == block_size for interior/full nodes; shorter for a
    partial tail leaf, which is never descended through)."""

    __slots__ = ("key", "block", "parent", "children", "ref", "stamp", "seq")

    def __init__(self, key: Tuple[int, ...], block: int,
                 parent: Optional["RadixNode"], seq: int):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: Dict[Tuple[int, ...], RadixNode] = {}
        self.ref = 0             # running requests pinning this node
        self.stamp = seq         # last touch (LRU priority)
        self.seq = seq           # insertion order (FIFO priority)

    def __repr__(self) -> str:  # debugging aid
        return (f"RadixNode(block={self.block}, len={len(self.key)}, "
                f"ref={self.ref}, children={len(self.children)})")


@dataclasses.dataclass
class MatchResult:
    path: List[RadixNode]              # full-block nodes, root-to-leaf order
    partial: Optional[RadixNode]       # node whose block seeds the COW tail
    tail_tokens: int                   # leading rows of ``partial`` reused
    n_tokens: int                      # total matched tokens

    @property
    def n_full_blocks(self) -> int:
        return len(self.path)


@dataclasses.dataclass
class AdmitPlan:
    hit_tokens: int     # prompt tokens a match would reuse
    n_shared: int       # full blocks spliced by reference
    n_cow: int          # fresh blocks needed for a copy-on-write tail (0/1)
    evictable: int      # cached blocks eviction could shed for this admit
    match: MatchResult  # the underlying match; hand the plan to admit() to
                        # avoid re-walking the tree (valid only while the
                        # tree is unmutated)


class RadixCache:
    def __init__(self, pool: PagedKVCache, evict_policy: str = "lru"):
        if evict_policy not in EVICT_POLICIES:
            raise ValueError(f"evict_policy must be one of {EVICT_POLICIES},"
                             f" got {evict_policy!r}")
        self.pool = pool
        self.bs = pool.block_size
        self.evict_policy = evict_policy
        self.root = RadixNode((), 0, None, 0)
        self._held: Dict[int, List[RadixNode]] = {}   # req_id -> pinned path
        # per-request publish cursor: (deepest full-block node inserted,
        # tokens covered by it). Progressive chunked-prefill publishing
        # calls insert() once per chunk with an ever-longer prefix of the
        # same sequence; resuming from the cursor keeps the total publish
        # work O(prompt) instead of O(prompt^2 / chunk). Cursor nodes are
        # pinned by the same request, so eviction cannot invalidate them;
        # release() drops the cursor with the pins.
        self._cursor: Dict[int, Tuple[RadixNode, int]] = {}
        self._clock = 0
        self.stats = CacheStats()

    # -- clock ------------------------------------------------------------

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _touch(self, node: RadixNode) -> None:
        node.stamp = self._tick()

    # -- introspection ----------------------------------------------------

    def _walk(self) -> List[RadixNode]:
        out, stack = [], [self.root]
        while stack:
            nd = stack.pop()
            for ch in nd.children.values():
                out.append(ch)
                stack.append(ch)
        return out

    @property
    def cached_blocks(self) -> int:
        """Physical blocks currently owned by the tree."""
        return len(self._walk())

    def evictable_blocks(self) -> int:
        """Tree blocks reclaimable right now: nodes no running request pins.
        (Pinning refs every node on a request's path, so an unpinned node
        never has a pinned descendant and the whole unpinned frontier can be
        evicted leaf-first.)"""
        return sum(1 for nd in self._walk() if nd.ref == 0)

    # -- matching ---------------------------------------------------------

    def _match(self, tokens: Sequence[int]) -> MatchResult:
        toks = tokens.tolist() if isinstance(tokens, np.ndarray) else \
            [int(t) for t in tokens]
        limit = len(toks) - 1       # always leave >= 1 token to recompute
        node, path, matched = self.root, [], 0
        while matched + self.bs <= limit:
            # a bs-length lookup key can only hit a full-block node:
            # children are keyed by their own (shorter, for partials) keys
            child = node.children.get(tuple(toks[matched:matched + self.bs]))
            if child is None:
                break
            path.append(child)
            node = child
            matched += self.bs
        # Tail: ANY child block (full or partial) whose key shares a leading
        # run with the remaining tokens seeds a copy-on-write tail — the
        # copy's first `run` rows are valid, the request overwrites onward.
        rem = toks[matched:limit]
        best, best_run = None, 0
        for key, child in node.children.items():
            run = 0
            for a, b in zip(key, rem):
                if a != b:
                    break
                run += 1
            if run > best_run:
                best, best_run = child, run
        matched += best_run
        return MatchResult(path, best if best_run else None, best_run,
                           matched)

    def lookup(self, tokens: Sequence[int]) -> int:
        """Read-only longest-prefix match; returns reusable token count."""
        return self._match(tokens).n_tokens

    def plan(self, tokens: Sequence[int]) -> "AdmitPlan":
        """Size an admission without mutating anything: how many tokens a
        match would reuse, how many blocks it would splice by reference,
        whether it needs a copy-on-write tail block, and how many cached
        blocks eviction could shed for it (the matched path excluded —
        ``admit`` pins it)."""
        m = self._match(tokens)
        return AdmitPlan(m.n_tokens, len(m.path),
                         1 if m.partial is not None else 0,
                         self._sheddable(m), m)

    def _sheddable(self, m: MatchResult) -> int:
        matched = {id(nd) for nd in m.path}
        if m.partial is not None:
            matched.add(id(m.partial))
        return sum(1 for nd in self._walk()
                   if nd.ref == 0 and id(nd) not in matched)

    # -- admission --------------------------------------------------------

    def admit(self, req_id: int, tokens: np.ndarray,
              ensure_free: int = 0,
              plan: Optional[AdmitPlan] = None) -> int:
        """Match ``tokens`` against the tree and splice the hit into the
        request's pool table: shared full blocks by reference, a matched
        partial tail by copy-on-write into a fresh block. Evicts unpinned
        cached blocks (policy order) until at least
        ``max(ensure_free, 1-if-COW)`` blocks are free, so the COW
        allocation itself can never fail mid-flight. Pass the ``plan`` this
        admission was sized with (tree unmutated since) to skip re-matching
        and re-walking the tree. Returns the prompt tokens the engine may
        skip at prefill.

        Raises ``PoolExhausted`` — leaving no state behind — if eviction
        cannot reach the free-block target.
        """
        m = plan.match if plan is not None else self._match(tokens)
        target = max(ensure_free, 1 if m.partial is not None else 0)
        # Feasibility first: everything the tree can shed, minus our own
        # matched path (we are about to pin it).
        sheddable = plan.evictable if plan is not None else \
            self._sheddable(m)
        if self.pool.num_free + sheddable < target:
            raise PoolExhausted(
                f"admit req {req_id}: need {target} free "
                f"blocks, have {self.pool.num_free} + {sheddable} evictable")
        # Pin the matched path so eviction cannot take it out from under us.
        held = self._held.setdefault(req_id, [])
        for nd in m.path:
            nd.ref += 1
            self._touch(nd)
            held.append(nd)
        if m.partial is not None:
            m.partial.ref += 1
            self._touch(m.partial)
        try:
            self._ensure_free(target)
        except PoolExhausted:
            for nd in m.path:           # roll the pins back
                nd.ref -= 1
                held.remove(nd)
            if m.partial is not None:
                m.partial.ref -= 1
            if not held:
                self._held.pop(req_id, None)
            raise
        # Splice shared full blocks, then COW the partial tail (cannot
        # fail: the target above reserved its block).
        if m.path:
            self.pool.share(req_id, [nd.block for nd in m.path])
        if m.partial is not None:
            (dst,) = self.pool.alloc(req_id, 1)
            self.pool.copy_block(m.partial.block, dst)
            m.partial.ref -= 1          # copy done; the leaf is free again
        self.stats.lookup_tokens += len(tokens)
        if m.n_tokens:
            self.stats.hits += 1
            self.stats.hit_tokens += m.n_tokens
        else:
            self.stats.misses += 1
        return m.n_tokens

    def _ensure_free(self, target: int) -> None:
        if not self.evict_until_free(target):
            raise PoolExhausted(
                f"prefix cache: cannot evict down to {target} free blocks")

    # -- publication ------------------------------------------------------

    def _promote(self, node: RadixNode, block: int, new_key: Tuple[int, ...]
                 ) -> Optional[RadixNode]:
        """Re-key a child of ``node`` in place: a partial leaf of OURS that
        already owns ``block`` (published before the block filled up) whose
        key is a strict prefix of ``new_key`` — the missing rows have been
        written since (later chunks / generated tokens), so extending the
        key keeps one tree owner per physical block instead of donating a
        duplicate. Returns the promoted node, or None if there is none."""
        for ch in list(node.children.values()):
            if ch.block == block and 0 < len(ch.key) < len(new_key) and \
                    new_key[:len(ch.key)] == ch.key:
                del node.children[ch.key]
                ch.key = new_key
                node.children[new_key] = ch
                return ch
        return None

    def insert(self, req_id: int, tokens: Sequence[int]) -> int:
        """Publish a freshly prefilled request's prompt blocks to the tree
        (full blocks as interior nodes, the partial prompt tail as a leaf)
        and pin its whole path. Chunks already cached keep the incumbent
        node — the request's duplicate block is simply not donated and
        falls back to the free list at release. Returns blocks donated.

        Idempotent under re-insertion of a longer sequence (progressive
        chunked-prefill publishing, generated tokens at finish): a shorter
        partial-tail leaf of the same request is promoted in place rather
        than double-owned, and the walk resumes from this request's
        publish cursor — each call only converts and walks the tokens
        beyond what it already published (callers always pass extensions
        of their own earlier inserts: prefixes of [prompt ‖ reply])."""
        n = len(tokens)
        node, skip = self._cursor.get(req_id, (self.root, 0))
        if skip > n:                     # defensive: never shrink
            node, skip = self.root, 0
        # np fast path: tolist() is C-speed; only the unpublished delta is
        # converted, keeping progressive publishing O(prompt) overall
        tail_toks = tokens[skip:]
        toks = tail_toks.tolist() if isinstance(tail_toks, np.ndarray) \
            else [int(t) for t in tail_toks]
        table = self.pool.blocks_of(req_id)
        held = self._held.setdefault(req_id, [])
        held_ids: Set[int] = {id(nd) for nd in held}
        donated = 0
        n_full = n // self.bs
        skip_full = skip // self.bs      # cursor is always block-aligned
        for i in range(skip_full, n_full):
            chunk = tuple(toks[i * self.bs - skip:(i + 1) * self.bs - skip])
            child = node.children.get(chunk)
            if child is None:
                child = self._promote(node, table[i], chunk)
            if child is None:
                child = RadixNode(chunk, table[i], node, self._tick())
                node.children[chunk] = child
                self.pool.incref(table[i])
                donated += 1
            self._touch(child)
            if id(child) not in held_ids:
                child.ref += 1
                held.append(child)
                held_ids.add(id(child))
            node = child
        self._cursor[req_id] = (node, n_full * self.bs)
        tail = tuple(toks[n_full * self.bs - skip:])
        if tail:
            # any child (full block or partial) whose key extends the tail
            # already serves these rows — donating ours would cache them
            # twice and waste a pool block
            covered = any(len(ch.key) >= len(tail) and
                          ch.key[:len(tail)] == tail
                          for ch in node.children.values())
            if not covered:
                leaf = self._promote(node, table[n_full], tail)
                if leaf is None:
                    leaf = RadixNode(tail, table[n_full], node, self._tick())
                    node.children[tail] = leaf
                    self.pool.incref(table[n_full])
                    donated += 1
                self._touch(leaf)
                if id(leaf) not in held_ids:
                    leaf.ref += 1
                    held.append(leaf)
                    held_ids.add(id(leaf))
                # drop now-redundant shorter partials nobody is using
                # (housekeeping, not memory pressure: stats.evictions
                # deliberately not bumped)
                for ch in list(node.children.values()):
                    if ch is not leaf and 0 < len(ch.key) < len(tail) and \
                            ch.ref == 0 and not ch.children and \
                            tail[:len(ch.key)] == ch.key:
                        self._drop_node(ch, count_eviction=False)
        self.stats.inserts += donated
        return donated

    # -- release ----------------------------------------------------------

    def release(self, req_id: int) -> int:
        """Finish/preempt: unpin the request's path and drop its table
        references. Cached blocks stay in the tree (and become evictable
        once unpinned); blocks only the request owned return to the free
        list. Returns the number of blocks actually freed."""
        for nd in self._held.pop(req_id, []):
            nd.ref -= 1
        self._cursor.pop(req_id, None)
        return self.pool.free(req_id)

    # -- quarantine -------------------------------------------------------

    def purge(self, req_id: int) -> int:
        """Quarantine support: detach from the tree every node owning one
        of ``req_id``'s table blocks, **and the node's whole subtree** —
        descendants extend the poisoned prefix, so KV that was computed
        attending the corrupted blocks must go too. Detached nodes drop
        their tree reference (the block frees once no table holds it);
        nodes other requests still pin are detached all the same — their
        pins unwind normally at release (``release`` only decrements
        ``nd.ref``, never touches tree structure), but no FUTURE admission
        can match the poisoned path. Returns nodes purged. The caller
        (``ContinuousEngine._quarantine``) cancels the request afterwards,
        which releases its pins and frees its table."""
        table = set(self.pool._tables.get(req_id, ()))
        if not table:
            return 0
        purged = 0
        # collect the topmost poisoned nodes, then drop each subtree
        # post-order (re-check parentage: an earlier drop may have already
        # taken a descendant's whole subtree)
        roots = [nd for nd in self._walk() if nd.block in table]
        for nd in roots:
            if nd.parent is None or \
                    nd.parent.children.get(nd.key) is not nd:
                continue             # already detached with an ancestor
            purged += self._drop_subtree(nd)
        self.stats.purged_blocks += purged
        # publish cursors may now point at detached nodes; drop every
        # cursor whose node is no longer reachable so later inserts
        # republish from the root instead of into a detached subtree
        live = {id(n) for n in self._walk()}
        live.add(id(self.root))
        for rid, (node, _skip) in list(self._cursor.items()):
            if id(node) not in live:
                self._cursor.pop(rid)
        return purged

    def _drop_subtree(self, nd: RadixNode) -> int:
        """Detach ``nd`` and every descendant, dropping each node's tree
        reference on its block (post-order)."""
        n = 0
        for ch in list(nd.children.values()):
            n += self._drop_subtree(ch)
        del nd.parent.children[nd.key]
        self.pool.decref(nd.block)
        return n + 1

    # -- eviction ---------------------------------------------------------

    def _priority(self, nd: RadixNode) -> int:
        return nd.stamp if self.evict_policy == "lru" else nd.seq

    def _drop_node(self, nd: RadixNode, count_eviction: bool = True) -> None:
        del nd.parent.children[nd.key]
        self.pool.decref(nd.block)
        if count_eviction:
            self.stats.evictions += 1

    def _evict_while(self, keep_going) -> int:
        """Shared eviction walk: pop childless unpinned nodes in policy
        order while ``keep_going()`` is true; parents re-enter the one heap
        as their subtree drains (no per-block tree re-walks)."""
        heap: List[Tuple[int, int, RadixNode]] = []
        tiebreak = 0
        for nd in self._walk():
            if not nd.children and nd.ref == 0:
                heap.append((self._priority(nd), tiebreak := tiebreak + 1,
                             nd))
        heapq.heapify(heap)
        evicted = 0
        while heap and keep_going(evicted):
            _, _, nd = heapq.heappop(heap)
            parent = nd.parent
            self._drop_node(nd)
            evicted += 1
            if parent is not self.root and not parent.children and \
                    parent.ref == 0:
                heapq.heappush(heap, (self._priority(parent),
                                      tiebreak := tiebreak + 1, parent))
        return evicted

    def evict(self, n: int) -> int:
        """Evict up to ``n`` cached blocks (childless, unpinned nodes first,
        in policy order). Returns the number of blocks evicted."""
        return self._evict_while(lambda done: done < n)

    def evict_until_free(self, target: int) -> bool:
        """Evict until the pool has ``target`` free blocks (an evicted
        node's block only frees once no request references it, so this may
        pop several nodes per freed block — one heap, no tree re-walks).
        Returns True when the target was reached."""
        self._evict_while(lambda _done: self.pool.num_free < target)
        return self.pool.num_free >= target

    def reset(self) -> int:
        """Drop the entire tree (requires no pinned paths — i.e. no running
        requests). Used by ``ContinuousEngine.warmup`` to flush the
        synthetic workload's cache entries."""
        if any(self._held.values()):     # empty pin lists are hygiene, not
            #                              running work (admit() can leave
            #                              a req's entry behind with no pins)
            raise RuntimeError("reset() with running requests still pinned")
        self._held.clear()
        self._cursor.clear()
        dropped = 0
        for nd in self._walk():
            self.pool.decref(nd.block)
            dropped += 1
        self.root = RadixNode((), 0, None, 0)
        return dropped
