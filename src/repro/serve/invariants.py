"""Pool/radix-tree consistency contract, promoted from the PR 2 property
test into the library so the resilience benchmark (and any harness) can
assert it mid-flight, not just under pytest.

The contract (documented in kv_pool.py / radix_cache.py):

    refcount(b) == #request tables containing b + (1 if a tree node owns b)
    a block is on the free list  iff  refcount(b) == 0
    block 0 (the garbage block) is never on the free list or in the tree
    no two tree nodes own one physical block
    node.ref == #running requests pinning the node
    partial-tail nodes (key shorter than block_size) are childless

``check_invariants`` raises ``InvariantViolation`` on the first breach;
``tests/test_prefix_cache.py`` drives it through random interleavings and
``benchmarks/resilience_bench.py`` asserts it after every step of the
fault-injected runs.
"""
from __future__ import annotations

from typing import Optional

from repro.serve.kv_pool import PagedKVCache
from repro.serve.radix_cache import RadixCache


class InvariantViolation(AssertionError):
    """The pool/tree bookkeeping contract was broken."""


def _fail(msg: str) -> None:
    raise InvariantViolation(msg)


def check_invariants(pool: PagedKVCache,
                     cache: Optional[RadixCache] = None) -> None:
    """Assert the full refcount/free-list/tree contract. O(blocks + tree);
    meant for tests and benches, not the serving hot path."""
    N = pool.num_blocks
    free = pool._free
    if len(set(free)) != len(free):
        _fail("duplicate free-list entries")
    if 0 in free:
        _fail("garbage block 0 leaked into the free list")
    table_blocks = [b for t in pool._tables.values() for b in t]
    tree_nodes = cache._walk() if cache is not None else []
    tree_blocks = [nd.block for nd in tree_nodes]
    if len(set(tree_blocks)) != len(tree_blocks):
        _fail("two tree nodes own one physical block")
    if 0 in tree_blocks:
        _fail("garbage block 0 owned by a tree node")
    free_set, tree_set = set(free), set(tree_blocks)
    for b in range(1, N + 1):
        rc = pool.refcount(b)
        expect = table_blocks.count(b) + (1 if b in tree_set else 0)
        if rc != expect:
            _fail(f"block {b}: refcount {rc} != tables+tree {expect}")
        if (b in free_set) != (rc == 0):
            _fail(f"block {b}: rc {rc} but free={b in free_set}")
    if pool.stats.blocks_in_use != N - len(free):
        _fail(f"blocks_in_use {pool.stats.blocks_in_use} != "
              f"{N - len(free)}")
    if cache is not None:
        pins = {}
        for nodes in cache._held.values():
            for nd in nodes:
                pins[id(nd)] = pins.get(id(nd), 0) + 1
        for nd in tree_nodes:
            if nd.ref != pins.get(id(nd), 0):
                _fail(f"node {nd!r}: ref {nd.ref} != pins "
                      f"{pins.get(id(nd), 0)}")
            if 0 < len(nd.key) < cache.bs and nd.children:
                _fail("partial tail node has children")


def leaked_blocks(pool: PagedKVCache,
                  cache: Optional[RadixCache] = None) -> int:
    """Blocks neither free nor tree-owned at quiescence (no request
    tables) — must be 0 (the zero-leak gate). With tables still resident
    this counts every block some live request holds, so call it only
    after the engine drained."""
    cached = cache.cached_blocks if cache is not None else 0
    return pool.num_blocks - pool.num_free - cached
