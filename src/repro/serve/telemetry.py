"""Serving observability: per-request lifecycle tracing, step timelines,
and online numerics monitors, publishing into ``serve/metrics.py``.

The engine owns at most one ``Telemetry`` instance (``telemetry=None`` — the
default — keeps every hot path at a single ``is not None`` check, which is
what makes the disabled mode free). When attached, the engine calls the
``on_*`` hooks at the lifecycle points below; everything else here is
host-side bookkeeping — no device work happens in any hook.

    submit ──► admit ──► prefill[-chunk|-suffix]* ──► first token ──►
      decode token* ──► finish
                 ▲                                        │
                 └──────────────── preempt ◄──────────────┘

**Per-request tracing** (``RequestTrace``): monotonic timestamps for every
lifecycle edge, queue-wait (submit→admit), TTFT (submit→first token), TPOT
(decode-token gaps), E2E (submit→finish), prefix-hit tokens and preemption
count. Aggregates stream into fixed log-bucket histograms (p50/p90/p99
without per-sample storage); the full per-token event list is kept only on
the traced requests themselves and is bounded by ``max_new``.

**Step timeline** (``StepTimeline``): one Chrome trace-event record per
engine phase — prefill/prefill-chunk/prefill-suffix/decode/drain — with
batch rows, the table-width bucket chosen, the split-K/tile grid knobs,
and host↔device sync duration in the args. ``save_chrome_trace`` writes
the standard ``{"traceEvents": [...]}`` JSON that chrome://tracing and
Perfetto load directly. Engine phases land on tid 0; request lifecycle
instants land on tid = req_id so Perfetto shows one lane per request.

**Clock injection**: all timestamps come from ``Telemetry.clock`` (default
``time.monotonic``); ``ManualClock`` makes tests fully deterministic.

**Online numerics monitors** (``numerics_every > 0`` on an int8 engine):
every Nth completed prefill re-runs that request's prompt prefix through
``serve/paged_step.paged_prefill_audit`` — a lockstep full-precision vs
int8-fake-quant forward (PR 4's bounded-logit-error probe, made a live
gauge) that also counts Softermax IntMax overflows against the paper's
Q(6,2) LocalMax format and K/V rows that would saturate a static
percentile-calibrated int8 scale. The paper's "negligible accuracy
impact" claim becomes ``numerics_logit_error_max`` on a running server.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.serve.metrics import MetricRegistry

Clock = Callable[[], float]


class ManualClock:
    """Deterministic clock for tests: every reading advances by ``tick``
    (so durations are non-zero and reproducible); ``advance`` jumps."""

    def __init__(self, start: float = 0.0, tick: float = 0.0):
        self.t = float(start)
        self.tick = float(tick)

    def __call__(self) -> float:
        t = self.t
        self.t += self.tick
        return t

    def advance(self, dt: float) -> None:
        self.t += dt


@dataclasses.dataclass
class RequestTrace:
    """Lifecycle record of one request (one line of the trace export)."""

    req_id: int
    prompt_len: int = 0
    max_new: int = 0
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_finish: float = 0.0
    n_prefix_hit: int = 0
    n_preemptions: int = 0
    n_tokens: int = 0
    prefill_chunks: int = 0
    # how the request left the engine: "" while live, then "length" /
    # "cancelled" / "deadline" / "quarantined" / "shed" (terminal states)
    finish_reason: str = ""
    # (event name, timestamp) — submit/admit/prefill*/token/preempt/finish;
    # bounded by the request's own lifetime (≤ max_new token events)
    events: List[Tuple[str, float]] = dataclasses.field(default_factory=list)

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.t_submit if self.t_admit else 0.0

    @property
    def ttft(self) -> float:
        return (self.t_first_token - self.t_submit
                if self.t_first_token else 0.0)

    @property
    def e2e(self) -> float:
        return self.t_finish - self.t_submit if self.t_finish else 0.0

    @property
    def tpot_mean(self) -> float:
        """Mean decode-token gap (dispatch-time convention, like TTFT)."""
        if self.n_tokens <= 1 or not self.t_finish:
            return 0.0
        return (self.t_finish - self.t_first_token) / (self.n_tokens - 1)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["queue_wait"] = self.queue_wait
        d["ttft"] = self.ttft
        d["e2e"] = self.e2e
        d["tpot_mean"] = self.tpot_mean
        return d


class StepTimeline:
    """Chrome trace-event accumulator (bounded; drops are counted)."""

    def __init__(self, t0: float, max_events: int = 200_000):
        self.t0 = t0
        self.max_events = max_events
        self.events: List[Dict] = []
        self.dropped = 0

    def _us(self, t: float) -> float:
        return (t - self.t0) * 1e6

    def _push(self, ev: Dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def complete(self, name: str, t_start: float, dur: float,
                 tid: int = 0, **args) -> None:
        self._push({"name": name, "cat": "serve", "ph": "X",
                    "ts": self._us(t_start), "dur": dur * 1e6,
                    "pid": 0, "tid": tid, "args": args})

    def instant(self, name: str, t: float, tid: int = 0, **args) -> None:
        self._push({"name": name, "cat": "serve", "ph": "i",
                    "ts": self._us(t), "s": "t",
                    "pid": 0, "tid": tid, "args": args})

    def to_chrome(self, meta: Optional[Dict] = None) -> Dict:
        """The standard Chrome trace-event JSON object (Perfetto-loadable).
        tid 0 is named "engine"; request tids are req_id + 1 so they never
        collide with it."""
        events = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
                   "args": {"name": "engine"}}]
        req_tids = sorted({e["tid"] for e in self.events if e["tid"] != 0})
        for tid in req_tids:
            events.append({"name": "thread_name", "ph": "M", "pid": 0,
                           "tid": tid, "args": {"name": f"req {tid - 1}"}})
        events.extend(self.events)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": dict(meta or {},
                                  dropped_events=self.dropped)}


class Telemetry:
    """Observability hub one ``ContinuousEngine`` publishes into.

    Parameters
    ----------
    clock : injectable time source (``time.monotonic`` by default).
    timeline : record Chrome trace events per engine phase.
    trace_requests : keep per-request ``RequestTrace`` records (finished
        ones in ``finished_traces``, bounded by ``max_finished_traces``).
    numerics_every : probe every Nth completed prefill with the lockstep
        int8-vs-full-precision audit (0 = off; needs an int8 engine).
    numerics_max_tokens : cap on probed prompt-prefix length (bounds both
        probe cost and jit bucket count — lengths quantize to powers of
        two by truncation).
    """

    def __init__(self, *, clock: Optional[Clock] = None,
                 timeline: bool = True, trace_requests: bool = True,
                 numerics_every: int = 0, numerics_max_tokens: int = 64,
                 max_timeline_events: int = 200_000,
                 max_finished_traces: int = 10_000):
        if numerics_every < 0:
            raise ValueError("numerics_every must be >= 0")
        self.clock: Clock = clock or time.monotonic
        self.trace_requests = trace_requests
        self.numerics_every = numerics_every
        self.numerics_max_tokens = numerics_max_tokens
        self._timeline_on = timeline
        self._max_timeline_events = max_timeline_events
        self._max_finished = max_finished_traces
        self.registry = MetricRegistry()
        self._audit_fn = None        # lazily-jitted numerics probe
        self._build()

    def _build(self) -> None:
        reg = self.registry
        # publish_engine's (Gauge, attrgetter) bindings point into the
        # registry — a reset swaps the metric objects out, so rebind lazily
        self._gauge_bindings = None
        self.timeline = StepTimeline(self.clock(),
                                     self._max_timeline_events) \
            if self._timeline_on else None
        self.traces: Dict[int, RequestTrace] = {}
        self.finished_traces: List[RequestTrace] = []
        h = reg.histogram
        self.h_ttft = h("serve_ttft_seconds",
                        "submit to first sampled token")
        self.h_tpot = h("serve_tpot_seconds",
                        "gap between consecutive decode tokens of one "
                        "request (dispatch-time convention)")
        self.h_e2e = h("serve_e2e_seconds", "submit to finish")
        self.h_queue = h("serve_queue_wait_seconds", "submit to admission")
        self.h_step = h("serve_step_seconds", "one engine step() call")
        c = reg.counter
        self.c_submitted = c("serve_requests_submitted_total",
                             "requests enqueued")
        self.c_finished = c("serve_requests_finished_total",
                            "requests completed")
        self.c_preempted = c("serve_requests_preempted_total",
                             "preemption events (one request can count "
                             "several times)")
        self.c_probes = c("numerics_probes_total",
                          "int8-vs-full-precision audit runs")
        self.c_intmax_overflow = c(
            "numerics_intmax_overflow_rows_total",
            "score rows whose running IntMax exceeds the Q(6,2) LocalMax "
            "format across probed prefills")
        self.c_scale_sat = c(
            "numerics_kv_scale_sat_rows_total",
            "K/V rows whose amax would saturate a static "
            "percentile-calibrated int8 scale across probed prefills")
        # kernel cost observatory: analytic per-launch accounting
        # (serve/kernel_costs.py) published live. Counters aggregate
        # all layers of every launch; histograms sample PER-LAUNCH
        # (= per-layer) values on byte/FLOP-shaped ladders (the default
        # ladder is latency-shaped and would overflow immediately).
        self.c_kernel_dma = c(
            "kernel_dma_bytes_total",
            "modeled gather-DMA bytes moved by the paged kernels "
            "(KV + int8 scale siblings, incl. clamped block-0 waste)")
        self.c_kernel_flops = c(
            "kernel_flops_total",
            "modeled MXU matmul FLOPs executed by the paged kernels")
        self.c_kernel_waste = c(
            "kernel_waste_bytes_total",
            "subset of kernel_dma_bytes_total gathered for table entries "
            "at/past each row's real block cover (pow2 bucketing, tile "
            "padding, dead tail blocks) — pure masked-out DMA")
        self.h_launch_dma = h("kernel_launch_dma_bytes",
                              "gather-DMA bytes of one kernel launch "
                              "(one layer)", lo=1024.0, growth=1.6,
                              n_buckets=64)
        self.h_launch_flops = h("kernel_launch_flops",
                                "MXU FLOPs of one kernel launch "
                                "(one layer)", lo=4096.0, growth=1.6,
                                n_buckets=64)
        # resilience surface (PR 8): fault injections, terminal-state
        # counters, retry/readback accounting, and the guard ladder gauge
        self.c_faults = c("fault_injected_total",
                          "fault-injector firings (by the engine's "
                          "attached FaultPlan)")
        self.c_cancelled = c("requests_cancelled_total",
                             "requests cancelled (client, deadline, or "
                             "quarantine)")
        self.c_shed = c("requests_shed_total",
                        "submissions refused while the guard sheds load")
        self.c_deadline = c("deadline_misses_total",
                            "requests cancelled on deadline/TTFT breach")
        self.c_quarantined = c("requests_quarantined_total",
                               "requests cancelled by the scatter-readback "
                               "KV-integrity audit")
        self.c_retries = c("step_transient_retries_total",
                           "TransientFaults absorbed by bounded retry")
        self.c_readback = c("readback_audits_total",
                            "scatter-readback KV-integrity audits run")
        self.c_guard_transitions = c("guard_transitions_total",
                                     "degradation-ladder state changes")
        self.g_guard_state = reg.gauge(
            "guard_state",
            "degradation ladder level: 0 healthy, 1 degraded, 2 shedding")

    # -- lifecycle hooks (engine calls these; all host-side, O(1)) --------

    def _trace(self, req) -> Optional[RequestTrace]:
        if not self.trace_requests:
            return None
        tr = self.traces.get(req.req_id)
        if tr is None:
            tr = RequestTrace(req.req_id, prompt_len=req.prompt_len,
                              max_new=req.max_new, t_submit=req.t_submit)
            self.traces[req.req_id] = tr
        return tr

    def _mark(self, req, name: str, t: float) -> None:
        tr = self._trace(req)
        if tr is not None:
            tr.events.append((name, t))
        if self.timeline is not None:
            self.timeline.instant(name, t, tid=req.req_id + 1)

    def on_submit(self, req) -> None:
        self.c_submitted.inc()
        self._mark(req, "submit", req.t_submit)

    def on_admit(self, req) -> None:
        self.h_queue.observe(req.t_admit - req.t_submit)
        tr = self._trace(req)
        if tr is not None:
            tr.t_admit = req.t_admit
            tr.n_prefix_hit = req.n_prefix_hit
        self._mark(req, "readmit" if req.n_preemptions else "admit",
                   req.t_admit)

    def on_prefill(self, req, kind: str, n_tokens: int, table_width: int,
                   t_start: float, dur: float, cost=None,
                   launches: int = 1) -> None:
        """kind: "prefill" (one-shot cold), "prefill-suffix" (cache hit),
        or "prefill-chunk". ``cost`` is the per-launch ``LaunchCost`` when
        the phase ran a paged kernel (chunked prefill); ``launches`` is
        kernel launches in the phase (= model layers)."""
        tr = self._trace(req)
        if tr is not None:
            tr.events.append((kind, t_start))
            if kind == "prefill-chunk":
                tr.prefill_chunks += 1
        extra = self.on_kernel_launch(kind, cost, launches)
        if self.timeline is not None:
            self.timeline.complete(kind, t_start, dur,
                                   req=req.req_id, tokens=n_tokens,
                                   table_width=table_width, **extra)

    def on_first_token(self, req) -> None:
        # observe TTFT once per request: a preempted request's re-delivered
        # first token is not a second TTFT sample (only DECODING requests
        # are ever preempted, so n_preemptions > 0 implies a prior join).
        # A request migrated from a dead replica arrives with
        # ttft_observed=True — its fleet-wide first token already streamed
        # from the old replica, so this replica's registry must not add a
        # second sample (fleet aggregation via MetricRegistry.collect
        # would double-count it)
        tr = self._trace(req)
        first = ((not tr.t_first_token) if tr is not None
                 else (req.n_preemptions == 0)) and \
            not getattr(req, "ttft_observed", False)
        if first:
            self.h_ttft.observe(req.t_first_token - req.t_submit)
        if tr is not None:
            if not tr.t_first_token:
                tr.t_first_token = req.t_first_token
            tr.n_tokens = req.n_generated
        self._mark(req, "first_token", req.t_first_token)

    def on_decode_token(self, req, now: float) -> None:
        self.on_decode_tokens((req,), now)

    def on_decode_tokens(self, reqs, now: float) -> None:
        """Per-token accounting for one decode step, batched: the engine
        calls this once per step with every occupied row's request (the
        hottest hook — once per generated token) so the histogram/trace
        lookups are hoisted out of the per-request loop. Trace event
        lists record lifecycle milestones only — the per-token signal is
        the tpot histogram sample, not an event tuple per token (which
        would dominate hook cost AND allocator churn at serving rates)."""
        observe = self.h_tpot.observe
        traces = self.traces if self.trace_requests else None
        for req in reqs:
            if req.t_last_token > 0:
                observe(now - req.t_last_token)
            req.t_last_token = now
            if traces is not None:
                tr = traces.get(req.req_id)
                if tr is not None:
                    tr.n_tokens = req.n_generated

    def on_decode_step(self, *, rows: int, table_width: int,
                       t_start: float, dur: float, split_k: int,
                       kv_tile_blocks: int, cost=None,
                       launches: int = 1) -> None:
        extra = self.on_kernel_launch("decode", cost, launches)
        if self.timeline is not None:
            self.timeline.complete("decode", t_start, dur, rows=rows,
                                   table_width=table_width,
                                   split_k=split_k,
                                   kv_tile_blocks=kv_tile_blocks, **extra)

    def on_kernel_launch(self, phase: str, cost, launches: int = 1) -> Dict:
        """Account one engine phase's paged-kernel launches from its
        analytic ``LaunchCost`` (``serve/kernel_costs.py``): counters get
        the phase total (cost × launches), per-launch histograms get one
        per-layer sample. Returns the trace args to stamp on the phase's
        timeline slice — Perfetto then shows bytes/FLOPs per phase.
        ``cost=None`` (phase didn't run a paged kernel) is a no-op."""
        if cost is None:
            return {}
        dma = cost.gather_bytes * launches
        flops = cost.flops * launches
        waste = cost.waste_bytes * launches
        self.c_kernel_dma.inc(dma)
        self.c_kernel_flops.inc(flops)
        self.c_kernel_waste.inc(waste)
        self.h_launch_dma.observe(cost.gather_bytes)
        self.h_launch_flops.observe(cost.flops)
        return {"dma_bytes": dma, "flops": flops, "waste_bytes": waste,
                "grid_steps": cost.grid_steps * launches}

    def on_drain(self, t_start: float, dur: float, n_vectors: int) -> None:
        """Host↔device sync: materializing the async token pipeline."""
        if self.timeline is not None:
            self.timeline.complete("drain", t_start, dur,
                                   vectors=n_vectors)

    def on_preempt(self, req) -> None:
        self.c_preempted.inc()
        tr = self._trace(req)
        if tr is not None:
            tr.n_preemptions = req.n_preemptions
        self._mark(req, "preempt", self.clock())

    def on_finish(self, req) -> None:
        self.c_finished.inc()
        self.h_e2e.observe(req.t_finish - req.t_submit)
        self._mark(req, "finish", req.t_finish)
        self._finalize_trace(req, getattr(req, "finish_reason", "length"))

    def _finalize_trace(self, req, reason: str) -> None:
        tr = self.traces.pop(req.req_id, None)
        if tr is not None:
            tr.t_finish = req.t_finish
            tr.n_tokens = req.n_generated
            tr.n_preemptions = req.n_preemptions
            tr.finish_reason = reason
            if len(self.finished_traces) < self._max_finished:
                self.finished_traces.append(tr)

    # -- resilience hooks (faults / cancellation / guard) -----------------

    def on_fault(self, kind: str, step: int, **details) -> None:
        """One injector firing (called when a fault window opens)."""
        self.c_faults.inc()
        if self.timeline is not None:
            self.timeline.instant(f"fault:{kind}", self.clock(),
                                  step=step, **details)

    def on_cancel(self, req, reason: str) -> None:
        """Terminal states that are not natural completion: client cancel,
        deadline/TTFT breach, quarantine. The request's trace finalizes
        with the reason; e2e samples stay completion-only so the latency
        histograms are not polluted by cut-short requests."""
        self.c_cancelled.inc()
        if reason == "deadline":
            self.c_deadline.inc()
        elif reason == "quarantined":
            self.c_quarantined.inc()
        self._mark(req, f"cancel:{reason}", req.t_finish or self.clock())
        self._finalize_trace(req, reason)

    def on_shed(self) -> None:
        self.c_shed.inc()

    def on_retry(self) -> None:
        self.c_retries.inc()

    def on_readback(self, req, err: float) -> None:
        self.c_readback.inc()
        self.registry.gauge(
            "readback_logit_error",
            "latest scatter-readback audit's max logit delta").set(err)

    def on_guard(self, old: str, new: str, reason: str,
                 step: int = -1) -> None:
        """Degradation-ladder transition (the engine calls this only when
        the state actually changed; the steady-state gauge refresh happens
        engine-side)."""
        from repro.serve.guard import GUARD_STATES
        self.c_guard_transitions.inc()
        self.g_guard_state.set(float(GUARD_STATES.index(new)))
        if self.timeline is not None:
            self.timeline.instant(f"guard:{old}->{new}", self.clock(),
                                  step=step, reason=reason)

    def on_step_end(self, engine, t_start: float, dur: float) -> None:
        self.h_step.observe(dur)
        if self.timeline is not None:
            self.timeline.complete("step", t_start, dur)
        # the gauges mirror cumulative engine structs, so scrape freshness
        # is bounded by the publish cadence, not correctness: refresh on a
        # short cadence plus whenever the engine goes quiescent (the final
        # step of a run always publishes — post-run snapshots are exact)
        if engine.metrics.steps % 4 == 0 or not engine.sched.running:
            self.publish_engine(engine)

    # -- registry publication ---------------------------------------------

    # (exported gauge name, attribute on the mirrored struct) — resolved
    # to bound (Gauge, attrgetter) pairs once per Telemetry instance:
    # publish_engine runs every engine step, and per-step registry name
    # lookups plus rebuilding these tables dominated the hook budget
    _ENGINE_GAUGES = (
        ("serve_steps", "steps"),
        ("serve_decode_steps", "decode_steps"),
        ("serve_prefills", "prefills"),
        ("serve_prefill_chunks", "prefill_chunks"),
        ("serve_preemptions", "preemptions"),
        ("serve_tokens_out", "tokens_out"),
        ("serve_tokens_discarded", "tokens_discarded"),
        ("serve_prefill_tokens", "prefill_tokens"),
        ("serve_prefix_hit_tokens", "prefix_hit_tokens"),
        ("serve_prefill_savings", "prefill_savings"),
        ("serve_wall_seconds", "wall_s"),
        ("serve_kv_pool_bytes", "kv_pool_bytes"),
        ("serve_pool_token_capacity", "pool_token_capacity"))
    _POOL_GAUGES = (
        ("pool_blocks_in_use", "blocks_in_use"),
        ("pool_blocks_peak", "peak_in_use"),
        ("pool_utilization", "utilization"),
        ("pool_allocs", "allocs"),
        ("pool_frees", "frees"),
        ("pool_shared_blocks", "shared_blocks"),
        ("pool_shared_blocks_peak", "peak_shared"),
        ("pool_cow_copies", "cow_copies"))
    _CACHE_GAUGES = (
        ("cache_lookup_tokens", "lookup_tokens"),
        ("cache_hit_tokens", "hit_tokens"),
        ("cache_hit_rate", "hit_rate"),
        ("cache_hits", "hits"),
        ("cache_misses", "misses"),
        ("cache_inserts", "inserts"),
        ("cache_evictions", "evictions"))

    def publish_engine(self, engine) -> None:
        """Mirror ``EngineMetrics`` / ``PoolStats`` / ``CacheStats`` into
        the registry (cumulative-since-reset values exported as gauges —
        the authoritative counters live on the engine structs)."""
        bound = self._gauge_bindings
        if bound is None:
            import operator
            g = self.registry.gauge
            bound = self._gauge_bindings = tuple(
                tuple((g(n), operator.attrgetter(a)) for n, a in grp)
                for grp in (self._ENGINE_GAUGES, self._POOL_GAUGES,
                            self._CACHE_GAUGES))
        m = engine.metrics
        for gg, get in bound[0]:
            gg.value = float(get(m))
        p = engine.pool.stats
        for gg, get in bound[1]:
            gg.value = float(get(p))
        if engine.prefix_cache is not None:
            s = engine.prefix_cache.stats
            for gg, get in bound[2]:
                gg.value = float(get(s))

    # -- numerics monitor --------------------------------------------------

    def maybe_numerics_probe(self, engine, req) -> Optional[Dict[str, float]]:
        """Every ``numerics_every``-th completed prefill of an int8 engine,
        re-run (a power-of-two prefix of) the request's prompt through the
        lockstep full-precision/int8 audit and publish the live gauges.
        Returns the probe dict when a probe ran (the engine feeds its
        ``logit_error`` into the guard's per-step signal), else None."""
        if self.numerics_every <= 0 or not engine.quantized:
            return None
        # called right after _join_decode bumped prefills: probe the 1st,
        # (1+N)th, (1+2N)th ... completed prefill
        if (engine.metrics.prefills - 1) % self.numerics_every != 0:
            return None
        return self.numerics_probe(engine, req.prompt)

    def numerics_probe(self, engine, prompt) -> Dict[str, float]:
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.serve.paged_step import paged_prefill_audit

        if self._audit_fn is None:
            cfg = engine.cfg
            self._audit_fn = jax.jit(
                lambda p, t, lp: paged_prefill_audit(p, t, lp, cfg))
        # power-of-two prefix: bounded jit buckets, bounded probe cost
        n = min(int(prompt.shape[0]), self.numerics_max_tokens)
        probe_len = 1
        while probe_len * 2 <= n:
            probe_len *= 2
        tokens = jnp.asarray(
            np.asarray(prompt[:probe_len], np.int32)[None])
        last = jnp.asarray([probe_len - 1], jnp.int32)
        lg_ref, lg_q, stats = self._audit_fn(engine.params, tokens, last)
        V = engine.cfg.vocab_size
        err = float(jnp.max(jnp.abs(lg_ref[:, :V] - lg_q[:, :V])))
        out = {k: float(v) for k, v in stats.items()}
        out["logit_error"] = err
        g = self.registry.gauge
        g("numerics_logit_error",
          "latest probe's max |full - int8| logit delta").set(err)
        g("numerics_logit_error_max",
          "largest logit delta seen since reset (PR 4's bound, live)"
          ).max(err)
        g("numerics_probe_tokens", "prompt prefix length probed"
          ).set(probe_len)
        g("numerics_score_intmax_max",
          "largest running IntMax over probed attention scores").max(
              out["score_intmax_max"])
        g("numerics_kv_amax_max",
          "largest per-row K/V amax seen (static-scale headroom)").max(
              out["kv_amax_max"])
        self.c_probes.inc()
        self.c_intmax_overflow.inc(out["intmax_overflow_rows"])
        self.c_scale_sat.inc(out["kv_scale_sat_rows"])
        return out

    # -- export ------------------------------------------------------------

    def quantiles(self, name: str) -> Dict[str, float]:
        """{"p50": ..., "p90": ..., "p99": ..., "count": ...} of one of
        the telemetry histograms (name without the serve_ prefix is
        accepted: "ttft" → serve_ttft_seconds)."""
        h = self.registry.get(name) or \
            self.registry.get(f"serve_{name}_seconds")
        if h is None:
            raise KeyError(name)
        return {"p50": h.quantile(0.50), "p90": h.quantile(0.90),
                "p99": h.quantile(0.99), "count": h.count,
                "mean": h.mean}

    def save_chrome_trace(self, path: str,
                          meta: Optional[Dict] = None) -> None:
        if self.timeline is None:
            raise RuntimeError("timeline recording is disabled")
        with open(path, "w") as f:
            json.dump(self.timeline.to_chrome(meta), f)
            f.write("\n")

    def save_metrics(self, path: str,
                     extra: Optional[Dict] = None) -> None:
        """``.jsonl`` → append one registry snapshot line (the JSONL
        sink); anything else → Prometheus text exposition."""
        if path.endswith(".jsonl"):
            self.registry.write_jsonl(path, extra)
        else:
            with open(path, "w") as f:
                f.write(self.registry.prometheus_text())

    def reset(self) -> None:
        """Coherent zero of every aggregate (histograms, counters, gauges,
        timeline, traces). The numerics jit cache survives."""
        self.registry.reset()
        self._build()
