from repro.serve.engine import (ContinuousEngine, EngineMetrics,
                                GenerateResult, ServeEngine)
from repro.serve.kv_pool import PagedKVCache, PoolExhausted, PoolStats
from repro.serve.radix_cache import CacheStats, RadixCache
from repro.serve.scheduler import Request, Scheduler

__all__ = ["ContinuousEngine", "EngineMetrics", "GenerateResult",
           "ServeEngine", "PagedKVCache", "PoolExhausted", "PoolStats",
           "RadixCache", "CacheStats", "Request", "Scheduler"]
