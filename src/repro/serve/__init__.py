from repro.serve.engine import GenerateResult, ServeEngine

__all__ = ["GenerateResult", "ServeEngine"]
