from repro.serve.engine import (ContinuousEngine, EngineMetrics,
                                GenerateResult, ServeEngine)
from repro.serve.kv_pool import PagedKVCache, PoolExhausted, PoolStats
from repro.serve.metrics import (Counter, Gauge, Histogram, MetricRegistry,
                                 parse_prometheus_text)
from repro.serve.radix_cache import CacheStats, RadixCache
from repro.serve.scheduler import Request, Scheduler
from repro.serve.telemetry import (ManualClock, RequestTrace, StepTimeline,
                                   Telemetry)

__all__ = ["ContinuousEngine", "EngineMetrics", "GenerateResult",
           "ServeEngine", "PagedKVCache", "PoolExhausted", "PoolStats",
           "RadixCache", "CacheStats", "Request", "Scheduler",
           "Counter", "Gauge", "Histogram", "MetricRegistry",
           "parse_prometheus_text", "ManualClock", "RequestTrace",
           "StepTimeline", "Telemetry"]
