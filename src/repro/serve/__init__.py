from repro.serve.autotune import (AUTOTUNE_MODES, GridDecision, GridPlanner,
                                  default_candidates)
from repro.serve.engine import (ContinuousEngine, EngineMetrics,
                                GenerateResult, ServeEngine)
from repro.serve.kernel_costs import (CostParams, LaunchCost,
                                      decode_launch_cost, estimate_seconds,
                                      prefill_launch_cost)
from repro.serve.kv_pool import PagedKVCache, PoolExhausted, PoolStats
from repro.serve.metrics import (Counter, Gauge, Histogram, MetricRegistry,
                                 parse_prometheus_text)
from repro.serve.radix_cache import CacheStats, RadixCache
from repro.serve.scheduler import Request, Scheduler
from repro.serve.telemetry import (ManualClock, RequestTrace, StepTimeline,
                                   Telemetry)

__all__ = ["ContinuousEngine", "EngineMetrics", "GenerateResult",
           "ServeEngine", "PagedKVCache", "PoolExhausted", "PoolStats",
           "RadixCache", "CacheStats", "Request", "Scheduler",
           "Counter", "Gauge", "Histogram", "MetricRegistry",
           "parse_prometheus_text", "ManualClock", "RequestTrace",
           "StepTimeline", "Telemetry",
           "AUTOTUNE_MODES", "GridDecision", "GridPlanner",
           "default_candidates", "CostParams", "LaunchCost",
           "decode_launch_cost", "prefill_launch_cost",
           "estimate_seconds"]
