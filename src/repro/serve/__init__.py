from repro.serve.autotune import (AUTOTUNE_MODES, GridDecision, GridPlanner,
                                  default_candidates)
from repro.serve.engine import (ContinuousEngine, EngineMetrics,
                                GenerateResult, ServeEngine)
from repro.serve.faults import (ENGINE_FAULT_KINDS, FAULT_KINDS, FAULT_REQ,
                                FLEET_FAULT_KINDS, FaultInjector, FaultPlan,
                                FaultSpec, TransientFault, canned_fleet_plan,
                                canned_plan)
from repro.serve.frontend import (AsyncFrontend, AsyncStream, RequestResult,
                                  RequestTracker, TrackedRequest)
from repro.serve.guard import (GUARD_STATES, EngineGuard, EngineSheddingError,
                               GuardConfig, GuardSignals)
from repro.serve.invariants import (InvariantViolation, check_invariants,
                                    leaked_blocks)
from repro.serve.journal import (FSYNC_POLICIES, Journal, JournalCorrupt,
                                 ReplayedRequest, ReplayState, replay,
                                 state_digest)
from repro.serve.kernel_costs import (CostParams, LaunchCost,
                                      decode_launch_cost, estimate_seconds,
                                      prefill_launch_cost)
from repro.serve.kv_pool import PagedKVCache, PoolExhausted, PoolStats
from repro.serve.metrics import (Counter, Gauge, Histogram, MetricRegistry,
                                 parse_prometheus_text)
from repro.serve.radix_cache import CacheStats, RadixCache
from repro.serve.router import ROUTING_POLICIES, PlacementDecision, Router
from repro.serve.scheduler import (FINISH_CANCELLED, FINISH_DEADLINE,
                                   FINISH_FAILOVER, FINISH_LENGTH,
                                   FINISH_QUARANTINED,
                                   CapacityExceededError,
                                   DuplicateRequestError, EmptyPromptError,
                                   Request, Scheduler, SubmitError)
from repro.serve.snapshot import (Snapshot, SnapshotCorrupt, apply_snapshot,
                                  engine_fingerprint, requeue_inflight,
                                  restore_engine, snapshot_state,
                                  write_snapshot)
from repro.serve.supervisor import (FleetSupervisor, ReplicaHandle,
                                    snapshot_path)
from repro.serve.telemetry import (ManualClock, RequestTrace, StepTimeline,
                                   Telemetry)

__all__ = ["ContinuousEngine", "EngineMetrics", "GenerateResult",
           "ServeEngine", "PagedKVCache", "PoolExhausted", "PoolStats",
           "RadixCache", "CacheStats", "Request", "Scheduler",
           "Counter", "Gauge", "Histogram", "MetricRegistry",
           "parse_prometheus_text", "ManualClock", "RequestTrace",
           "StepTimeline", "Telemetry",
           "AUTOTUNE_MODES", "GridDecision", "GridPlanner",
           "default_candidates", "CostParams", "LaunchCost",
           "decode_launch_cost", "prefill_launch_cost",
           "estimate_seconds",
           # resilience layer (PR 8)
           "FAULT_KINDS", "FAULT_REQ", "FaultInjector", "FaultPlan",
           "FaultSpec", "TransientFault", "canned_plan",
           "GUARD_STATES", "EngineGuard", "EngineSheddingError",
           "GuardConfig", "GuardSignals",
           "InvariantViolation", "check_invariants", "leaked_blocks",
           "SubmitError", "EmptyPromptError", "DuplicateRequestError",
           "CapacityExceededError", "FINISH_LENGTH", "FINISH_CANCELLED",
           "FINISH_DEADLINE", "FINISH_QUARANTINED",
           # fleet serving layer (PR 9)
           "ENGINE_FAULT_KINDS", "FLEET_FAULT_KINDS", "canned_fleet_plan",
           "FINISH_FAILOVER", "AsyncFrontend", "AsyncStream",
           "RequestResult", "RequestTracker", "TrackedRequest",
           "Journal", "JournalCorrupt", "ReplayState", "ReplayedRequest",
           "replay", "ROUTING_POLICIES", "PlacementDecision", "Router",
           "FleetSupervisor", "ReplicaHandle",
           # durability layer (PR 10)
           "FSYNC_POLICIES", "state_digest", "Snapshot", "SnapshotCorrupt",
           "apply_snapshot", "engine_fingerprint", "requeue_inflight",
           "restore_engine", "snapshot_state", "write_snapshot",
           "snapshot_path"]
