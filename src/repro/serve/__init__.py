from repro.serve.autotune import (AUTOTUNE_MODES, GridDecision, GridPlanner,
                                  default_candidates)
from repro.serve.engine import (ContinuousEngine, EngineMetrics,
                                GenerateResult, ServeEngine)
from repro.serve.faults import (FAULT_KINDS, FAULT_REQ, FaultInjector,
                                FaultPlan, FaultSpec, TransientFault,
                                canned_plan)
from repro.serve.guard import (GUARD_STATES, EngineGuard, EngineSheddingError,
                               GuardConfig, GuardSignals)
from repro.serve.invariants import (InvariantViolation, check_invariants,
                                    leaked_blocks)
from repro.serve.kernel_costs import (CostParams, LaunchCost,
                                      decode_launch_cost, estimate_seconds,
                                      prefill_launch_cost)
from repro.serve.kv_pool import PagedKVCache, PoolExhausted, PoolStats
from repro.serve.metrics import (Counter, Gauge, Histogram, MetricRegistry,
                                 parse_prometheus_text)
from repro.serve.radix_cache import CacheStats, RadixCache
from repro.serve.scheduler import (FINISH_CANCELLED, FINISH_DEADLINE,
                                   FINISH_LENGTH, FINISH_QUARANTINED,
                                   CapacityExceededError,
                                   DuplicateRequestError, EmptyPromptError,
                                   Request, Scheduler, SubmitError)
from repro.serve.telemetry import (ManualClock, RequestTrace, StepTimeline,
                                   Telemetry)

__all__ = ["ContinuousEngine", "EngineMetrics", "GenerateResult",
           "ServeEngine", "PagedKVCache", "PoolExhausted", "PoolStats",
           "RadixCache", "CacheStats", "Request", "Scheduler",
           "Counter", "Gauge", "Histogram", "MetricRegistry",
           "parse_prometheus_text", "ManualClock", "RequestTrace",
           "StepTimeline", "Telemetry",
           "AUTOTUNE_MODES", "GridDecision", "GridPlanner",
           "default_candidates", "CostParams", "LaunchCost",
           "decode_launch_cost", "prefill_launch_cost",
           "estimate_seconds",
           # resilience layer (PR 8)
           "FAULT_KINDS", "FAULT_REQ", "FaultInjector", "FaultPlan",
           "FaultSpec", "TransientFault", "canned_plan",
           "GUARD_STATES", "EngineGuard", "EngineSheddingError",
           "GuardConfig", "GuardSignals",
           "InvariantViolation", "check_invariants", "leaked_blocks",
           "SubmitError", "EmptyPromptError", "DuplicateRequestError",
           "CapacityExceededError", "FINISH_LENGTH", "FINISH_CANCELLED",
           "FINISH_DEADLINE", "FINISH_QUARANTINED"]
