from repro.serve.autotune import (AUTOTUNE_MODES, GridDecision, GridPlanner,
                                  default_candidates)
from repro.serve.engine import (ContinuousEngine, EngineMetrics,
                                GenerateResult, ServeEngine)
from repro.serve.faults import (ENGINE_FAULT_KINDS, FAULT_KINDS, FAULT_REQ,
                                FLEET_FAULT_KINDS, FaultInjector, FaultPlan,
                                FaultSpec, TransientFault, canned_fleet_plan,
                                canned_plan)
from repro.serve.frontend import (AsyncFrontend, AsyncStream, RequestResult,
                                  RequestTracker, TrackedRequest)
from repro.serve.guard import (GUARD_STATES, EngineGuard, EngineSheddingError,
                               GuardConfig, GuardSignals)
from repro.serve.invariants import (InvariantViolation, check_invariants,
                                    leaked_blocks)
from repro.serve.journal import (Journal, JournalCorrupt, ReplayedRequest,
                                 ReplayState, replay)
from repro.serve.kernel_costs import (CostParams, LaunchCost,
                                      decode_launch_cost, estimate_seconds,
                                      prefill_launch_cost)
from repro.serve.kv_pool import PagedKVCache, PoolExhausted, PoolStats
from repro.serve.metrics import (Counter, Gauge, Histogram, MetricRegistry,
                                 parse_prometheus_text)
from repro.serve.radix_cache import CacheStats, RadixCache
from repro.serve.router import ROUTING_POLICIES, PlacementDecision, Router
from repro.serve.scheduler import (FINISH_CANCELLED, FINISH_DEADLINE,
                                   FINISH_FAILOVER, FINISH_LENGTH,
                                   FINISH_QUARANTINED,
                                   CapacityExceededError,
                                   DuplicateRequestError, EmptyPromptError,
                                   Request, Scheduler, SubmitError)
from repro.serve.supervisor import FleetSupervisor, ReplicaHandle
from repro.serve.telemetry import (ManualClock, RequestTrace, StepTimeline,
                                   Telemetry)

__all__ = ["ContinuousEngine", "EngineMetrics", "GenerateResult",
           "ServeEngine", "PagedKVCache", "PoolExhausted", "PoolStats",
           "RadixCache", "CacheStats", "Request", "Scheduler",
           "Counter", "Gauge", "Histogram", "MetricRegistry",
           "parse_prometheus_text", "ManualClock", "RequestTrace",
           "StepTimeline", "Telemetry",
           "AUTOTUNE_MODES", "GridDecision", "GridPlanner",
           "default_candidates", "CostParams", "LaunchCost",
           "decode_launch_cost", "prefill_launch_cost",
           "estimate_seconds",
           # resilience layer (PR 8)
           "FAULT_KINDS", "FAULT_REQ", "FaultInjector", "FaultPlan",
           "FaultSpec", "TransientFault", "canned_plan",
           "GUARD_STATES", "EngineGuard", "EngineSheddingError",
           "GuardConfig", "GuardSignals",
           "InvariantViolation", "check_invariants", "leaked_blocks",
           "SubmitError", "EmptyPromptError", "DuplicateRequestError",
           "CapacityExceededError", "FINISH_LENGTH", "FINISH_CANCELLED",
           "FINISH_DEADLINE", "FINISH_QUARANTINED",
           # fleet serving layer (PR 9)
           "ENGINE_FAULT_KINDS", "FLEET_FAULT_KINDS", "canned_fleet_plan",
           "FINISH_FAILOVER", "AsyncFrontend", "AsyncStream",
           "RequestResult", "RequestTracker", "TrackedRequest",
           "Journal", "JournalCorrupt", "ReplayState", "ReplayedRequest",
           "replay", "ROUTING_POLICIES", "PlacementDecision", "Router",
           "FleetSupervisor", "ReplicaHandle"]
