"""Metric registry for the serving stack: counters, gauges, and streaming
log-bucket histograms, with Prometheus text exposition and a JSONL sink.

The registry is deliberately tiny and dependency-free — it is the storage
layer ``serve/telemetry.py`` publishes into and the thing ``launch/serve.py
--metrics-out`` serializes. Design points:

* **Streaming histograms, no per-sample storage.** ``Histogram`` keeps a
  fixed geometric bucket ladder (``lo * growth**i``): every ``observe`` is
  O(1) float math + one integer increment, and quantiles (p50/p90/p99 TTFT,
  TPOT, E2E latency) are recovered by log-linear interpolation inside the
  covering bucket. Memory is ~n_buckets ints per histogram regardless of
  how many requests are served — the property that makes per-token
  observation affordable inside the decode loop.
* **Prometheus text exposition** (``MetricRegistry.prometheus_text``):
  the standard ``# HELP`` / ``# TYPE`` + cumulative ``_bucket{le=...}``
  format, scrapeable by any Prometheus, promtool-checkable. A minimal
  ``parse_prometheus_text`` lives here too so tests and the CI smoke can
  validate an exposition without a Prometheus install.
* **JSONL sink** (``MetricRegistry.write_jsonl``): one JSON object per
  call appended to a file — a run's metric snapshots become a trajectory
  other tooling (and later PRs' dashboards) can diff across commits.

Metric names follow Prometheus conventions (``snake_case``, ``_total``
suffix on counters, base-unit suffix like ``_seconds``). The glossary of
every name the serving stack exports lives in ``serve/README.md``
("Observability").
"""
from __future__ import annotations

import bisect
import json
import math
import re
from typing import Dict, List, Optional, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(f"invalid metric name {name!r}")
    return name


class Counter:
    """Monotonic counter. ``inc`` only; use a Gauge for set-to-value."""

    __slots__ = ("name", "help", "value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = _check_name(name)
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only go up (inc {n})")
        self.value += n

    def merge(self, other: "Counter") -> None:
        """Fold another replica's counter in (sum — counters are
        extensive)."""
        self.value += other.value


class Gauge:
    """Set-to-current-value metric (pool occupancy, live error bounds)."""

    __slots__ = ("name", "help", "value")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = _check_name(name)
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def max(self, v: float) -> None:
        """Running-maximum update (peak trackers, live error bounds)."""
        self.value = max(self.value, float(v))

    def merge(self, other: "Gauge") -> None:
        """Fold another replica's gauge in. Gauges are point-in-time
        values with no universally correct cross-replica reduction; max is
        the conservative choice for everything this stack exports (peaks,
        occupancy high-water, error bounds)."""
        self.value = max(self.value, other.value)


class Histogram:
    """Streaming histogram over fixed geometric buckets.

    Bucket ``i`` covers ``(lo*growth**(i-1), lo*growth**i]``; bucket 0 is
    ``(0, lo]`` and one overflow bucket catches everything above the top
    edge. Defaults span 1 microsecond to ~50 minutes at ~25% bucket width
    — latency-shaped. Quantile error is bounded by the bucket width
    (log-linear interpolation inside the covering bucket), which is plenty
    for p50/p99 reporting; exact extremes are kept in ``min``/``max``.
    """

    __slots__ = ("name", "help", "lo", "growth", "counts", "count", "sum",
                 "min", "max", "_edges")
    kind = "histogram"

    def __init__(self, name: str, help: str = "", lo: float = 1e-6,
                 growth: float = 1.25, n_buckets: int = 98):
        if lo <= 0 or growth <= 1 or n_buckets < 2:
            raise ValueError("need lo > 0, growth > 1, n_buckets >= 2")
        self.name = _check_name(name)
        self.help = help
        self.lo = lo
        self.growth = growth
        # counts[0..n-1] are the ladder, counts[n] is the +Inf overflow
        self.counts = [0] * (n_buckets + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        # precomputed upper edges of the ladder buckets: observe() is on
        # the serving hot path (several per decode step), and a C-level
        # bisect over these beats float log math — and lands samples on
        # the EXACT same boundaries upper_edge()/quantile() report
        self._edges = [lo * growth ** i for i in range(n_buckets)]

    def _bucket_index(self, x: float) -> int:
        # first bucket whose upper edge covers x; len(_edges) == overflow
        return bisect.bisect_left(self._edges, x)

    def upper_edge(self, i: int) -> float:
        """Upper bound of bucket ``i`` (inf for the overflow bucket)."""
        if i >= len(self.counts) - 1:
            return math.inf
        return self.lo * self.growth ** i

    def observe(self, x: float) -> None:
        x = float(x)
        if not math.isfinite(x) or x < 0:
            return                      # clock glitches must not poison p99
        self.counts[self._bucket_index(x)] += 1
        self.count += 1
        self.sum += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)

    def quantile(self, q: float) -> float:
        """q in [0, 1]; 0.0 when empty. Log-linear interpolation inside
        the covering bucket, clamped to the observed min/max so tiny
        samples don't report values outside what was actually seen."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                hi = self.upper_edge(i)
                lo = self.lo * self.growth ** (i - 1) if i > 0 else 0.0
                if not math.isfinite(hi):
                    return self.max
                frac = (rank - seen) / c
                est = lo + (hi - lo) * frac
                return min(max(est, self.min), self.max)
            seen += c
        return self.max

    def merge(self, other: "Histogram") -> None:
        """Fold another histogram with the IDENTICAL bucket ladder into
        this one — the per-replica aggregation primitive. Exact: geometric
        ladders are closed under elementwise count addition, so quantiles
        of the merge are as accurate as if every sample had been observed
        here (min/max stay exact too). Mismatched ladders raise — resample
        semantics across different ladders would be silently lossy."""
        if (other.lo, other.growth, len(other.counts)) != \
                (self.lo, self.growth, len(self.counts)):
            raise ValueError(
                f"{self.name}: cannot merge mismatched bucket ladders "
                f"(lo/growth/n {self.lo}/{self.growth}/{len(self.counts)} "
                f"vs {other.lo}/{other.growth}/{len(other.counts)})")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricRegistry:
    """Flat namespace of metrics; get-or-create accessors so publishing
    sites never need to coordinate registration order."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        return self._get(Histogram, name, help, **kw)

    def __iter__(self):
        return iter(sorted(self._metrics.values(), key=lambda m: m.name))

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str):
        return self._metrics.get(name)

    def reset(self) -> None:
        """Drop every registered metric (coherent-reset semantics: a
        fresh registry, not zeroed husks — callers re-create lazily)."""
        self._metrics.clear()

    def collect(self, *registries: "MetricRegistry",
                prefix: str = "") -> "MetricRegistry":
        """Aggregate same-named metrics from per-replica registries into
        this one (groundwork for the multi-replica front-end): counters
        and histograms merge additively, gauges take the max, and
        ``prefix`` restricts which metric names are collected (e.g.
        ``prefix="serve_"``). Metrics absent here are created with the
        source's ladder/help; kind mismatches raise. Returns self so
        ``MetricRegistry().collect(*replicas)`` reads naturally."""
        for reg in registries:
            for m in reg:
                if prefix and not m.name.startswith(prefix):
                    continue
                if isinstance(m, Histogram):
                    mine = self._get(Histogram, m.name, m.help, lo=m.lo,
                                     growth=m.growth,
                                     n_buckets=len(m.counts) - 1)
                else:
                    mine = self._get(type(m), m.name, m.help)
                mine.merge(m)
        return self

    # -- export -----------------------------------------------------------

    def prometheus_text(self) -> str:
        """Standard Prometheus text exposition (version 0.0.4)."""
        out: List[str] = []
        for m in self:
            if m.help:
                out.append(f"# HELP {m.name} {m.help}")
            out.append(f"# TYPE {m.name} {m.kind}")
            if isinstance(m, Histogram):
                cum = 0
                for i, c in enumerate(m.counts):
                    cum += c
                    edge = m.upper_edge(i)
                    le = "+Inf" if math.isinf(edge) else repr(edge)
                    out.append(f'{m.name}_bucket{{le="{le}"}} {cum}')
                out.append(f"{m.name}_sum {m.sum!r}")
                out.append(f"{m.name}_count {m.count}")
            else:
                out.append(f"{m.name} {m.value!r}")
        return "\n".join(out) + "\n"

    def snapshot(self) -> Dict[str, object]:
        """JSON-able view: scalars verbatim, histograms as summary stats
        plus the standard quantiles."""
        snap: Dict[str, object] = {}
        for m in self:
            if isinstance(m, Histogram):
                snap[m.name] = {
                    "count": m.count,
                    "sum": round(m.sum, 9),
                    "mean": round(m.mean, 9),
                    "min": round(m.min, 9) if m.count else 0.0,
                    "max": round(m.max, 9) if m.count else 0.0,
                    "p50": round(m.quantile(0.50), 9),
                    "p90": round(m.quantile(0.90), 9),
                    "p99": round(m.quantile(0.99), 9),
                }
            else:
                snap[m.name] = m.value
        return snap

    def write_jsonl(self, path: str,
                    extra: Optional[Dict[str, object]] = None) -> None:
        """Append one snapshot as a single JSON line (the JSONL sink)."""
        rec = dict(extra or {})
        rec["metrics"] = self.snapshot()
        with open(path, "a") as f:
            json.dump(rec, f, sort_keys=True)
            f.write("\n")


# ---------------------------------------------------------------------------
# Minimal exposition parser (tests + CI smoke validate without Prometheus)
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")


def parse_prometheus_text(text: str) -> Dict[str, Dict]:
    """Parse a text exposition into {family: {"type": ..., "samples":
    [(name, labels, value)]}}. Raises ValueError on malformed lines —
    the CI smoke's "does the exposition parse" check. Validates histogram
    bucket monotonicity and the +Inf bucket == _count invariant."""
    families: Dict[str, Dict] = {}
    types: Dict[str, str] = {}
    for ln, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) != 4:
                raise ValueError(f"line {ln}: malformed TYPE: {line!r}")
            types[parts[2]] = parts[3]
            families.setdefault(parts[2], {"type": parts[3], "samples": []})
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: malformed sample: {line!r}")
        name = m.group("name")
        labels: Dict[str, str] = {}
        if m.group("labels"):
            for item in m.group("labels").split(","):
                if not item:
                    continue
                k, _, v = item.partition("=")
                if not v.startswith('"') or not v.endswith('"'):
                    raise ValueError(f"line {ln}: unquoted label: {line!r}")
                labels[k.strip()] = v[1:-1]
        try:
            value = float(m.group("value"))
        except ValueError:
            raise ValueError(f"line {ln}: bad value: {line!r}")
        family = name
        for suffix in ("_bucket", "_sum", "_count"):
            base = name[:-len(suffix)] if name.endswith(suffix) else None
            if base and types.get(base) == "histogram":
                family = base
                break
        families.setdefault(family, {"type": types.get(family, "untyped"),
                                     "samples": []})
        families[family]["samples"].append((name, labels, value))

    for fam, info in families.items():
        if info["type"] != "histogram":
            continue
        buckets: List[Tuple[float, float]] = []
        count = None
        for name, labels, value in info["samples"]:
            if name == f"{fam}_bucket":
                le = labels.get("le")
                buckets.append((math.inf if le == "+Inf" else float(le),
                                value))
            elif name == f"{fam}_count":
                count = value
        buckets.sort(key=lambda e: e[0])
        cum = [v for _, v in buckets]
        if cum != sorted(cum):
            raise ValueError(f"{fam}: bucket counts not cumulative")
        if buckets and count is not None and buckets[-1][1] != count:
            raise ValueError(f"{fam}: +Inf bucket != _count")
    return families
