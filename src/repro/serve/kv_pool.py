"""Paged KV cache: a fixed pool of physical blocks + per-request block tables.

The pool owns two device arrays shaped ``(L, num_blocks, Hkv, block_size,
Dh)`` (layer-major inside each block, so one physical block holds a token
span for *every* layer and the per-request block table is shared across the
layer scan). Block 0 is reserved as the garbage block: padding rows of the
decode batch and padded block-table tails point at it, so scatter writes from
inactive batch slots land somewhere harmless.

Allocation metadata (free list, per-request block lists) is plain host-side
Python — the scheduler calls ``alloc``/``append_block``/``free`` between
device steps; the jitted steps only ever see the padded int32 block tables.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class PoolExhausted(Exception):
    """Raised when an allocation cannot be satisfied; triggers preemption."""


@dataclasses.dataclass
class PoolStats:
    num_blocks: int          # usable blocks (excludes the garbage block)
    blocks_in_use: int
    peak_in_use: int
    allocs: int
    frees: int

    @property
    def utilization(self) -> float:
        return self.blocks_in_use / max(self.num_blocks, 1)


class PagedKVCache:
    def __init__(self, cfg: ModelConfig, num_blocks: int, block_size: int):
        from repro.serve.paged_step import check_paged_support
        check_paged_support(cfg)     # one rule set with the model steps
        self.cfg = cfg
        self.block_size = block_size
        self.num_blocks = num_blocks
        L = cfg.n_layers
        Hkv, Dh = cfg.n_kv_heads, cfg.head_dim_
        dt = cfg.compute_dtype_
        # +1: block 0 is the reserved garbage block, never allocated.
        shape = (L, num_blocks + 1, Hkv, block_size, Dh)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        self._free: List[int] = list(range(1, num_blocks + 1))
        self._tables: Dict[int, List[int]] = {}
        self.stats = PoolStats(num_blocks, 0, 0, 0, 0)

    # -- allocation -------------------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, req_id: int, n: int) -> List[int]:
        """Allocate ``n`` blocks for a new request."""
        if req_id in self._tables:
            raise ValueError(f"request {req_id} already has blocks")
        if n > len(self._free):
            raise PoolExhausted(f"need {n} blocks, {len(self._free)} free")
        blocks = [self._free.pop() for _ in range(n)]
        self._tables[req_id] = blocks
        self._account(n)
        return blocks

    def append_block(self, req_id: int) -> int:
        """Grow a request's table by one block (decode crossed a boundary)."""
        if not self._free:
            raise PoolExhausted("no free blocks")
        b = self._free.pop()
        self._tables[req_id].append(b)
        self._account(1)
        return b

    def free(self, req_id: int) -> int:
        """Return a finished/preempted request's blocks. Returns the count."""
        blocks = self._tables.pop(req_id, [])
        self._free.extend(blocks)
        self.stats.blocks_in_use -= len(blocks)
        self.stats.frees += len(blocks)
        return len(blocks)

    def _account(self, n: int) -> None:
        self.stats.blocks_in_use += n
        self.stats.allocs += n
        self.stats.peak_in_use = max(self.stats.peak_in_use,
                                     self.stats.blocks_in_use)

    # -- views ------------------------------------------------------------

    def blocks_of(self, req_id: int) -> List[int]:
        return self._tables[req_id]

    def n_blocks_of(self, req_id: int) -> int:
        return len(self._tables.get(req_id, ()))

    def table_array(self, req_ids: Sequence[int], width: int) -> np.ndarray:
        """Padded (len(req_ids), width) int32 block table; pad = block 0."""
        out = np.zeros((len(req_ids), width), np.int32)
        for i, rid in enumerate(req_ids):
            blocks = self._tables.get(rid, ())
            out[i, :len(blocks)] = blocks
        return out

    @property
    def utilization(self) -> float:
        return self.stats.utilization
