"""Paged KV cache: a fixed pool of physical blocks + per-request block tables.

The pool owns two device arrays shaped ``(L, num_blocks, Hkv, block_size,
Dh)`` (layer-major inside each block, so one physical block holds a token
span for *every* layer and the per-request block table is shared across the
layer scan).

**Quantized storage (``kv_dtype="int8"``).** K/V values are stored as
symmetric int8 with one float32 scale per *row* — per (layer, block, head,
token) — in two sibling pools shaped ``(L, num_blocks, Hkv, block_size)``.
The scale tensors are indexed by the same physical block id as the values,
so every operation that moves a block (COW ``copy_block``, radix-tree
sharing, refcounting) carries the scales with it for free: sharing is
metadata-only either way, and the one device op that touches block payloads
(``copy_block``) copies values and scales together. Writers quantize rows
on scatter (``serve/paged_step.py``); readers dequantize at gather time —
inside the Pallas kernels on TPU (``kernels/flash_decode_paged`` /
``flash_prefill_paged``), post-gather in the pure-JAX refs — and always
accumulate attention in float32, mirroring the paper's
int-storage/wide-accumulate split. Per-row (not per-block) scales are what
make decode append O(1): a new token's row quantizes against its own amax
and never re-quantizes the rest of the block.

**Garbage-block-0 convention.** Physical block 0 is reserved and never
allocated: every padded structure in the serving stack — padding rows of the
decode batch, padded block-table tails, padded scatter rows of an offset
prefill — points at block 0, so device writes from inactive slots land
somewhere harmless and device reads from padding return junk that is always
masked by a length. Nothing may ever hand block 0 to a request or to the
prefix cache; ``alloc`` draws from ``1..num_blocks`` only.

Blocks are **reference counted** so the radix prefix cache
(``serve/radix_cache.py``) can share one physical block between several
requests and the tree itself:

    refcount(b) == (#request tables containing b) + (1 if a tree node owns b)

A block returns to the free list exactly when its refcount reaches zero.
``alloc``/``append_block`` hand out fresh blocks at refcount 1; ``share``
splices already-resident blocks into a request's table (refcount +1);
``incref``/``decref`` are the tree-ownership handles. All metadata is
host-side Python — the scheduler and cache mutate it between device steps;
the jitted steps only ever see the padded int32 block tables.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class PoolExhausted(Exception):
    """Raised when an allocation cannot be satisfied; triggers cache
    eviction first and preemption as the last resort."""


@dataclasses.dataclass
class PoolStats:
    num_blocks: int          # usable blocks (excludes the garbage block)
    blocks_in_use: int = 0   # blocks off the free list (refcount >= 1)
    peak_in_use: int = 0
    allocs: int = 0
    frees: int = 0
    # prefix-cache counters
    shared_blocks: int = 0   # blocks with refcount >= 2 right now
    peak_shared: int = 0
    cow_copies: int = 0      # partially-filled tail blocks copied on write

    @property
    def utilization(self) -> float:
        return self.blocks_in_use / max(self.num_blocks, 1)


KV_DTYPES = ("auto", "bf16", "int8")


class PagedKVCache:
    def __init__(self, cfg: ModelConfig, num_blocks: int, block_size: int,
                 kv_dtype: str = "auto"):
        from repro.serve.paged_step import check_paged_support
        check_paged_support(cfg)     # one rule set with the model steps
        if kv_dtype not in KV_DTYPES:
            raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                             f"got {kv_dtype!r}")
        self.cfg = cfg
        self.block_size = block_size
        self.num_blocks = num_blocks
        L = cfg.n_layers
        Hkv, Dh = cfg.n_kv_heads, cfg.head_dim_
        dt = self._storage_dtype(cfg, kv_dtype)
        # resolved storage name ("auto" would hide what the pool holds)
        self.kv_dtype = jnp.dtype(dt).name
        self.quantized = dt == jnp.int8
        # +1: block 0 is the reserved garbage block, never allocated.
        shape = (L, num_blocks + 1, Hkv, block_size, Dh)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        if self.quantized:
            # one f32 scale per stored row, block-indexed like the values
            sshape = (L, num_blocks + 1, Hkv, block_size)
            self.k_scale = jnp.zeros(sshape, jnp.float32)
            self.v_scale = jnp.zeros(sshape, jnp.float32)
        else:
            self.k_scale = self.v_scale = None
        self._free: List[int] = list(range(1, num_blocks + 1))
        self._tables: Dict[int, List[int]] = {}
        self._ref = np.zeros(num_blocks + 1, np.int32)   # [0] unused
        self._copy = None            # jitted COW kernel, built on first use
        # nullable fault-injection hook (serve/faults.py): may raise
        # TransientFault from append_block — BEFORE any state mutates, so
        # the engine's bounded retry re-enters a clean pool
        self.faults = None
        self.stats = PoolStats(num_blocks)

    # -- storage sizing ---------------------------------------------------

    @staticmethod
    def _storage_dtype(cfg: ModelConfig, kv_dtype: str):
        if kv_dtype == "int8" or (kv_dtype == "auto" and cfg.opt_int8_kv):
            return jnp.int8              # "auto" follows the --optimized flag
        if kv_dtype == "bf16":
            return jnp.dtype(jnp.bfloat16)
        return cfg.compute_dtype_

    @staticmethod
    def bytes_per_block(cfg: ModelConfig, block_size: int,
                        kv_dtype: str = "auto") -> int:
        """HBM bytes ONE usable block costs across all layers (K + V, plus
        the per-row scales when quantized) — the unit the equal-memory-
        budget benchmarks size pools with."""
        L, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
        dt = jnp.dtype(PagedKVCache._storage_dtype(cfg, kv_dtype))
        per = 2 * L * Hkv * block_size * Dh * dt.itemsize
        if dt == jnp.int8:
            per += 2 * L * Hkv * block_size * 4        # f32 scales
        return per

    @property
    def hbm_bytes(self) -> int:
        """Device bytes actually held by the pool arrays (incl. block 0)."""
        n = self.k.nbytes + self.v.nbytes
        if self.quantized:
            n += self.k_scale.nbytes + self.v_scale.nbytes
        return n

    @property
    def token_capacity(self) -> int:
        return self.num_blocks * self.block_size

    # -- refcounts --------------------------------------------------------

    def _incref(self, b: int) -> None:
        self._ref[b] += 1
        if self._ref[b] == 2:
            self.stats.shared_blocks += 1
            self.stats.peak_shared = max(self.stats.peak_shared,
                                         self.stats.shared_blocks)

    def _decref(self, b: int) -> None:
        if self._ref[b] <= 0:
            raise ValueError(f"block {b}: refcount underflow (double free)")
        self._ref[b] -= 1
        if self._ref[b] == 1:
            self.stats.shared_blocks -= 1
        elif self._ref[b] == 0:
            self._free.append(b)
            self.stats.blocks_in_use -= 1
            self.stats.frees += 1

    def incref(self, b: int) -> None:
        """Take a tree-ownership reference on an already-resident block."""
        if self._ref[b] < 1:
            raise ValueError(f"block {b} is not resident; cannot incref")
        self._incref(b)

    def decref(self, b: int) -> None:
        """Drop a tree-ownership reference (eviction / node removal)."""
        self._decref(b)

    def refcount(self, b: int) -> int:
        return int(self._ref[b])

    # -- allocation -------------------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_shared(self) -> int:
        return self.stats.shared_blocks

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def _take_fresh(self, n: int) -> List[int]:
        if n > len(self._free):
            raise PoolExhausted(f"need {n} blocks, {len(self._free)} free")
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._ref[b] = 1
        self.stats.blocks_in_use += n
        self.stats.allocs += n
        self.stats.peak_in_use = max(self.stats.peak_in_use,
                                     self.stats.blocks_in_use)
        return blocks

    def alloc(self, req_id: int, n: int) -> List[int]:
        """Append ``n`` fresh blocks (refcount 1) to a request's table,
        creating the table if needed. With a prefix cache the table may
        already hold spliced shared blocks; ``alloc`` extends it in logical
        order (prefix first, fresh suffix after)."""
        blocks = self._take_fresh(n)
        self._tables.setdefault(req_id, []).extend(blocks)
        return blocks

    def share(self, req_id: int, blocks: Sequence[int]) -> None:
        """Splice already-resident blocks (a matched cache prefix) into a
        request's table; each gains one reference."""
        for b in blocks:
            if self._ref[b] < 1:
                raise ValueError(f"block {b} is not resident; cannot share")
            self._incref(b)
        self._tables.setdefault(req_id, []).extend(blocks)

    def append_block(self, req_id: int) -> int:
        """Grow a request's table by one block (decode crossed a boundary)."""
        if self.faults is not None:
            self.faults.on_append_block(req_id)   # may raise TransientFault
        (b,) = self._take_fresh(1)
        self._tables[req_id].append(b)
        return b

    def free(self, req_id: int) -> int:
        """Drop a finished/preempted request's references. Blocks whose
        refcount reaches zero return to the free list; blocks still owned by
        the prefix-cache tree (or another request) stay resident. Returns
        the number of blocks actually freed.

        Raises ``ValueError`` on an unknown ``req_id`` — a double free or a
        free of a never-allocated request is always a lifecycle bug, and
        silently returning 0 here used to let the caller's accounting drift.
        """
        if req_id not in self._tables:
            raise ValueError(
                f"free: request {req_id} has no block table "
                "(double free, or the request was never allocated)")
        blocks = self._tables.pop(req_id)
        before = len(self._free)
        for b in blocks:
            self._decref(b)
        return len(self._free) - before

    # -- device-side COW --------------------------------------------------

    def copy_block(self, src: int, dst: int) -> None:
        """Copy one physical block's K/V (all layers) ``src`` → ``dst``:
        the copy-on-write step when a request extends a partially-filled
        cached tail block that other owners must keep intact. Quantized
        pools copy the per-row scales alongside the values — a COW fork
        must reproduce the source rows bit-for-bit. On accelerators the
        pools are donated so the update aliases in place; on CPU donation
        would serialize dispatch (see engine) — skipped."""
        if self._copy is None:
            import jax

            def _cp(s, d, *pools):
                return tuple(p.at[:, d].set(p[:, s]) for p in pools)

            donate = jax.default_backend() != "cpu"
            n = 4 if self.quantized else 2
            self._copy = jax.jit(
                _cp, donate_argnums=tuple(range(2, 2 + n)) if donate else ())
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)
        if self.quantized:
            self.k, self.v, self.k_scale, self.v_scale = self._copy(
                src, dst, self.k, self.v, self.k_scale, self.v_scale)
        else:
            self.k, self.v = self._copy(src, dst, self.k, self.v)
        self.stats.cow_copies += 1

    def corrupt_block(self, b: int) -> None:
        """Fault injection only: silently corrupt one physical block's K/V
        payload in place (sign-flip every row, all layers) — the model the
        kv_corrupt fault uses for a bad DMA/scatter. Metadata (refcounts,
        tables, scales) is untouched: the corruption is invisible to every
        bookkeeping check and only detectable by reading the data back,
        which is exactly what the guard's readback audit does."""
        if b == 0:
            raise ValueError("refusing to corrupt the garbage block")
        self.k = self.k.at[:, b].set(-self.k[:, b])
        self.v = self.v.at[:, b].set(-self.v[:, b])

    # -- views ------------------------------------------------------------

    def blocks_of(self, req_id: int) -> List[int]:
        return self._tables[req_id]

    def n_blocks_of(self, req_id: int) -> int:
        return len(self._tables.get(req_id, ()))

    def table_array(self, req_ids: Sequence[int], width: int) -> np.ndarray:
        """Padded (len(req_ids), width) int32 block table; pad = block 0."""
        out = np.zeros((len(req_ids), width), np.int32)
        for i, rid in enumerate(req_ids):
            blocks = self._tables.get(rid, ())
            out[i, :len(blocks)] = blocks
        return out

    @property
    def utilization(self) -> float:
        return self.stats.utilization
