"""Replica placement for the fleet front-end.

Two policies:

``affinity`` (default) — radix-cache prefix affinity: each candidate
replica is scored by ``engine.prefix_cache.lookup(prompt)``, the
longest already-cached prefix its radix tree can serve (a read-only
walk; no pins, no side effects). The replica with the longest hit wins —
prefill skips those tokens AND the shared-prefix blocks are reused
copy-on-write, so tenant traffic naturally colocates. Ties (including
the cold all-zeros case) fall back to load (fewest queued+running
requests), then to the largest evictable budget (free blocks plus
evictable cached blocks — the headroom a new trajectory can actually
claim).

``round-robin`` — rotate over accepting replicas; the bench baseline
affinity is gated against.

Health gating applies to both policies, from the replica's PR 8
``EngineGuard`` plus the supervisor's liveness view: dead/hung replicas
are skipped, SHEDDING replicas are skipped (their front door raises
``EngineSheddingError`` anyway), and DEGRADED replicas are demoted — a
healthy replica always wins over a degraded one regardless of affinity,
because a degraded replica is already shrinking its admission/prefill
knobs to shed pressure.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.serve.guard import DEGRADED, SHEDDING

ROUTING_POLICIES = ("affinity", "round-robin")


@dataclasses.dataclass
class PlacementDecision:
    """One routing decision (kept for forensics/tests)."""

    replica: int
    policy: str
    affinity_tokens: int = 0
    load: int = 0
    budget: int = 0
    demoted: bool = False      # placed on a DEGRADED replica


class Router:
    """Stateless scoring over the live replica set (the one mutable bit
    is the round-robin cursor). ``place`` returns the chosen replica
    handle or None when no replica is accepting."""

    def __init__(self, policy: str = "affinity"):
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"expected one of {ROUTING_POLICIES}")
        self.policy = policy
        self.decisions: List[PlacementDecision] = []
        self._rr_next = 0

    # -- scoring -----------------------------------------------------------

    @staticmethod
    def _accepting(replica) -> bool:
        if not replica.accepting:
            return False
        guard = replica.engine.guard
        return guard is None or guard.state != SHEDDING

    @staticmethod
    def _health_rank(replica) -> int:
        guard = replica.engine.guard
        return 1 if (guard is not None and guard.state == DEGRADED) else 0

    @staticmethod
    def _affinity(replica, prompt: np.ndarray) -> int:
        cache = replica.engine.prefix_cache
        return cache.lookup(prompt) if cache is not None else 0

    @staticmethod
    def _load(replica) -> int:
        sched = replica.engine.sched
        return len(sched.waiting) + len(sched.running)

    @staticmethod
    def _budget(replica) -> int:
        eng = replica.engine
        free = eng.pool.num_free
        if eng.prefix_cache is not None:
            free += eng.prefix_cache.evictable_blocks()
        return free

    def place(self, prompt: np.ndarray, replicas) -> Optional[object]:
        """Choose a replica for ``prompt`` among ``replicas`` (a list of
        supervisor ``ReplicaHandle``s). Returns the handle, or None when
        the whole fleet is refusing work (caller backs off and retries)."""
        cands = [r for r in replicas if self._accepting(r)]
        if not cands:
            return None
        if self.policy == "round-robin":
            order = sorted(cands, key=lambda r: (
                (r.idx - self._rr_next) % (max(r.idx for r in cands) + 1),
                r.idx))
            best = order[0]
            self._rr_next = best.idx + 1
            self.decisions.append(PlacementDecision(
                best.idx, self.policy, load=self._load(best),
                demoted=self._health_rank(best) > 0))
            return best
        scored = sorted(
            cands,
            key=lambda r: (self._health_rank(r),
                           -self._affinity(r, prompt),
                           self._load(r),
                           -self._budget(r),
                           r.idx))
        best = scored[0]
        self.decisions.append(PlacementDecision(
            best.idx, self.policy,
            affinity_tokens=self._affinity(best, prompt),
            load=self._load(best), budget=self._budget(best),
            demoted=self._health_rank(best) > 0))
        return best
