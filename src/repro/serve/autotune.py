"""Profile-guided grid planning for the paged decode kernel.

PR 5 left ``kv_tile_blocks`` / ``decode_split_k`` as static constructor
knobs and the ROADMAP follow-up open: *"per-step ``decode_split_k`` chosen
from the ``lengths`` vector instead of one static factor"*. The kernel
cost observatory (``serve/kernel_costs.py``) provides the missing signal;
this module closes the loop.

``GridPlanner`` ranks a fixed candidate set of ``(kv_tile_blocks,
split_k)`` grids by the analytic latency proxy ``estimate_seconds ∘
decode_launch_cost`` evaluated on the *actual* batch state — the lengths
vector the kernel is about to attend and the bucketed table width — and
returns the argmin. The tradeoff it arbitrates is real and shifts with
the batch: bigger kv tiles amortize per-grid-step overhead but round
short rows' compute up to the tile (and pad the table, pure gather
waste); split-K shortens the long row's sequential walk but multiplies
padding and merge work. A mixed batch prefers different grids before and
after its long request finishes — that regime shift is what
``benchmarks/autotune_bench.py`` gates on.

Two invariants keep this serve-safe:

* **Closed candidate set.** Candidates are fixed at construction and the
  engine warms up every (candidate × table-width-bucket) jit entry, so
  per-step planning NEVER compiles a new shape mid-serve — it only picks
  among already-compiled entries. The knobs are layout, not math, so any
  choice produces the identical greedy stream.
* **Decisions are observable.** Every decision lands in the PR 6 metric
  registry (choice counters, predicted-seconds histogram) and, when the
  engine reports the measured step duration back via
  ``observe_measured``, predicted-vs-measured is recorded too — the
  observatory watches its own model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.kernel_costs import (CostParams, DEFAULT_COST_PARAMS,
                                      LaunchCost, decode_launch_cost,
                                      estimate_seconds)

AUTOTUNE_MODES = ("off", "static", "per-step")


@dataclasses.dataclass(frozen=True)
class GridDecision:
    """One planning outcome: the chosen grid, its modeled cost, and the
    full ranking it won (``considered`` is ``((tile, split, seconds),
    ...)`` in candidate order)."""

    kv_tile_blocks: int
    split_k: int
    predicted_s: float
    cost: LaunchCost
    considered: Tuple[Tuple[int, int, float], ...]


def default_candidates(kv_tile_blocks: int,
                       split_k: int) -> Tuple[Tuple[int, int], ...]:
    """The candidate grids implied by the engine's static knobs: every
    combination of {1, kv_tile_blocks} × {1, split_k}, deduped. Bounded so
    warmup compiles at most 4 variants per width bucket."""
    cands = {(1, 1), (kv_tile_blocks, 1), (1, split_k),
             (kv_tile_blocks, split_k)}
    return tuple(sorted(cands))


class GridPlanner:
    """Ranks decode grid candidates by modeled step latency.

    Pure host-side arithmetic — never touches a device. Costs depend on
    the lengths vector only through the per-row block counts
    (``ceil(len/BS)``; tile-level ceils derive from it), so decisions are
    memoized on ``(table_width, sorted block counts)`` — decode lengths
    advance one token per step, so consecutive steps usually hit.
    """

    def __init__(self, candidates: Sequence[Tuple[int, int]], *,
                 n_q_heads: int, n_kv_heads: int, head_dim: int,
                 block_size: int, kv_dtype: str = "float32",
                 cost_params: Optional[CostParams] = None,
                 registry=None, max_decisions: int = 4096):
        cands = sorted({(int(t), int(s)) for t, s in candidates})
        if not cands or any(t < 1 or s < 1 for t, s in cands):
            raise ValueError(f"bad candidate grid set: {candidates!r}")
        self.candidates: Tuple[Tuple[int, int], ...] = tuple(cands)
        self.n_q_heads = n_q_heads
        self.n_kv_heads = n_kv_heads
        self.head_dim = head_dim
        self.block_size = block_size
        self.kv_dtype = kv_dtype
        self.cost_params = cost_params or DEFAULT_COST_PARAMS
        self.registry = registry
        self.decisions: List[Dict] = []      # bounded in-memory trail
        self.max_decisions = max_decisions
        self._cache: Dict[Tuple, GridDecision] = {}

    # -- planning ---------------------------------------------------------

    def rank(self, lengths: Sequence[int],
             table_width: int) -> GridDecision:
        """Model every candidate on this batch state; argmin latency.
        Ties break toward fewer grid steps, then candidate order (stable,
        deterministic)."""
        scored = []
        for (t, s) in self.candidates:
            c = decode_launch_cost(
                lengths, table_width, n_q_heads=self.n_q_heads,
                n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
                block_size=self.block_size, kv_tile_blocks=t, split_k=s,
                kv_dtype=self.kv_dtype)
            scored.append((estimate_seconds(c, self.cost_params), c, t, s))
        best_s, best_c, bt, bs = min(
            scored, key=lambda x: (x[0], x[1].grid_steps))
        return GridDecision(
            kv_tile_blocks=bt, split_k=bs, predicted_s=best_s, cost=best_c,
            considered=tuple((t, s, sec) for sec, _, t, s in scored))

    def plan_decode(self, lengths: Sequence[int],
                    table_width: int) -> GridDecision:
        """Memoized ``rank`` + telemetry recording — the engine's per-step
        entry point. ``lengths`` must be what the kernel will attend."""
        BS = self.block_size
        key = (table_width,
               tuple(sorted(-(-int(ln) // BS) for ln in lengths)))
        dec = self._cache.get(key)
        if dec is None:
            if len(self._cache) >= self.max_decisions:
                self._cache.clear()
            dec = self._cache[key] = self.rank(lengths, table_width)
        self._record(dec, table_width)
        return dec

    # -- observability ----------------------------------------------------

    def _record(self, dec: GridDecision, table_width: int) -> None:
        if len(self.decisions) < self.max_decisions:
            self.decisions.append({
                "table_width": table_width,
                "kv_tile_blocks": dec.kv_tile_blocks,
                "split_k": dec.split_k,
                "predicted_s": dec.predicted_s,
                "gather_bytes": dec.cost.gather_bytes,
                "waste_bytes": dec.cost.waste_bytes,
                "flops": dec.cost.flops})
        reg = self.registry
        if reg is None:
            return
        reg.counter("autotune_decisions_total",
                    "grid planning decisions made").inc()
        reg.counter(
            f"autotune_choice_t{dec.kv_tile_blocks}_s{dec.split_k}_total",
            "decisions that picked this (kv_tile_blocks, split_k)").inc()
        reg.gauge("autotune_kv_tile_blocks",
                  "kv_tile_blocks of the latest decision"
                  ).set(dec.kv_tile_blocks)
        reg.gauge("autotune_split_k",
                  "split_k of the latest decision").set(dec.split_k)
        reg.histogram("autotune_predicted_step_seconds",
                      "modeled decode step latency of the chosen grid"
                      ).observe(dec.predicted_s)

    def observe_measured(self, dec: GridDecision, measured_s: float) -> None:
        """Close the predicted-vs-measured loop for one planned step."""
        reg = self.registry
        if reg is None or measured_s <= 0:
            return
        reg.histogram("autotune_measured_step_seconds",
                      "measured decode step latency under planned grids"
                      ).observe(measured_s)
        reg.gauge("autotune_pred_over_measured",
                  "latest predicted/measured step-latency ratio (a "
                  "calibration signal, not a correctness one: the argmin "
                  "is scale-free)").set(dec.predicted_s / measured_s)

    def summary(self) -> Dict[str, int]:
        """Decision counts per chosen grid, e.g. ``{"t4_s2": 37, ...}``."""
        out: Dict[str, int] = {}
        for d in self.decisions:
            k = f"t{d['kv_tile_blocks']}_s{d['split_k']}"
            out[k] = out.get(k, 0) + 1
        return out
